"""Measured-runtime calibration of the analytical cost model.

Sweeps (scheme, layer) pairs through the full pipeline — solve with the
intra-layer solver, lower to a ``KernelPlan``, execute through
``pl.pallas_call``, time it — and compares the detailed model's predicted
latency against the measured wall clock:

  * **rank correlation** (Spearman): does the model order schemes/layers
    the same way the hardware does?  This is the trust gate every future
    solver change can be held to (the MAESTRO lesson: analytical models
    are only as good as their measured validation);
  * **per-term scale coefficients**: least-squares fit of measured seconds
    against the roofline's component cycle terms (compute, DRAM, GBUF)
    plus a per-grid-step launch overhead.  The fit is exported as a
    ``cost_model.Calibration`` that ``cost_model.predicted_seconds`` /
    ``BatchResult.predicted_seconds`` optionally load to turn cycle counts
    into wall-clock estimates.

On CPU the kernels run in Pallas interpret mode, so absolute numbers
calibrate the *interpreter*, not silicon — the record stores the backend so
a TPU-measured record is distinguishable.  Rank correlation is meaningful
on both.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from ..core.cost_model import Calibration, cycle_terms
from ..core.directives import LayerScheme, canonical_orders
from ..core.solver.intralayer import Constraints, solve_intra_layer
from ..hw.template import HWTemplate
from ..hw.presets import eyeriss_multinode
from ..workloads.layers import LayerSpec, attention, conv, fc
from .exec import make_inputs, plan_runner, reference_output, rel_error
from .plan import lower_scheme


# ---------------------------------------------------------------------------
# Spearman rank correlation (no scipy dependency)
# ---------------------------------------------------------------------------

def _ranks(a: np.ndarray) -> np.ndarray:
    a = np.asarray(a, dtype=float)
    order = np.argsort(a, kind="mergesort")
    r = np.empty(len(a))
    r[order] = np.arange(1, len(a) + 1)
    vals, inv, counts = np.unique(a, return_inverse=True, return_counts=True)
    sums = np.zeros(len(vals))
    np.add.at(sums, inv, r)
    return sums[inv] / counts[inv]          # tie-averaged ranks


def spearman(x: Sequence[float], y: Sequence[float]) -> float:
    rx, ry = _ranks(np.asarray(x)), _ranks(np.asarray(y))
    rx -= rx.mean()
    ry -= ry.mean()
    denom = float(np.sqrt((rx ** 2).sum() * (ry ** 2).sum()))
    return float((rx * ry).sum() / denom) if denom > 0 else 0.0


# ---------------------------------------------------------------------------
# Sweep definition
# ---------------------------------------------------------------------------

def default_hw() -> HWTemplate:
    """A deliberately small node grid so realistic layers overflow on-chip
    capacity and the DRAM-level loop nest (the Pallas grid) is non-trivial."""
    return eyeriss_multinode(nodes=4, pe=8)


def default_sweep(quick: bool = True) -> List[LayerSpec]:
    """conv / matmul / attention layers spanning ~3 orders of magnitude of
    work, all small enough for interpret-mode execution."""
    layers = [
        fc("cal.fc.s", 64, 128, 128),
        fc("cal.fc.m", 64, 512, 512),
        fc("cal.fc.l", 128, 1024, 1024),
        fc("cal.fc.wide", 512, 1024, 512),
        fc("cal.fc.xl", 256, 2048, 1024),
        conv("cal.conv.s", 2, 16, 32, 14, 14, 3, 3),
        conv("cal.conv.m", 2, 64, 64, 28, 28, 3, 3),
        conv("cal.conv.5x5", 4, 32, 96, 14, 14, 5, 5),
        conv("cal.conv.stride2", 2, 32, 64, 28, 28, 3, 3, stride=2),
        conv("cal.conv.deep", 2, 96, 128, 14, 14, 3, 3),
        conv("cal.conv.l", 4, 64, 128, 28, 28, 3, 3),
        attention("cal.attn.s", 2, 2, 128, 64),
        attention("cal.attn.m", 2, 4, 256, 64),
        attention("cal.attn.l", 4, 4, 256, 64),
        attention("cal.attn.long", 2, 4, 512, 64),
    ]
    if not quick:
        layers += [
            fc("cal.fc.xxl", 256, 4096, 2048),
            conv("cal.conv.xl", 4, 128, 256, 28, 28, 3, 3),
            attention("cal.attn.xl", 4, 8, 512, 64),
        ]
    return layers


def _active_nest(scheme: LayerScheme) -> tuple:
    """The DRAM-level loops that actually run (dims with tf > 1, in nest
    order) — two orders with the same active nest lower to the same plan."""
    top = scheme.levels[-1]
    sig = [d for d in top.order if top.tf(d) > 1]
    sig += [d for d in scheme.layer.dims if top.tf(d) > 1 and d not in sig]
    return tuple(sig)


def scheme_variants(layer: LayerSpec, hw: HWTemplate,
                    n_variants: int = 2) -> List[LayerScheme]:
    """The solver's best scheme plus up to ``n_variants`` DRAM loop-order
    variants of it (identical factors, different outermost nest — different
    grid order AND different predicted traffic).  Orders whose *active*
    nest matches an already-kept scheme are no-op duplicates and skipped,
    so every returned scheme lowers to a distinct plan."""
    scheme, cost = solve_intra_layer(layer, hw,
                                     Constraints(nodes=hw.node_array))
    if scheme is None or not cost.valid:
        return []
    out = [scheme]
    seen = {_active_nest(scheme)}
    for order in canonical_orders():
        if len(out) >= 1 + n_variants:
            break
        var = LayerScheme(layer, [lv.copy() for lv in scheme.levels])
        var.levels[-1].order = tuple(order)
        sig = _active_nest(var)
        if sig in seen:
            continue
        seen.add(sig)
        out.append(var)
    return out


# ---------------------------------------------------------------------------
# Calibration run
# ---------------------------------------------------------------------------

def fit_calibration(pairs: List[Dict], hw: HWTemplate,
                    backend: str = "interpret") -> Calibration:
    """Least-squares fit: measured_seconds ~ cycle terms + grid steps.
    The fit is stamped with the backend it measured, so interpreter and
    compiled coefficients never masquerade as each other."""
    X = np.array([[p["cyc_compute"], p["cyc_dram"], p["cyc_gbuf"],
                   p["grid_steps"], 1.0] for p in pairs])
    y = np.array([p["measured_seconds"] for p in pairs])
    coef, *_ = np.linalg.lstsq(X, y, rcond=None)
    raw = [p["predicted_cycles"] for p in pairs]
    return Calibration(
        a_compute=float(coef[0]), a_dram=float(coef[1]),
        a_gbuf=float(coef[2]), a_step=float(coef[3]),
        intercept=float(coef[4]),
        spearman=spearman(raw, y), n_pairs=len(pairs), backend=backend)


def run_calibration(hw: Optional[HWTemplate] = None, quick: bool = True,
                    layers: Optional[Sequence[LayerSpec]] = None,
                    n_variants: int = 3, interpret: bool = True,
                    verify: bool = True, iters: int = 2,
                    seed: int = 0, backend: Optional[str] = None) -> Dict:
    """Full calibration sweep; returns a JSON-safe record (see module
    docstring).  ``record["calibration"]`` round-trips through
    ``cost_model.Calibration.from_json_dict`` and carries the executed
    ``backend``, so ``load_calibration`` installs it per backend —
    compiled-backend coefficients never price interpreter runs."""
    from ..kernels.backend import resolve_backend
    from .netexec import record_latency_drift
    backend = resolve_backend(backend, interpret)
    hw = hw if hw is not None else default_hw()
    layers = list(layers) if layers is not None else default_sweep(quick)
    pairs: List[Dict] = []
    skipped: List[Dict] = []
    for layer in layers:
        for vi, scheme in enumerate(scheme_variants(layer, hw, n_variants)):
            plan = lower_scheme(scheme, hw)
            if not plan.valid:
                skipped.append({"layer": layer.name, "variant": vi,
                                "reason": plan.reason})
                continue
            entry = {
                "layer": layer.name, "kind": plan.kind, "variant": vi,
                "grid": [(ax.dim, ax.steps) for ax in plan.grid],
                "grid_steps": plan.grid_steps,
                "predicted_cycles": plan.predicted.latency_cycles,
                "predicted_energy_pj": plan.predicted.energy_pj,
                "predicted_seconds_raw":
                    plan.predicted.latency_cycles / hw.freq_hz,
            }
            entry.update(cycle_terms(plan.predicted, layer.total_macs(), hw))
            # one jitted runner serves warmup, verification and timing —
            # the warmup output IS the numerics check, no extra execution
            inputs = make_inputs(plan, seed)
            run = plan_runner(plan, jit=True, backend=backend)
            out = jax.block_until_ready(run(inputs))
            if verify:
                err = rel_error(out, reference_output(plan, inputs))
                entry["rel_err"] = err
                if err >= 1e-3:
                    skipped.append({"layer": layer.name, "variant": vi,
                                    "reason": f"numerics {err:.2e}"})
                    continue
            best = float("inf")
            for _ in range(max(1, iters)):
                t0 = time.perf_counter()
                jax.block_until_ready(run(inputs))
                best = min(best, time.perf_counter() - t0)
            entry["measured_seconds"] = best
            # per-kernel drift sample: the watchdog sees the sweep's
            # predicted-vs-measured pairs, not only network-level ones
            record_latency_drift(entry["predicted_seconds_raw"], best,
                                 source="calibration", backend=backend)
            pairs.append(entry)

    record: Dict = {
        "hw": hw.name,
        "backend": backend,
        "n_pairs": len(pairs),
        "pairs": pairs,
        "skipped": skipped,
    }
    if len(pairs) >= 3:
        cal = fit_calibration(pairs, hw, backend=backend)
        measured = [p["measured_seconds"] for p in pairs]
        calibrated = [
            cal.a_compute * p["cyc_compute"] + cal.a_dram * p["cyc_dram"]
            + cal.a_gbuf * p["cyc_gbuf"] + cal.a_step * p["grid_steps"]
            + cal.intercept for p in pairs]
        record["calibration"] = cal.to_json_dict()
        record["spearman_raw"] = cal.spearman
        record["spearman_calibrated"] = spearman(calibrated, measured)
    return record


# ---------------------------------------------------------------------------
# Network-level calibration: solve -> lower_network -> execute -> measure
# ---------------------------------------------------------------------------

def default_network_sweep(quick: bool = True):
    """Real registered nets spanning ~2 orders of magnitude of work, every
    layer kind executable (conv/fc/pool/eltwise), at batch sizes small
    enough for interpret-mode end-to-end execution.  The full sweep's four
    nets are the BENCH_network.json record; the quick pair is the CI
    network-execution smoke gate."""
    from ..workloads.nets import get_net, transformer
    nets = [get_net("mlp", batch=4), transformer(batch=8, layers=2)]
    if not quick:
        nets += [get_net("lstm", batch=64), get_net("alexnet", batch=1)]
    return nets


def run_network_calibration(hw: Optional[HWTemplate] = None,
                            quick: bool = True, nets=None,
                            interpret: bool = True, iters: int = 2,
                            seed: int = 0, tol: float = 1e-3,
                            backend: Optional[str] = None) -> Dict:
    """End-to-end network calibration: each net is solved, lowered to a
    ``NetworkPlan``, verified against the whole-graph reference pass, and
    its measured wall clock compared with the schedule's predicted
    latency.  ``spearman_network`` is the network-granularity trust gate
    (does the solver order whole nets the way execution does?), the
    counterpart of the per-kernel gate in ``run_calibration``."""
    from ..core.solver import solve
    from ..kernels.backend import resolve_backend
    from .netexec import (compare_network, make_network_inputs,
                          measure_network, network_runner)
    from .netplan import lower_network

    backend = resolve_backend(backend, interpret)
    hw = hw if hw is not None else default_hw()
    nets = list(nets) if nets is not None else default_network_sweep(quick)
    entries: List[Dict] = []
    skipped: List[Dict] = []
    for net in nets:
        schedule = solve(net, hw)
        if not schedule.valid:
            skipped.append({"net": net.name, "reason": "solve failed"})
            continue
        nplan = lower_network(schedule, net, hw)
        bad = nplan.invalid_layers()
        if bad:
            skipped.append({"net": net.name,
                            "reason": "; ".join(f"{n}: {r}"
                                                for n, r in bad)})
            continue
        # one compiled runner serves verification, warmup and timing
        inputs = make_network_inputs(nplan, seed)
        run = network_runner(nplan, inputs, jit=True, backend=backend)
        ver = compare_network(nplan, run(), inputs, tol)
        entry = {
            "net": net.name,
            "n_layers": len(nplan.order),
            "n_segments": len(nplan.segments),
            "n_forwarded": ver.n_forwarded,
            "forwarded": list(nplan.forwarded()),
            "max_rel_err": ver.max_rel_err,
            "worst_layer": ver.worst_layer,
            "predicted_cycles": schedule.total_latency_cycles,
            "predicted_seconds_raw":
                schedule.total_latency_cycles / hw.freq_hz,
            "predicted_energy_pj": schedule.total_energy_pj,
            "solve_seconds": schedule.solve_seconds,
        }
        if not ver.ok:
            # keep the rel error visible so numerics gates can still fire
            # on nets excluded from the timing record
            skipped.append({"net": net.name, "max_rel_err": ver.max_rel_err,
                            "reason": f"numerics {ver.max_rel_err:.2e} "
                                      f"at {ver.worst_layer}"})
            continue
        entry["measured_seconds"] = measure_network(
            nplan, iters=iters, warmup=0, runner=run,
            predicted_seconds=entry["predicted_seconds_raw"],
            drift_source="calibration", backend=backend)
        entries.append(entry)

    record: Dict = {
        "hw": hw.name,
        "backend": backend,
        "n_nets": len(entries),
        "nets": entries,
        "skipped": skipped,
    }
    if len(entries) >= 2:
        record["spearman_network"] = spearman(
            [e["predicted_cycles"] for e in entries],
            [e["measured_seconds"] for e in entries])
    return record


def save_record(record: Dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")


def load_record(path: str) -> Dict:
    with open(path) as f:
        return json.load(f)


def main(argv=None) -> int:
    """CLI sweep driver: ``python -m repro.lower.calibrate [--compiled]``.

    ``--compiled`` measures the fused XLA tier instead of the interpret
    oracle; the emitted record (and its fitted coefficients) carry the
    backend, so loading it calibrates ``predicted_seconds`` for that
    backend only."""
    import argparse
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--compiled", action="store_true",
                        help="measure the fused compiled backend instead "
                             "of the interpret oracle")
    parser.add_argument("--backend", default=None,
                        choices=["interpret", "pallas", "compiled"],
                        help="explicit backend (overrides --compiled)")
    parser.add_argument("--network", action="store_true",
                        help="run the end-to-end network sweep instead of "
                             "the per-kernel sweep")
    parser.add_argument("--full", action="store_true",
                        help="full sweep (default: quick)")
    parser.add_argument("--iters", type=int, default=2)
    parser.add_argument("--out", default=None,
                        help="write the JSON record here")
    args = parser.parse_args(argv)
    backend = args.backend or ("compiled" if args.compiled else "interpret")
    if args.network:
        record = run_network_calibration(quick=not args.full,
                                         iters=args.iters, backend=backend)
    else:
        record = run_calibration(quick=not args.full, iters=args.iters,
                                 backend=backend)
    if args.out:
        save_record(record, args.out)
    print(json.dumps({k: v for k, v in record.items()
                      if k not in ("pairs", "nets")}, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())


__all__ = ["spearman", "default_hw", "default_sweep", "scheme_variants",
           "fit_calibration", "run_calibration", "save_record",
           "load_record", "Calibration", "default_network_sweep",
           "run_network_calibration", "main"]
