"""Execute a ``NetworkPlan`` end-to-end through ``pl.pallas_call``.

The layer tier (``exec.py``) runs one kernel; this module chains every
kernel of a lowered network in topological order, realizing the plan's
buffer schedule:

  * **forwarded** tensors (segment-internal, see ``netplan``) stay live
    jax arrays handed directly from the producing kernel to its
    consumers — never materialized through a host round-trip;
  * **boundary** tensors are materialized to host numpy after the
    producer and re-uploaded when consumed — the execution analogue of a
    DRAM store + reload.

Layer graphs are analytical specs, so producer/consumer shapes line up
only approximately (conv halos, flattening before FC, LSTM gate merges,
inception concat).  A single canonical **adapter** closes the gap, used
identically by the executor and the whole-graph reference pass
(``reference_network``) so rel-error comparisons are apples-to-apples:

  1. equal per-batch size        -> reshape (flatten before FC, 2-D<->4-D);
  2. channel-matched 4-D tensors -> centered zero-pad / crop of the
     spatial dims (reproduces e.g. AlexNet's conv padding exactly);
  3. divisible per-batch size    -> fold-sum over the leading groups
     (LSTM gate merge: 4*hidden -> hidden);

and multi-source eltwise layers whose channel counts partition the output
(inception concat) embed each source at its channel offset, so the n-ary
sum kernel computes the concatenation.
"""
from __future__ import annotations

import dataclasses
import math
import time
import zlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ref
from ..kernels.backend import backend_interprets, resolve_backend
from ..obs import metrics, trace, watch
from ..workloads.layers import LayerSpec
from .exec import (_check_compiled_revisit_order, _run_conv, _run_eltwise,
                   _run_fc, _run_pool, input_extent, rel_error)
from .netplan import NetworkPlan


# ---------------------------------------------------------------------------
# shapes + the canonical adapter
# ---------------------------------------------------------------------------


def required_input_shape(layer: LayerSpec) -> Tuple[int, ...]:
    """Canonical input-activation shape each kernel consumes."""
    if layer.kind == "fc":
        return (layer.dim("N"), layer.dim("C"))
    if layer.kind in ("conv", "pool"):
        XI, YI = input_extent(layer)
        return (layer.dim("N"), layer.dim("C"), XI, YI)
    if layer.kind == "eltwise":
        return (layer.dim("N"), layer.dim("C"), layer.dim("X"),
                layer.dim("Y"))
    raise ValueError(f"no network-exec input feed for kind {layer.kind!r}")


def adapt_tensor(arr: jnp.ndarray, shape: Tuple[int, ...]) -> jnp.ndarray:
    """Adapt a producer output to a consumer's required input shape (see
    module docstring for the three rules)."""
    arr = jnp.asarray(arr)
    if tuple(arr.shape) == tuple(shape):
        return arr
    n = shape[0]
    src_per = int(np.prod(arr.shape[1:]))
    dst_per = int(np.prod(shape[1:]))
    if src_per == dst_per:
        return arr.reshape(shape)
    if arr.ndim == 4 and len(shape) == 4 and arr.shape[1] == shape[1]:
        out = arr
        for ax in (2, 3):
            d = shape[ax] - out.shape[ax]
            if d > 0:
                pad = [(0, 0)] * 4
                pad[ax] = (d // 2, d - d // 2)
                out = jnp.pad(out, pad)
            elif d < 0:
                lo = (-d) // 2
                out = jax.lax.slice_in_dim(out, lo, lo + shape[ax], axis=ax)
        return out
    if src_per % dst_per == 0:
        k = src_per // dst_per
        return arr.reshape((n, k, dst_per)).sum(axis=1).reshape(shape)
    raise ValueError(f"cannot adapt shape {tuple(arr.shape)} -> "
                     f"{tuple(shape)}")


def _eltwise_operands(srcs: Sequence[jnp.ndarray],
                      layer: LayerSpec) -> List[jnp.ndarray]:
    """Adapt eltwise sources to the output shape.  When the sources'
    channel counts partition the output channels (inception concat), each
    source is embedded at its channel offset so the sum kernel computes
    the concatenation; otherwise every source adapts independently and
    the kernel computes a plain sum (residual add, gate merge)."""
    shape = required_input_shape(layer)
    C = shape[1]
    chans = [a.shape[1] if a.ndim == 4 else -1 for a in srcs]
    if len(srcs) > 1 and all(c > 0 for c in chans) and sum(chans) == C \
            and any(c != C for c in chans):
        out, off = [], 0
        for a, c in zip(srcs, chans):
            a4 = adapt_tensor(a, (shape[0], c, shape[2], shape[3]))
            out.append(jnp.pad(a4, ((0, 0), (off, C - off - c),
                                    (0, 0), (0, 0))))
            off += c
        return out
    return [adapt_tensor(a, shape) for a in srcs]


# ---------------------------------------------------------------------------
# deterministic network inputs (external activations + per-layer weights)
# ---------------------------------------------------------------------------

def _key(seed: int, name: str) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(seed),
                              zlib.crc32(name.encode()) & 0x7FFFFFFF)


def make_network_inputs(nplan: NetworkPlan,
                        seed: int = 0) -> Dict[str, jnp.ndarray]:
    """``"<layer>.I"`` external activations for graph sources and
    ``"<layer>.W"`` weights for conv/fc layers, variance-scaled so
    activations stay O(1) through deep graphs."""
    inputs: Dict[str, jnp.ndarray] = {}
    for name in nplan.order:
        layer = nplan.plans[name].layer
        if not any(s in nplan.plans for s in layer.src):
            inputs[f"{name}.I"] = jax.random.normal(
                _key(seed, name + ".I"), required_input_shape(layer),
                jnp.float32)
        if layer.kind == "fc":
            inputs[f"{name}.W"] = jax.random.normal(
                _key(seed, name + ".W"),
                (layer.dim("C"), layer.dim("K")), jnp.float32) \
                * layer.dim("C") ** -0.5
        elif layer.kind == "conv":
            R, S = int(layer.meta["R"]), int(layer.meta["S"])
            fan_in = layer.dim("C") * R * S
            inputs[f"{name}.W"] = jax.random.normal(
                _key(seed, name + ".W"),
                (layer.dim("K"), layer.dim("C"), R, S), jnp.float32) \
                * fan_in ** -0.5
    return inputs


# ---------------------------------------------------------------------------
# per-layer step functions + the execution chain
# ---------------------------------------------------------------------------

def _layer_fn(nplan: NetworkPlan, name: str, inputs: Dict,
              interpret: bool) -> Tuple[Callable, Tuple[str, ...]]:
    """(fn, src_names): ``fn(*src_arrays) -> output`` for one layer, with
    the shape adapter folded in (so the whole step jits as one unit)."""
    plan = nplan.plans[name]
    layer = plan.layer
    srcs = tuple(s for s in layer.src if s in nplan.plans)
    w = inputs.get(f"{name}.W")
    ext = inputs.get(f"{name}.I")
    shape = required_input_shape(layer)

    if plan.kind == "fc":
        def fn(*xs):
            return _run_fc(plan, adapt_tensor(xs[0] if xs else ext, shape),
                           w, interpret)
    elif plan.kind == "conv":
        def fn(*xs):
            return _run_conv(plan, adapt_tensor(xs[0] if xs else ext,
                                                shape), w, interpret)
    elif plan.kind == "pool":
        def fn(*xs):
            return _run_pool(plan, adapt_tensor(xs[0] if xs else ext,
                                                shape), interpret)
    elif plan.kind == "eltwise":
        def fn(*xs):
            ops = _eltwise_operands(list(xs) if xs else [ext], layer)
            return _run_eltwise(plan, ops, interpret)
    else:
        raise ValueError(f"cannot execute layer {name!r}: kind "
                         f"{plan.kind!r} has no network-exec input feed")
    return fn, srcs


@dataclasses.dataclass
class NetworkExecution:
    """Outputs of one end-to-end network run plus the realized buffer
    schedule (which tensors stayed on-chip vs round-tripped).  Under the
    fused ``compiled`` backend nothing crosses the host at all —
    ``roundtrips`` then lists the segment-*boundary* tensors (the plan's
    DRAM analogue), which stay device-resident inside the executable."""

    outputs: Dict[str, jnp.ndarray]
    forwarded: Tuple[str, ...]      # handed on-chip, never left the device
    roundtrips: Tuple[str, ...]     # materialized to host numpy
    seconds: float
    backend: str = "interpret"


def _check_executable(nplan: NetworkPlan) -> None:
    bad = nplan.invalid_layers()
    if bad:
        raise ValueError(
            f"network plan {nplan.graph_name!r} is not executable: "
            + "; ".join(f"{n}: {r}" for n, r in bad))


def network_runner(nplan: NetworkPlan, inputs: Dict,
                   interpret: bool = True, jit: bool = True,
                   backend: Optional[str] = None,
                   keep: str = "all") -> Callable[[], NetworkExecution]:
    """Build a reusable ``() -> NetworkExecution`` for the plan.

    ``backend`` selects the execution tier (``kernels.backend`` is the
    source of truth; the legacy ``interpret`` bool keeps its meaning when
    ``backend`` is None):

      * ``"interpret"`` — per-layer interpret-mode ``pl.pallas_call``
        chain, the bit-accuracy oracle.  Forwarded tensors pass between
        kernels as live jax arrays; boundary tensors are materialized to
        host numpy and re-uploaded at the consumer — the host round-trip
        that models the DRAM boundary.  With ``jit=True`` each layer step
        (adapter + kernel) is staged once and re-invocations reuse the
        compiled executables.
      * ``"pallas"`` — the same chain with compiled Pallas kernels (TPU).
      * ``"compiled"`` — fused segments (``fuse.fused_runner``): the
        whole plan runs as one jitted executable from the process-wide
        executable cache; ``keep="boundary"`` returns only segment-
        boundary outputs (the serving/measurement path), ``keep="all"``
        every layer output (verification).
    """
    backend = resolve_backend(backend, interpret)
    if backend == "compiled":
        from .fuse import fused_runner
        fused = fused_runner(nplan)
        fwd = nplan.forwarded()
        boundary = tuple(n for n in nplan.order if n not in fwd)

        def run_fused() -> NetworkExecution:
            t0 = time.perf_counter()
            outputs = fused(inputs, keep=keep)
            for v in outputs.values():
                jax.block_until_ready(v)
            return NetworkExecution(
                outputs=outputs, forwarded=fwd, roundtrips=boundary,
                seconds=time.perf_counter() - t0, backend=backend)
        return run_fused

    _check_executable(nplan)
    if backend == "pallas":
        # compiled Pallas cannot accumulate across non-consecutive output-
        # block revisits: apply the layer tier's guard to every plan
        for name in nplan.order:
            _check_compiled_revisit_order(nplan.plans[name])
    steps = []
    for name in nplan.order:
        fn, srcs = _layer_fn(nplan, name, inputs,
                             backend_interprets(backend))
        steps.append((name, jax.jit(fn) if jit else fn, srcs,
                      nplan.placements[name].forwarded))

    def run() -> NetworkExecution:
        t0 = time.perf_counter()
        onchip: Dict[str, jnp.ndarray] = {}
        host: Dict[str, np.ndarray] = {}
        for name, fn, srcs, fwd in steps:
            args = [onchip[s] if s in onchip else jnp.asarray(host[s])
                    for s in srcs]
            out = fn(*args)
            if fwd:
                onchip[name] = out              # stays a live device array
            else:
                host[name] = np.asarray(out)    # the host round-trip
        for v in onchip.values():
            jax.block_until_ready(v)
        seconds = time.perf_counter() - t0
        outputs = {**onchip,
                   **{k: jnp.asarray(v) for k, v in host.items()}}
        return NetworkExecution(outputs=outputs, forwarded=tuple(onchip),
                                roundtrips=tuple(host), seconds=seconds,
                                backend=backend)
    return run


def execute_network(nplan: NetworkPlan, inputs: Optional[Dict] = None,
                    interpret: bool = True, seed: int = 0,
                    jit: bool = True,
                    backend: Optional[str] = None) -> NetworkExecution:
    """Run every kernel of the plan in topological order (one-shot
    convenience over ``network_runner``)."""
    inputs = inputs if inputs is not None else make_network_inputs(nplan,
                                                                   seed)
    return network_runner(nplan, inputs, interpret=interpret, jit=jit,
                          backend=backend)()


# ---------------------------------------------------------------------------
# whole-graph reference forward pass + verification
# ---------------------------------------------------------------------------

def reference_network(nplan: NetworkPlan,
                      inputs: Dict) -> Dict[str, jnp.ndarray]:
    """Ground truth: the same graph evaluated with the ``kernels/ref.py``
    oracles and the same canonical adapters, in the same order."""
    vals: Dict[str, jnp.ndarray] = {}
    for name in nplan.order:
        layer = nplan.plans[name].layer
        srcs = [vals[s] for s in layer.src if s in vals]
        shape = required_input_shape(layer)
        x = adapt_tensor(srcs[0], shape) if srcs else inputs[f"{name}.I"]
        if layer.kind == "fc":
            vals[name] = ref.matmul_ref(x, inputs[f"{name}.W"])
        elif layer.kind == "conv":
            vals[name] = ref.conv2d_ref(x, inputs[f"{name}.W"],
                                        stride=int(layer.meta["stride"]))
        elif layer.kind == "pool":
            vals[name] = ref.pool2d_ref(x, int(layer.meta["R"]),
                                        int(layer.meta["S"]),
                                        stride=int(layer.meta["stride"]))
        elif layer.kind == "eltwise":
            ops = _eltwise_operands(srcs if srcs else [inputs[f"{name}.I"]],
                                    layer)
            vals[name] = ref.eltwise_ref(*ops)
        else:
            raise ValueError(f"no oracle for kind {layer.kind!r}")
    return vals


@dataclasses.dataclass
class NetworkVerification:
    ok: bool
    max_rel_err: float
    worst_layer: str
    errors: Dict[str, float]
    n_forwarded: int


def compare_network(nplan: NetworkPlan, ex: NetworkExecution,
                    inputs: Dict, tol: float = 1e-3) -> NetworkVerification:
    """Compare **every** layer output of an execution against the
    whole-graph reference pass (per-layer max relative error) — the one
    comparison rule shared by ``verify_network``, the calibration sweep
    and callers reusing a ``network_runner``."""
    want = reference_network(nplan, inputs)
    errors = {n: rel_error(ex.outputs[n], want[n]) for n in nplan.order}
    worst = max(errors, key=errors.get)
    return NetworkVerification(
        ok=errors[worst] < tol, max_rel_err=errors[worst],
        worst_layer=worst, errors=errors, n_forwarded=len(ex.forwarded))


def verify_network(nplan: NetworkPlan, interpret: bool = True,
                   seed: int = 0, tol: float = 1e-3, jit: bool = True,
                   backend: Optional[str] = None) -> NetworkVerification:
    """Execute the plan and compare against the whole-graph reference
    (one-shot convenience over ``compare_network``).  The default backend
    is the interpret oracle; pass ``backend="compiled"`` to verify the
    fused tier (it always keeps every layer output for the comparison)."""
    inputs = make_network_inputs(nplan, seed)
    ex = execute_network(nplan, inputs, interpret=interpret, jit=jit,
                         backend=backend)
    return compare_network(nplan, ex, inputs, tol)


_m_drift = metrics.histogram(
    "latency_drift_ratio",
    "measured / predicted network latency of lowered plans",
    ("source", "backend"), buckets=metrics.DRIFT_BUCKETS)


def record_latency_drift(predicted_seconds: Optional[float],
                         measured_seconds: float,
                         source: str = "netexec",
                         backend: str = "interpret") -> Optional[float]:
    """Record one predicted-vs-measured latency pair into the
    ``latency_drift_ratio`` histogram (+ a trace instant), so cost-model
    calibration decay is visible at serve time, not only in the
    calibration bench.  The ``backend`` label keeps interpreter-tax
    ratios from polluting the compiled tier's drift signal.  Returns the
    ratio, or None if either side is unusable (zero/negative prediction,
    NaN measurement)."""
    if not predicted_seconds or predicted_seconds <= 0.0:
        return None
    if not math.isfinite(measured_seconds) or measured_seconds <= 0.0:
        return None
    ratio = measured_seconds / predicted_seconds
    _m_drift.observe(ratio, source=source, backend=backend)
    watch.note_sample(predicted_seconds, measured_seconds,
                      source=source, backend=backend)
    trace.instant("netexec.latency_drift", source=source, backend=backend,
                  ratio=round(ratio, 4))
    return ratio


def measure_network(nplan: NetworkPlan, inputs: Optional[Dict] = None,
                    interpret: Optional[bool] = None, iters: int = 2,
                    warmup: int = 1,
                    runner: Optional[Callable[[], NetworkExecution]] = None,
                    predicted_seconds: Optional[float] = None,
                    drift_source: str = "netexec",
                    backend: Optional[str] = None) -> float:
    """Measured wall-clock seconds for one end-to-end network execution
    (min over ``iters`` after ``warmup`` runs compile every layer step).
    Includes the buffer schedule's real host round-trips — network time,
    not a sum of isolated kernel times.  Measurement defaults to the
    **compiled** tier (the serving path: one fused executable per
    segment, boundary outputs only, forwarded tensors never
    materialize); pass ``backend="interpret"`` (or legacy
    ``interpret=True``) to time the oracle instead.

    Pass an existing ``network_runner`` (with ``warmup=0`` if it already
    ran, e.g. for verification) to reuse its compiled steps — the single
    timing protocol behind the calibration sweep and the quickstart."""
    backend = resolve_backend(backend, interpret)
    if runner is None:
        inputs = inputs if inputs is not None \
            else make_network_inputs(nplan)
        runner = network_runner(
            nplan, inputs, jit=True, backend=backend,
            keep="boundary" if backend == "compiled" else "all")
        warmup = max(1, warmup)         # fresh steps always need a compile
    for _ in range(warmup):
        runner()
    out = min(runner().seconds for _ in range(max(1, iters)))
    if predicted_seconds is not None:
        record_latency_drift(predicted_seconds, out, source=drift_source,
                             backend=backend)
    return out
