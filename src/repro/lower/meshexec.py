"""Execute a ``MultiNodePlan`` over a pool of worker nodes — resiliently.

The network tier (``netexec``) chains every kernel on one device; this
module spreads that chain over a mesh of worker "nodes".  In this
container each node is a single-worker thread over the one local
device — but the interfaces (``NodePool.submit/kill/alive``,
``SegmentTask``) are the mesh-ready seams a real multi-node transport
would implement.

Execution walks the plan's chain segments in order; each segment runs
on one node of its assigned part (replicated parts round-robin
requests across their node group — every replica runs the identical
full-batch kernels, so results are bit-identical wherever a request
lands).  Segment-*boundary* tensors are host numpy (``netplan``'s DRAM
analogue), which makes each boundary a natural **checkpoint**: the
request's ``state`` dict after segment *i* is exactly what segment
*i+1* needs, so a failed dispatch replays from the last completed
boundary instead of restarting the request.

The node-failure ladder (each rung a cheaper recovery than the next):

  1. **speculate**   — ``StragglerDetector`` flags nodes whose EWMA
     task latency exceeds ``factor`` x the fleet median;
  2. **re-dispatch** — a flagged node's work is raced through
     ``BackupDispatcher`` against a healthy peer; first success wins;
  3. **re-partition** — a ``NodeFailure`` (crash, or a hang past the
     task deadline, which drains the node) triggers
     ``ElasticPlanner.plan_nodes`` + ``multinode.repartition``:
     surviving parts keep their assignments, only the dead node's
     segments are re-placed (the dirty set), and the straggler history
     of the drained node is ``forget``-ten;
  4. **single-node fallback** — below ``min_nodes`` survivors the
     executor runs segments inline on the driver, flagged degraded.

Faults are injected at the ``node.crash`` / ``node.hang`` /
``node.slow`` sites (``runtime.inject``), so chaos runs are seeded and
replayable.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeout
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.solver.multinode import MultiNodePlan, repartition
from ..kernels.backend import backend_interprets, resolve_backend
from ..obs import metrics, trace
from ..runtime import inject
from ..runtime.fault import ElasticPlanner, NodeFailure
from ..runtime.straggler import BackupDispatcher, StragglerDetector
from .netexec import _check_executable, _layer_fn
from .netplan import NetworkPlan

# -- telemetry (repro.obs) ---------------------------------------------------
_m_alive = metrics.gauge("mesh_alive_nodes",
                         "live worker nodes in the pool")
_m_recovery = metrics.histogram(
    "mesh_recovery_seconds",
    "wall clock per node-failure recovery (repartition or fallback)")


# ---------------------------------------------------------------------------
# segment tasks: one callable per chain segment, checkpoint in/out
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SegmentTask:
    """One chain segment as a self-contained unit of node work:
    ``run(state) -> outputs`` reads the boundary tensors it ``consumes``
    from the checkpoint state and returns the boundary tensors it
    ``produces`` as host numpy (the next checkpoint increment).
    Segment-internal forwarded tensors never leave the call."""

    index: int
    consumes: Tuple[str, ...]
    produces: Tuple[str, ...]
    run: Callable[[Dict[str, np.ndarray]], Dict[str, np.ndarray]]


def build_segment_tasks(nplan: NetworkPlan, weights: Dict,
                        interpret: bool = True,
                        jit: bool = True,
                        backend: Optional[str] = None) -> List[SegmentTask]:
    """Compile the plan's layers into per-segment tasks.

    ``weights`` holds the ``"<layer>.W"`` arrays (captured into the
    jitted steps — resident weights, like a serving node).  External
    activations are *not* captured: each request supplies its
    ``"<layer>.I"`` tensors through the state dict, so one compiled
    task list serves every request.

    Under ``backend="compiled"`` each task wraps one fused segment
    executable from the process-wide cache (``fuse.fused_runner``):
    replaying a task after a node failure — or rebuilding the task list
    for the same plan on another request — reuses the traced
    executable.  A fused task only emits tensors some later segment or
    the network output needs; tensors the interpret tier would
    round-trip but that stay inside one segment never leave the
    executable.
    """
    backend = resolve_backend(backend, interpret)
    if backend == "compiled":
        from .fuse import fused_runner
        fused = fused_runner(nplan)
        tasks = []
        for seg in nplan.segments:
            consumes, produces = fused.segment_io[seg.index]
            acts = tuple(s for s in consumes if not s.endswith(".W"))
            wkeys = tuple(s for s in consumes if s.endswith(".W"))

            def run(state: Dict[str, np.ndarray], index=seg.index,
                    acts=acts, wkeys=wkeys) -> Dict[str, np.ndarray]:
                feed = {s: jnp.asarray(state[s]) for s in acts}
                feed.update({w: weights[w] for w in wkeys})
                out = fused.run_segment(index, feed)
                return {k: np.asarray(v) for k, v in out.items()}

            tasks.append(SegmentTask(seg.index, acts, produces, run))
        return tasks
    _check_executable(nplan)
    interpret = backend_interprets(backend)
    steps: Dict[str, Tuple[Callable, Tuple[str, ...]]] = {}
    for name in nplan.order:
        fn, srcs = _layer_fn(nplan, name, weights, interpret)
        steps[name] = (jax.jit(fn) if jit else fn, srcs)
    # a forwarded tensor with a consumer outside its own segment must
    # still cross the boundary: emit it like a round-tripped tensor
    emit: Dict[str, bool] = {}
    for seg in nplan.segments:
        inseg = set(seg.layer_names)
        for n in seg.layer_names:
            outside = any(n in steps[c][1] for c in nplan.order
                          if c not in inseg)
            emit[n] = outside or not nplan.placements[n].forwarded
    tasks: List[SegmentTask] = []
    for seg in nplan.segments:
        names = tuple(seg.layer_names)
        inseg = set(names)
        consumes: List[str] = []
        for n in names:
            srcs = steps[n][1]
            if srcs:
                consumes += [s for s in srcs if s not in inseg]
            else:
                consumes.append(f"{n}.I")
        produces = tuple(n for n in names if emit[n])

        def run(state: Dict[str, np.ndarray], names=names,
                inseg=inseg) -> Dict[str, np.ndarray]:
            onchip: Dict[str, jnp.ndarray] = {}
            out: Dict[str, np.ndarray] = {}
            for n in names:
                fn, srcs = steps[n]
                if srcs:
                    args = [onchip[s] if s in onchip
                            else jnp.asarray(state[s]) for s in srcs]
                else:
                    args = [jnp.asarray(state[f"{n}.I"])]
                y = fn(*args)
                if n in inseg and nplan.placements[n].forwarded:
                    onchip[n] = y
                if emit[n]:
                    out[n] = np.asarray(y)
            return out

        tasks.append(SegmentTask(seg.index,
                                 tuple(dict.fromkeys(consumes)),
                                 produces, run))
    return tasks


# ---------------------------------------------------------------------------
# the node pool: serial workers with mesh-ready control surface
# ---------------------------------------------------------------------------

class NodePool:
    """``n`` worker nodes, each a single-thread executor (a node runs
    one segment at a time — serial, like a real accelerator queue).
    ``kill`` / ``set_slow`` are the chaos control surface; ``submit``
    on a dead node raises ``NodeFailure`` immediately."""

    def __init__(self, n: int, name_prefix: str = "node"):
        if n < 1:
            raise ValueError(f"pool needs >= 1 node, got {n}")
        self.n = n
        self._workers = {
            i: ThreadPoolExecutor(max_workers=1,
                                  thread_name_prefix=f"{name_prefix}{i}")
            for i in range(n)}
        self._dead: Dict[int, str] = {}
        self._slow: Dict[int, float] = {}
        self._lock = threading.Lock()
        self._events = metrics.CounterGroup("mesh_pool",
                                            ("submits", "kills"))
        _m_alive.set(n)

    def alive(self) -> List[int]:
        with self._lock:
            return [i for i in range(self.n) if i not in self._dead]

    def is_dead(self, nid: int) -> bool:
        with self._lock:
            return nid in self._dead

    def kill(self, nid: int, reason: str = "killed") -> None:
        with self._lock:
            if nid in self._dead:
                return
            self._dead[nid] = reason
            alive = self.n - len(self._dead)
        self._events.inc("kills")
        _m_alive.set(alive)
        trace.instant("mesh.node_killed", node=nid, reason=reason)
        self._workers[nid].shutdown(wait=False, cancel_futures=True)

    def set_slow(self, nid: int, factor: float) -> None:
        with self._lock:
            self._slow[nid] = max(1.0, factor)

    def slow_factor(self, nid: int) -> float:
        with self._lock:
            return self._slow.get(nid, 1.0)

    def submit(self, nid: int, fn: Callable, *args) -> Future:
        with self._lock:
            reason = self._dead.get(nid)
            worker = self._workers[nid]
        if reason is not None:
            raise NodeFailure(f"node {nid} is dead ({reason})",
                              permanent=True)
        try:
            self._events.inc("submits")
            return worker.submit(fn, *args)
        except RuntimeError as e:       # shutdown raced the check
            raise NodeFailure(f"node {nid} is dead (shut down)",
                              permanent=True) from e

    def stats(self) -> Dict:
        """Pool control-surface snapshot (mirrored into the registry as
        mesh_pool_events_total / mesh_alive_nodes)."""
        with self._lock:
            return {"nodes": self.n,
                    "alive": [i for i in range(self.n)
                              if i not in self._dead],
                    "dead": dict(self._dead),
                    "slow": dict(self._slow),
                    "submits": self._events["submits"],
                    "kills": self._events["kills"]}

    def close(self) -> None:
        for w in self._workers.values():
            w.shutdown(wait=False, cancel_futures=True)

    def __enter__(self) -> "NodePool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _node_body(pool: NodePool, nid: int, task: SegmentTask,
               state: Dict) -> Dict:
    """Run one segment task on one node, with the node-level fault
    sites applied around the real work."""
    key = f"node{nid}"
    if pool.is_dead(nid):
        raise NodeFailure(f"node {nid} is dead", permanent=True)
    inj = inject.active()
    if inj is not None:
        spec = inj.decide("node.crash", key)
        if spec is not None:
            pool.kill(nid, "injected crash")
            raise NodeFailure(f"node {nid} crashed (injected)",
                              permanent=True)
        inj.fault("node.hang", key)     # 'slow' spec blocks delay_s here
    t0 = time.perf_counter()
    out = task.run(state)
    elapsed = time.perf_counter() - t0
    factor = pool.slow_factor(nid)
    if inj is not None:
        spec = inj.decide("node.slow", key)
        if spec is not None:
            factor = max(factor, spec.factor if spec.factor > 1.0
                         else 5.0)
    if factor > 1.0:
        time.sleep(elapsed * (factor - 1.0))
    return out


# ---------------------------------------------------------------------------
# the resilient executor
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MeshExecution:
    """One request's outcome: boundary outputs plus recovery telemetry."""

    outputs: Dict[str, np.ndarray]
    degraded: bool
    replays: int                       # boundary replays after failures
    backups: int                       # speculative re-dispatches used
    seconds: float


class MeshExecutor:
    """Drive requests through a ``MultiNodePlan`` on a ``NodePool``,
    surviving node crash / hang / slowdown (see module docstring for
    the recovery ladder).  ``schedule``/``graph``/``hw`` give the
    re-partition context; without them a node loss goes straight to the
    single-node fallback rung.  Thread-safe: concurrent ``run`` calls
    share the pool, the detector and the (lock-guarded) plan."""

    def __init__(self, plan: MultiNodePlan, tasks: Sequence[SegmentTask],
                 schedule=None, graph=None, hw=None,
                 pool: Optional[NodePool] = None,
                 detector: Optional[StragglerDetector] = None,
                 planner: Optional[ElasticPlanner] = None,
                 min_nodes: int = 1,
                 task_timeout_s: float = 30.0,
                 min_backup_deadline_s: float = 0.02):
        self.plan = plan
        self.tasks = sorted(tasks, key=lambda t: t.index)
        if [t.index for t in self.tasks] != list(range(len(self.tasks))):
            raise ValueError("tasks must cover segments 0..S-1 exactly")
        self.schedule, self.graph, self.hw = schedule, graph, hw
        self._own_pool = pool is None
        self.pool = pool if pool is not None else NodePool(plan.mesh.nodes)
        self.detector = detector if detector is not None else \
            StragglerDetector(factor=2.0, warmup=2)
        self.planner = planner if planner is not None else \
            ElasticPlanner(model_axis=1, min_data=min_nodes)
        self.task_timeout_s = task_timeout_s
        self.min_backup_deadline_s = min_backup_deadline_s
        self._lock = threading.RLock()
        self._rr = itertools.count()
        self.fallback = False
        # mirrored into mesh_events_total{event=...} (repro.obs)
        self._events = metrics.CounterGroup("mesh", (
            "requests", "degraded_requests", "failures", "repartitions",
            "resolved_segments", "backups", "replays"))
        self.recovery_seconds = 0.0

    @property
    def requests(self) -> int:
        return self._events["requests"]

    @property
    def degraded_requests(self) -> int:
        return self._events["degraded_requests"]

    @property
    def failures(self) -> int:
        return self._events["failures"]

    @property
    def repartitions(self) -> int:
        return self._events["repartitions"]

    @property
    def resolved_segments(self) -> int:
        return self._events["resolved_segments"]

    @property
    def backups(self) -> int:
        return self._events["backups"]

    @property
    def replays(self) -> int:
        return self._events["replays"]

    # -- node choice ---------------------------------------------------------
    def _pick_node(self, seg_index: int, salt: int) -> Optional[int]:
        with self._lock:
            part = self.plan.part_of_segment(seg_index)
            alive = [n for n in part.node_ids
                     if not self.pool.is_dead(n)]
            if not alive:
                return None
            # replicate directive: requests round-robin the node group
            return alive[salt % len(alive)]

    def _backup_node(self, avoid: int) -> Optional[int]:
        flagged = {h for h in self.detector.stragglers()}
        with self._lock:
            alive = [n for n in self.pool.alive() if n != avoid]
        healthy = [n for n in alive if f"node{n}" not in flagged]
        pick = healthy or alive
        return pick[0] if pick else None

    # -- dispatch with the speculate / re-dispatch rungs ---------------------
    def _dispatch(self, nid: int, task: SegmentTask, state: Dict) -> Dict:
        host = f"node{nid}"
        straggling = host in set(self.detector.stragglers())
        backup_nid = self._backup_node(nid) if straggling else None
        with trace.span("mesh.task", node=nid,
                        segment=task.index) as sp:
            return self._dispatch_inner(nid, host, task, state,
                                        straggling, backup_nid, sp)

    def _dispatch_inner(self, nid: int, host: str, task: SegmentTask,
                        state: Dict, straggling: bool,
                        backup_nid: Optional[int], sp) -> Dict:
        t0 = time.perf_counter()
        if straggling:
            trace.instant(
                "mesh.straggler", node=nid,
                reason=f"EWMA latency > {self.detector.factor:g}x "
                       f"fleet median")
        if backup_nid is not None:
            med = self.detector.fleet_median() or 0.0
            deadline = max(self.min_backup_deadline_s,
                           self.detector.factor * med)
            primary = self.pool.submit(nid, _node_body, self.pool, nid,
                                       task, state)
            with BackupDispatcher(deadline_seconds=deadline) as bd:
                out = bd.run(
                    primary.result,
                    lambda: self.pool.submit(
                        backup_nid, _node_body, self.pool, backup_nid,
                        task, state).result())
                won_backup = bd.failovers > 0
            dt = time.perf_counter() - t0
            trace.instant(
                "mesh.backup_dispatch", primary=nid, backup=backup_nid,
                winner=backup_nid if won_backup else nid,
                reason="straggler flagged; raced a healthy peer")
            sp.set(backup=backup_nid, won_backup=won_backup)
            if won_backup:
                self._events.inc("backups")
            self.detector.record(f"node{backup_nid}" if won_backup
                                 else host, dt)
            return out
        fut = self.pool.submit(nid, _node_body, self.pool, nid, task,
                               state)
        try:
            out = fut.result(timeout=self.task_timeout_s)
        except FutureTimeout:
            # hung node: drain it so the repartition rung takes over
            self.pool.kill(nid, "hung")
            raise NodeFailure(
                f"node {nid} hung past {self.task_timeout_s}s deadline")
        self.detector.record(host, time.perf_counter() - t0)
        return out

    # -- the re-partition / fallback rungs -----------------------------------
    def _on_node_failure(self, nid: Optional[int],
                         err: NodeFailure) -> None:
        t0 = time.perf_counter()
        with self._lock:
            if nid is not None:
                self.pool.kill(nid, str(err))
                # a drained node must stop poisoning the fleet median
                self.detector.forget(f"node{nid}")
            self._events.inc("failures")
            survivors = self.pool.alive()
            try:
                self.planner.plan_nodes(len(survivors))
                if self.schedule is None or self.graph is None \
                        or self.hw is None:
                    raise NodeFailure("no re-partition context",
                                      permanent=True)
                new_plan, dirty = repartition(
                    self.plan, self.schedule, self.graph, self.hw,
                    survivors)
            except NodeFailure as fe:
                self.fallback = True
                trace.instant("mesh.fallback",
                              reason=f"{err} -> {fe}")
            else:
                if dirty:           # idempotent under concurrent failures
                    self.plan = new_plan
                    self._events.inc("repartitions")
                    self._events.inc("resolved_segments", len(dirty))
                    trace.instant(
                        "mesh.repartition", dead=nid,
                        dirty_segments=len(dirty),
                        survivors=len(survivors), reason=str(err))
            dt = time.perf_counter() - t0
            self.recovery_seconds += dt
        _m_recovery.observe(dt)

    # -- request execution ---------------------------------------------------
    def run(self, state_inputs: Dict,
            request_key: str = "req") -> MeshExecution:
        """Execute one request.  ``state_inputs`` carries the external
        ``"<layer>.I"`` activations; the returned outputs are every
        boundary tensor the request produced.  The state dict *is* the
        checkpoint: a failed segment replays from the last completed
        boundary, never from the start of the request."""
        t0 = time.perf_counter()
        salt = next(self._rr)
        self._events.inc("requests")
        state: Dict[str, np.ndarray] = dict(state_inputs)
        i = 0
        replays = 0
        backups0 = self.backups
        degraded = False
        with trace.span("mesh.request",
                        key=request_key) as req_span:
            while i < len(self.tasks):
                task = self.tasks[i]
                if self.fallback:
                    with trace.span("mesh.task", node="driver",
                                    segment=task.index):
                        out = task.run(state)   # last rung: inline, degraded
                    degraded = True
                else:
                    nid = self._pick_node(task.index, salt)
                    if nid is None:
                        self._on_node_failure(None, NodeFailure(
                            f"segment {task.index} lost every node"))
                        replays += 1
                        continue
                    try:
                        out = self._dispatch(nid, task, state)
                    except NodeFailure as e:
                        self._on_node_failure(nid, e)
                        replays += 1
                        continue            # replay from the last boundary
                state.update(out)           # checkpoint the boundary
                i += 1
            outputs = {k: v for k, v in state.items()
                       if k not in state_inputs}
            self._events.inc("replays", replays)
            backups = self.backups - backups0
            if degraded:
                self._events.inc("degraded_requests")
            req_span.set(replays=replays, backups=backups,
                         degraded=degraded)
        return MeshExecution(outputs=outputs, degraded=degraded,
                             replays=replays, backups=backups,
                             seconds=time.perf_counter() - t0)

    def stats(self) -> Dict:
        with self._lock:
            return {"requests": self.requests,
                    "degraded_requests": self.degraded_requests,
                    "failures": self.failures,
                    "repartitions": self.repartitions,
                    "resolved_segments": self.resolved_segments,
                    "backups": self.backups,
                    "replays": self.replays,
                    "recovery_seconds": self.recovery_seconds,
                    "fallback": self.fallback,
                    "alive_nodes": self.pool.alive(),
                    "straggler": self.detector.stats()}

    def close(self) -> None:
        if self._own_pool:
            self.pool.close()

    def __enter__(self) -> "MeshExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = ["SegmentTask", "build_segment_tasks", "NodePool",
           "MeshExecution", "MeshExecutor"]
