"""Execute ``KernelPlan``s through ``pl.pallas_call``.

One generic Pallas kernel per supported layer family (matmul/fc, conv,
attention, pool, eltwise), parameterized entirely by the plan: the grid is the solver's
DRAM-level loop nest (same order), the BlockSpecs carry the plan's block
sizes and index maps, and reduction grid axes accumulate into the output
block across revisits (initialized on the first visit, exactly like the
directive model's partial-sum residency).

Runs in interpret mode on CPU (the numerics/calibration gate) and compiled
on TPU backends.  Outputs are verified against the pure-jnp oracles in
``kernels/ref.py``.

Notes on fidelity:
  * everything on-chip (all node GBUFs + the PE arrays below them) is one
    Pallas block — a single-core Pallas program models the off-chip
    boundary, which is the boundary the solver's DRAM loop nest governs;
  * conv input halos: Pallas blocks cannot overlap, so the input streams
    in blocked over N/C with the full spatial extent and the kernel slices
    the (ix, iy) window dynamically — traffic is modeled pessimistically
    by the solver's halo multiplier either way;
  * attention keeps running (max, sum) softmax statistics in auxiliary
    *output* buffers indexed like O, so any loop order the solver picks —
    even with the KV-position axis outside the query axis — stays
    numerically exact across block revisits.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..kernels import ref
from ..kernels.backend import backend_interprets, resolve_backend
from .plan import KernelPlan


def _grid(plan: KernelPlan) -> Tuple[int, ...]:
    return plan.grid_shape if plan.grid else (1,)


def _check_compiled_revisit_order(plan: KernelPlan) -> None:
    """Compiled (non-interpret) Pallas requires revisits of an output block
    to be consecutive in grid order: every axis *inner* to an
    output-irrelevant (reduction) axis must itself be output-irrelevant.
    Interpret mode is buffer-backed and tolerates any order; compiled mode
    would silently accumulate into a flushed block, so refuse loudly."""
    rel = plan.layer.tensors["O"]
    seen_irrelevant = False
    for ax in plan.grid:
        if ax.dim not in rel:
            seen_irrelevant = True
        elif seen_irrelevant:
            raise ValueError(
                "compiled execution needs reduction grid axes innermost; "
                f"grid is ({', '.join(a.dim for a in plan.grid)}) — run in "
                "interpret mode or reorder the scheme's DRAM loop order")


def _first_visit(plan: KernelPlan):
    """Predicate: this grid step is the first visit to the current output
    block (all output-irrelevant grid axes at 0)."""
    rel = plan.layer.tensors["O"]
    pred = None
    for i, ax in enumerate(plan.grid):
        if ax.dim not in rel:
            p = pl.program_id(i) == 0
            pred = p if pred is None else jnp.logical_and(pred, p)
    return True if pred is None else pred


def _init_when(pred, fn) -> None:
    """Run ``fn`` under ``pl.when(pred)``; unconditionally when the output
    block is only ever visited once (no reduction grid axes)."""
    if pred is True:
        fn()
    else:
        pl.when(pred)(fn)


# ---------------------------------------------------------------------------
# matmul / fc
# ---------------------------------------------------------------------------

def _run_fc(plan: KernelPlan, x: jnp.ndarray, w: jnp.ndarray,
            interpret: bool) -> jnp.ndarray:
    layer = plan.layer
    bn, bc, bk = plan.block["N"], plan.block["C"], plan.block["K"]

    def kern(x_ref, w_ref, o_ref):
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)
        _init_when(_first_visit(plan), _init)
        o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                              preferred_element_type=jnp.float32)

    return pl.pallas_call(
        kern,
        grid=_grid(plan),
        in_specs=[
            pl.BlockSpec((bn, bc), plan.index_map(("N", "C"))),
            pl.BlockSpec((bc, bk), plan.index_map(("C", "K"))),
        ],
        out_specs=pl.BlockSpec((bn, bk), plan.index_map(("N", "K"))),
        out_shape=jax.ShapeDtypeStruct((layer.dim("N"), layer.dim("K")),
                                       jnp.float32),
        interpret=interpret,
    )(x, w)


# ---------------------------------------------------------------------------
# conv
# ---------------------------------------------------------------------------

def _run_conv(plan: KernelPlan, x: jnp.ndarray, w: jnp.ndarray,
              interpret: bool) -> jnp.ndarray:
    layer = plan.layer
    R = int(layer.meta["R"])
    S = int(layer.meta["S"])
    stride = int(layer.meta["stride"])
    N, C, K = layer.dim("N"), layer.dim("C"), layer.dim("K")
    XO, YO = layer.dim("X"), layer.dim("Y")
    XI, YI = x.shape[2], x.shape[3]
    bn, bc, bk = plan.block["N"], plan.block["C"], plan.block["K"]
    bx, by = plan.block["X"], plan.block["Y"]
    spanx = (bx - 1) * stride + R
    spany = (by - 1) * stride + S
    x_axis, y_axis = plan.axis_of("X"), plan.axis_of("Y")

    def kern(x_ref, w_ref, o_ref):
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)
        _init_when(_first_visit(plan), _init)
        ix = pl.program_id(x_axis) if x_axis >= 0 else 0
        iy = pl.program_id(y_axis) if y_axis >= 0 else 0
        xin = x_ref[...]                       # [bn, bc, XI, YI]
        xw = jax.lax.dynamic_slice(
            xin, (0, 0, ix * bx * stride, iy * by * stride),
            (bn, bc, spanx, spany))
        acc = jnp.zeros((bn, bk, bx, by), jnp.float32)
        for r in range(R):                     # R, S pinned in-block, as in
            for s in range(S):                 # the directive model
                patch = jax.lax.slice(
                    xw, (0, 0, r, s),
                    (bn, bc, r + (bx - 1) * stride + 1,
                     s + (by - 1) * stride + 1),
                    (1, 1, stride, stride))    # [bn, bc, bx, by]
                acc += jnp.einsum("ncxy,kc->nkxy", patch, w_ref[:, :, r, s],
                                  preferred_element_type=jnp.float32)
        o_ref[...] += acc

    return pl.pallas_call(
        kern,
        grid=_grid(plan),
        in_specs=[
            # halo'd input: blocked over N/C, full spatial extent streamed
            pl.BlockSpec((bn, bc, XI, YI), plan.index_map(("N", "C", "*",
                                                           "*"))),
            pl.BlockSpec((bk, bc, R, S), plan.index_map(("K", "C", "*",
                                                         "*"))),
        ],
        out_specs=pl.BlockSpec((bn, bk, bx, by),
                               plan.index_map(("N", "K", "X", "Y"))),
        out_shape=jax.ShapeDtypeStruct((N, K, XO, YO), jnp.float32),
        interpret=interpret,
    )(x, w)


NEG_INF = -1e30


# ---------------------------------------------------------------------------
# pool (max pooling; every grid axis is output-relevant: single visit)
# ---------------------------------------------------------------------------

def _run_pool(plan: KernelPlan, x: jnp.ndarray,
              interpret: bool) -> jnp.ndarray:
    layer = plan.layer
    R = int(layer.meta["R"])
    S = int(layer.meta["S"])
    stride = int(layer.meta["stride"])
    N, C = layer.dim("N"), layer.dim("C")
    XO, YO = layer.dim("X"), layer.dim("Y")
    XI, YI = x.shape[2], x.shape[3]
    bn, bc = plan.block["N"], plan.block["C"]
    bx, by = plan.block["X"], plan.block["Y"]
    spanx = (bx - 1) * stride + R
    spany = (by - 1) * stride + S
    x_axis, y_axis = plan.axis_of("X"), plan.axis_of("Y")

    def kern(x_ref, o_ref):
        ix = pl.program_id(x_axis) if x_axis >= 0 else 0
        iy = pl.program_id(y_axis) if y_axis >= 0 else 0
        xw = jax.lax.dynamic_slice(
            x_ref[...], (0, 0, ix * bx * stride, iy * by * stride),
            (bn, bc, spanx, spany))
        acc = jnp.full((bn, bc, bx, by), NEG_INF, jnp.float32)
        for r in range(R):                     # window pinned in-block, like
            for s in range(S):                 # conv's R/S
                patch = jax.lax.slice(
                    xw, (0, 0, r, s),
                    (bn, bc, r + (bx - 1) * stride + 1,
                     s + (by - 1) * stride + 1),
                    (1, 1, stride, stride))
                acc = jnp.maximum(acc, patch)
        o_ref[...] = acc

    return pl.pallas_call(
        kern,
        grid=_grid(plan),
        in_specs=[
            # halo'd input: blocked over N/C, full spatial extent streamed
            pl.BlockSpec((bn, bc, XI, YI), plan.index_map(("N", "C", "*",
                                                           "*"))),
        ],
        out_specs=pl.BlockSpec((bn, bc, bx, by),
                               plan.index_map(("N", "C", "X", "Y"))),
        out_shape=jax.ShapeDtypeStruct((N, C, XO, YO), jnp.float32),
        interpret=interpret,
    )(x)


# ---------------------------------------------------------------------------
# eltwise (n-ary sum; residual adds, gate merges, channel-embedded concat)
# ---------------------------------------------------------------------------

def _run_eltwise(plan: KernelPlan, xs: Sequence[jnp.ndarray],
                 interpret: bool) -> jnp.ndarray:
    layer = plan.layer
    shape = tuple(layer.dim(d) for d in ("N", "C", "X", "Y"))
    bshape = tuple(plan.block[d] for d in ("N", "C", "X", "Y"))

    def kern(*refs):
        acc = refs[0][...].astype(jnp.float32)
        for r in refs[1:-1]:
            acc = acc + r[...]
        refs[-1][...] = acc

    spec = pl.BlockSpec(bshape, plan.index_map(("N", "C", "X", "Y")))
    return pl.pallas_call(
        kern,
        grid=_grid(plan),
        in_specs=[spec] * len(xs),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(shape, jnp.float32),
        interpret=interpret,
    )(*xs)


# ---------------------------------------------------------------------------
# attention (flash-style online softmax over KV-position blocks)
# ---------------------------------------------------------------------------

def _run_attention(plan: KernelPlan, q: jnp.ndarray, k: jnp.ndarray,
                   v: jnp.ndarray, interpret: bool) -> jnp.ndarray:
    layer = plan.layer
    NH, Sq, Skv = layer.dim("N"), layer.dim("X"), layer.dim("C")
    D = layer.dim("K")
    bn, bx, bc = plan.block["N"], plan.block["X"], plan.block["C"]
    scale = D ** -0.5

    def kern(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref):
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
        _init_when(_first_visit(plan), _init)
        s = jnp.einsum("nqd,nkd->nqk", q_ref[...], k_ref[...],
                       preferred_element_type=jnp.float32) * scale
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        m_ref[...] = m_cur
        acc_ref[...] = acc_ref[...] * alpha[..., None] + \
            jnp.einsum("nqk,nkd->nqd", p, v_ref[...],
                       preferred_element_type=jnp.float32)

    acc, _m, lsum = pl.pallas_call(
        kern,
        grid=_grid(plan),
        in_specs=[
            pl.BlockSpec((bn, bx, D), plan.index_map(("N", "X", "*"))),
            pl.BlockSpec((bn, bc, D), plan.index_map(("N", "C", "*"))),
            pl.BlockSpec((bn, bc, D), plan.index_map(("N", "C", "*"))),
        ],
        out_specs=[
            pl.BlockSpec((bn, bx, D), plan.index_map(("N", "X", "*"))),
            pl.BlockSpec((bn, bx), plan.index_map(("N", "X"))),
            pl.BlockSpec((bn, bx), plan.index_map(("N", "X"))),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((NH, Sq, D), jnp.float32),
            jax.ShapeDtypeStruct((NH, Sq), jnp.float32),
            jax.ShapeDtypeStruct((NH, Sq), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return acc / jnp.maximum(lsum, 1e-30)[..., None]


# ---------------------------------------------------------------------------
# Public API: inputs, execution, verification, measurement
# ---------------------------------------------------------------------------

def input_extent(layer) -> Tuple[int, int]:
    """Minimal halo'd spatial input extent of a conv/pool layer under
    VALID padding: (X-1)*stride + R — the single definition shared by the
    layer-tier inputs and the network tier's shape plumbing."""
    R, S = int(layer.meta["R"]), int(layer.meta["S"])
    stride = int(layer.meta["stride"])
    return ((layer.dim("X") - 1) * stride + R,
            (layer.dim("Y") - 1) * stride + S)


def make_inputs(plan: KernelPlan, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Deterministic dense float32 inputs matching the plan's canonical
    layouts (fc: I[N,C] W[C,K]; conv: I[N,C,XI,YI] W[K,C,R,S];
    attention: Q/K/V [N, S, D]; pool: I[N,C,XI,YI]; eltwise: A/B
    [N,C,X,Y])."""
    layer = plan.layer
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    if plan.kind == "fc":
        return {"I": jax.random.normal(keys[0], (layer.dim("N"),
                                                 layer.dim("C")), jnp.float32),
                "W": jax.random.normal(keys[1], (layer.dim("C"),
                                                 layer.dim("K")), jnp.float32)
                * layer.dim("C") ** -0.5}
    if plan.kind == "conv":
        R, S = int(layer.meta["R"]), int(layer.meta["S"])
        XI, YI = input_extent(layer)
        fan_in = layer.dim("C") * R * S
        return {"I": jax.random.normal(
                    keys[0], (layer.dim("N"), layer.dim("C"), XI, YI),
                    jnp.float32),
                "W": jax.random.normal(
                    keys[1], (layer.dim("K"), layer.dim("C"), R, S),
                    jnp.float32) * fan_in ** -0.5}
    if plan.kind == "attention":
        NH, Sq, Skv, D = (layer.dim("N"), layer.dim("X"), layer.dim("C"),
                          layer.dim("K"))
        return {"Q": jax.random.normal(keys[0], (NH, Sq, D), jnp.float32),
                "K": jax.random.normal(keys[1], (NH, Skv, D), jnp.float32),
                "V": jax.random.normal(keys[2], (NH, Skv, D), jnp.float32)}
    if plan.kind == "pool":
        XI, YI = input_extent(layer)
        return {"I": jax.random.normal(
            keys[0], (layer.dim("N"), layer.dim("C"), XI, YI), jnp.float32)}
    if plan.kind == "eltwise":
        shape = tuple(layer.dim(d) for d in ("N", "C", "X", "Y"))
        return {"A": jax.random.normal(keys[0], shape, jnp.float32),
                "B": jax.random.normal(keys[1], shape, jnp.float32)}
    raise ValueError(f"unsupported kind {plan.kind!r}")


def plan_runner(plan: KernelPlan, interpret: bool = True,
                jit: bool = False, backend: Optional[str] = None):
    """Build a callable ``inputs_dict -> output`` for the plan.  With
    ``jit=True`` the whole pallas_call is staged once and re-invocations
    time the compiled executable (the measurement path).  ``backend``
    resolves through ``kernels.backend`` (the one source of truth):
    ``interpret``/``pallas`` run the Pallas kernel, ``compiled`` runs the
    fused tier's XLA twin of the plan (``fuse.compiled_plan_fn``)."""
    if not plan.valid:
        raise ValueError(
            f"cannot execute invalid plan for layer {plan.layer.name!r}: "
            f"{plan.invalid_reason}")
    backend = resolve_backend(backend, interpret)
    if backend == "compiled":
        from .fuse import compiled_plan_fn     # lazy: fuse imports netexec
        base, names = compiled_plan_fn(plan)
        fn = jax.jit(base) if jit else base
        return lambda inputs: fn(*(inputs[n] for n in names))
    interpret = backend_interprets(backend)
    if not interpret:
        _check_compiled_revisit_order(plan)
    if plan.kind == "fc":
        names, base = ("I", "W"), \
            lambda i, w: _run_fc(plan, i, w, interpret)
    elif plan.kind == "conv":
        names, base = ("I", "W"), \
            lambda i, w: _run_conv(plan, i, w, interpret)
    elif plan.kind == "attention":
        names, base = ("Q", "K", "V"), \
            lambda q, k, v: _run_attention(plan, q, k, v, interpret)
    elif plan.kind == "pool":
        names, base = ("I",), lambda i: _run_pool(plan, i, interpret)
    elif plan.kind == "eltwise":
        names, base = ("A", "B"), \
            lambda a, b: _run_eltwise(plan, (a, b), interpret)
    else:
        raise ValueError(f"unsupported kind {plan.kind!r}")
    fn = jax.jit(base) if jit else base
    return lambda inputs: fn(*(inputs[n] for n in names))


def execute_plan(plan: KernelPlan, inputs: Optional[Dict] = None,
                 interpret: bool = True, seed: int = 0) -> jnp.ndarray:
    """Run the plan through ``pl.pallas_call`` and return the output."""
    run = plan_runner(plan, interpret)       # refuses invalid plans first,
    inputs = inputs if inputs is not None else make_inputs(plan, seed)
    return run(inputs)                       # naming the layer + reason


def reference_output(plan: KernelPlan, inputs: Dict) -> jnp.ndarray:
    """Ground truth from ``kernels/ref.py`` for the plan's layer."""
    if plan.kind == "fc":
        return ref.matmul_ref(inputs["I"], inputs["W"])
    if plan.kind == "conv":
        return ref.conv2d_ref(inputs["I"], inputs["W"],
                              stride=int(plan.layer.meta["stride"]))
    if plan.kind == "attention":
        out = ref.attention_ref(inputs["Q"][:, None], inputs["K"][:, None],
                                inputs["V"][:, None], causal=False)
        return out[:, 0]
    if plan.kind == "pool":
        return ref.pool2d_ref(inputs["I"], int(plan.layer.meta["R"]),
                              int(plan.layer.meta["S"]),
                              stride=int(plan.layer.meta["stride"]))
    if plan.kind == "eltwise":
        return ref.eltwise_ref(inputs["A"], inputs["B"])
    raise ValueError(f"unsupported kind {plan.kind!r}")


def rel_error(out, want) -> float:
    import numpy as np
    a = np.asarray(out, np.float32)
    b = np.asarray(want, np.float32)
    return float(np.max(np.abs(a - b)) / (np.abs(b).max() + 1e-9))


def verify_plan(plan: KernelPlan, interpret: bool = True, seed: int = 0,
                tol: float = 1e-3) -> Tuple[bool, float]:
    """Execute the plan and compare against the oracle.  Returns
    (ok, max relative error)."""
    inputs = make_inputs(plan, seed)
    out = execute_plan(plan, inputs, interpret=interpret)
    err = rel_error(out, reference_output(plan, inputs))
    return err < tol, err


def measure_plan(plan: KernelPlan, inputs: Optional[Dict] = None,
                 interpret: bool = True, iters: int = 2,
                 warmup: int = 1, jit: bool = True) -> float:
    """Measured wall-clock seconds for one plan execution (min over
    ``iters`` after ``warmup`` runs; ``block_until_ready`` fences).

    Measures the jitted executable by default so the time reflects the
    plan's actual compute/memory work, not per-call tracing overhead
    (compilation happens during warmup)."""
    inputs = inputs if inputs is not None else make_inputs(plan)
    run = plan_runner(plan, interpret, jit=jit)
    for _ in range(max(1, warmup)):
        jax.block_until_ready(run(inputs))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(run(inputs))
        best = min(best, time.perf_counter() - t0)
    return best
