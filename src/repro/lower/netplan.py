"""Network-tier lowering: compile a whole solved ``NetworkSchedule`` into
an executable ``NetworkPlan``.

The layer tier (``plan.py``) turns one ``LayerScheme`` into one
``KernelPlan``; this module composes those per-layer plans along the
solver's *inter-layer* decisions — the chain's segment slicing, per-layer
node-region allocations and forwarding granularity — into an ordered plan
for the full graph plus a **buffer schedule**:

  * outputs of **segment-internal** layers (every consumer lives in the
    same chain segment) are *forwarded on-chip*: the executor hands the
    producing kernel's output directly to the consumer kernel, never
    materializing it through a host round-trip — the execution analogue of
    the directive model replacing DRAM traffic with NoC forwarding
    (``evaluate_layer(src_onchip/dst_onchip)``);
  * **segment-boundary** tensors round-trip through host arrays, the
    execution analogue of a DRAM store + reload.

A forwarded tensor is only scheduled on-chip when its double-buffered
granule (``LayerScheme.forward_bytes``) fits the *spare* aggregated GBUF
capacity of the producer's node region — capacity minus the footprint the
scheme itself already occupies.  Tensors that do not fit are demoted to a
host round-trip with the reason recorded, mirroring how the solver's
conservative inter-layer validity check is allowed false positives
(§IV-B): the network plan stays executable, just less pipelined.

This module is numpy-only (no jax); execution lives in ``netexec.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from ..hw.template import HWTemplate
from ..workloads.layers import LayerGraph
from ..core.solver.interlayer import _consumer_map
from .plan import KernelPlan, lower_scheme

#: kinds the network executor can feed from predecessor outputs (attention
#: layers take Q/K/V triples, which layer graphs do not model as edges)
NETWORK_EXEC_KINDS = ("conv", "fc", "pool", "eltwise")


@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    """One chain segment resolved to layer names + node regions."""

    index: int
    start: int
    stop: int                              # [start, stop) into the order
    layer_names: Tuple[str, ...]
    alloc: Tuple[Tuple[int, int], ...]     # node region (h, w) per layer
    granule_frac: float

    @property
    def length(self) -> int:
        return self.stop - self.start


@dataclasses.dataclass(frozen=True)
class TensorPlacement:
    """Where one layer's output tensor lives between producer and
    consumers: forwarded on-chip within a segment, or round-tripped
    through a host array (the DRAM analogue)."""

    producer: str
    consumers: Tuple[str, ...]
    segment: int
    forwarded: bool
    granule_bytes: float = 0.0             # double-buffered forwarded bytes
    spare_bytes: float = 0.0               # producer region's spare GBUF
    reason: str = ""                       # why not forwarded


@dataclasses.dataclass
class NetworkPlan:
    """A fully-resolved execution recipe for one solved network: ordered
    kernel plans, the segment structure, and the buffer schedule."""

    graph_name: str
    order: Tuple[str, ...]                 # topological layer order
    plans: Dict[str, KernelPlan]
    segments: Tuple[SegmentPlan, ...]
    placements: Dict[str, TensorPlacement]
    predicted_latency_cycles: float
    predicted_energy_pj: float

    @property
    def executable(self) -> bool:
        return not self.invalid_layers()

    def invalid_layers(self) -> List[Tuple[str, str]]:
        """(layer name, reason) for every layer that cannot execute."""
        out = [(n, self.plans[n].invalid_reason) for n in self.order
               if not self.plans[n].valid]
        out += [(n, f"kind {self.plans[n].kind!r} has no network-exec "
                 "input feed") for n in self.order
                if self.plans[n].valid
                and self.plans[n].kind not in NETWORK_EXEC_KINDS]
        for n in self.order:
            src = self.plans[n].layer.src
            in_graph = sum(1 for s in src if s in self.plans)
            if 0 < in_graph < len(src):
                # the executor feeds a layer EITHER from its in-graph
                # producers OR from one external input — a mix would
                # silently drop the external operand
                out.append((n, "mix of in-graph and external sources "
                            f"{tuple(src)} is not executable"))
        return out

    def forwarded(self) -> Tuple[str, ...]:
        """Names of outputs handed on-chip (never host round-tripped)."""
        return tuple(n for n in self.order if self.placements[n].forwarded)

    def segment_of(self, name: str) -> SegmentPlan:
        return self.segments[self.placements[name].segment]

    def describe(self) -> str:
        lines = [f"netplan[{self.graph_name}] {len(self.order)} layers, "
                 f"{len(self.segments)} segments, "
                 f"{len(self.forwarded())} forwarded tensors"]
        for seg in self.segments:
            marks = []
            for n in seg.layer_names:
                p = self.placements[n]
                marks.append(n + (" ->onchip" if p.forwarded else ""))
            lines.append(f"  seg{seg.index} gf={seg.granule_frac:g} "
                         f"[{', '.join(marks)}]")
        bad = self.invalid_layers()
        if bad:
            lines.append("  NOT EXECUTABLE: " +
                         "; ".join(f"{n}: {r}" for n, r in bad))
        return "\n".join(lines)


def _segments(schedule, graph: LayerGraph) -> List[SegmentPlan]:
    """Chain segments resolved to names; without a chain (deserialized or
    degenerate schedules) every layer becomes its own singleton segment."""
    names = [l.name for l in graph.layers]
    if schedule.chain is not None and schedule.chain.segments:
        return [SegmentPlan(i, s.start, s.stop,
                            tuple(names[s.start:s.stop]), s.alloc,
                            s.granule_frac)
                for i, s in enumerate(schedule.chain.segments)]
    return [SegmentPlan(i, i, i + 1, (n,), ((1, 1),), 1.0)
            for i, n in enumerate(names)]


def lower_network(schedule, graph: LayerGraph, hw: HWTemplate,
                  repair: bool = True) -> NetworkPlan:
    """Compile a solved ``NetworkSchedule`` into a ``NetworkPlan``.

    Layers missing a scheme (partial schedules) and unsupported kinds come
    back as invalid kernel plans with reasons — the plan reports them via
    ``invalid_layers()`` instead of raising, so callers can see exactly
    what is and is not executable.
    """
    consumers = _consumer_map(graph)
    segs = _segments(schedule, graph)
    seg_of: Dict[str, int] = {}
    for seg in segs:
        for n in seg.layer_names:
            seg_of[n] = seg.index

    plans: Dict[str, KernelPlan] = {}
    for layer in graph.layers:
        scheme = schedule.layer_schemes.get(layer.name)
        if scheme is None:
            from .plan import _invalid
            from ..core.directives import LayerScheme
            plans[layer.name] = _invalid(
                LayerScheme(layer, []), layer.kind, "no solved scheme")
        else:
            plans[layer.name] = lower_scheme(scheme, hw, repair=repair)

    gbuf_top = len(hw.levels) - 2          # outermost on-chip level
    cap = hw.levels[gbuf_top].capacity_bytes
    placements: Dict[str, TensorPlacement] = {}
    for li, layer in enumerate(graph.layers):
        name = layer.name
        cons = tuple(consumers.get(name, ()))
        seg = segs[seg_of[name]]
        common = dict(producer=name, consumers=cons, segment=seg.index)
        if not cons:
            placements[name] = TensorPlacement(
                forwarded=False, reason="network output", **common)
            continue
        if seg.length <= 1 or any(seg_of[c] != seg.index for c in cons):
            placements[name] = TensorPlacement(
                forwarded=False, reason="consumer crosses segment boundary",
                **common)
            continue
        plan = plans[name]
        if not plan.valid or any(not plans[c].valid for c in cons):
            placements[name] = TensorPlacement(
                forwarded=False, reason="producer/consumer plan invalid",
                **common)
            continue
        # double-buffered forwarded granule vs the producer region's spare
        # aggregated GBUF (capacity minus the scheme's own footprint)
        i = li - seg.start
        nodes = seg.alloc[i][0] * seg.alloc[i][1]
        need = 2.0 * plan.scheme.forward_bytes(seg.granule_frac)
        spare = nodes * max(0.0, cap
                            - plan.scheme.level_footprint_bytes(gbuf_top))
        if need > spare:
            placements[name] = TensorPlacement(
                forwarded=False, granule_bytes=need, spare_bytes=spare,
                reason=f"granule {need:.0f}B > spare GBUF {spare:.0f}B",
                **common)
            continue
        placements[name] = TensorPlacement(
            forwarded=True, granule_bytes=need, spare_bytes=spare, **common)

    return NetworkPlan(
        graph_name=schedule.graph_name,
        order=tuple(l.name for l in graph.layers),
        plans=plans, segments=tuple(segs), placements=placements,
        predicted_latency_cycles=schedule.total_latency_cycles,
        predicted_energy_pj=schedule.total_energy_pj)


def lower_cached(schedule, hw: HWTemplate,
                 graph: Optional[LayerGraph] = None,
                 repair: bool = True) -> NetworkPlan:
    """Lower a schedule that came back from the schedule store
    (``repro.service``): when no live ``graph`` is supplied, the layer
    graph is rebuilt from the specs embedded in the schedule's schemes
    (``NetworkSchedule.to_graph``) — cached schedules compile to
    executable plans without re-running the solver or keeping the
    original graph object around."""
    graph = graph if graph is not None else schedule.to_graph()
    return lower_network(schedule, graph, hw, repair=repair)
