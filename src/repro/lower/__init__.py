"""Lowering subsystem: compile solved dataflow schemes into executable
Pallas plans, execute/verify them, and calibrate the cost model against
measured runtimes — at two tiers:

  layer tier
      solver (LayerScheme)
          -> plan.lower_scheme                      (KernelPlan)
          -> exec.execute_plan / verify_plan / measure_plan
  network tier
      solver (NetworkSchedule, or schedule.lower(graph, hw))
          -> netplan.lower_network                  (NetworkPlan: ordered
             kernel plans + segment buffer schedule w/ on-chip forwarding)
          -> netexec.execute_network / verify_network / measure_network
  compiled tier
      fuse.fused_runner                  (FusedNetwork: whole segments /
         whole net jitted as single executables, process-wide cache
         keyed by fuse.plan_signature — the default measured backend;
         the interpret tier above stays the bit-accuracy oracle)
  calibration
      calibrate.run_calibration          (per-kernel Spearman + fit,
         per-backend coefficients)
      calibrate.run_network_calibration  (end-to-end network Spearman)
"""
from .plan import GridAxis, KernelPlan, lower_scheme, lower_schedule
from .exec import (execute_plan, make_inputs, measure_plan,
                   reference_output, verify_plan)
from .netplan import (NetworkPlan, SegmentPlan, TensorPlacement,
                      lower_cached, lower_network)
from .netexec import (compare_network, execute_network, make_network_inputs,
                      measure_network, network_runner, reference_network,
                      verify_network)
from .fuse import (FusedNetwork, cache_stats, clear_cache,
                   compiled_plan_fn, fused_runner, plan_signature)
from .calibrate import (fit_calibration, run_calibration,
                        run_network_calibration, save_record, spearman)

__all__ = [
    "GridAxis", "KernelPlan", "lower_scheme", "lower_schedule",
    "execute_plan", "make_inputs", "measure_plan", "reference_output",
    "verify_plan",
    "NetworkPlan", "SegmentPlan", "TensorPlacement", "lower_cached",
    "lower_network",
    "compare_network", "execute_network", "make_network_inputs",
    "measure_network", "network_runner", "reference_network",
    "verify_network",
    "FusedNetwork", "cache_stats", "clear_cache", "compiled_plan_fn",
    "fused_runner", "plan_signature",
    "fit_calibration", "run_calibration", "run_network_calibration",
    "save_record", "spearman",
]
