"""Lowering subsystem: compile solved dataflow schemes into executable
Pallas plans, execute/verify them, and calibrate the cost model against
measured runtimes.

  solver (LayerScheme / NetworkSchedule)
      -> plan.lower_scheme / plan.lower_schedule   (KernelPlan)
      -> exec.execute_plan / verify_plan / measure_plan   (pl.pallas_call)
      -> calibrate.run_calibration   (Spearman gate + fitted Calibration)
"""
from .plan import GridAxis, KernelPlan, lower_scheme, lower_schedule
from .exec import (execute_plan, make_inputs, measure_plan,
                   reference_output, verify_plan)
from .calibrate import (fit_calibration, run_calibration, save_record,
                        spearman)

__all__ = [
    "GridAxis", "KernelPlan", "lower_scheme", "lower_schedule",
    "execute_plan", "make_inputs", "measure_plan", "reference_output",
    "verify_plan", "fit_calibration", "run_calibration", "save_record",
    "spearman",
]
