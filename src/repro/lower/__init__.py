"""Lowering subsystem: compile solved dataflow schemes into executable
Pallas plans, execute/verify them, and calibrate the cost model against
measured runtimes — at two tiers:

  layer tier
      solver (LayerScheme)
          -> plan.lower_scheme                      (KernelPlan)
          -> exec.execute_plan / verify_plan / measure_plan
  network tier
      solver (NetworkSchedule, or schedule.lower(graph, hw))
          -> netplan.lower_network                  (NetworkPlan: ordered
             kernel plans + segment buffer schedule w/ on-chip forwarding)
          -> netexec.execute_network / verify_network / measure_network
  calibration
      calibrate.run_calibration          (per-kernel Spearman + fit)
      calibrate.run_network_calibration  (end-to-end network Spearman)
"""
from .plan import GridAxis, KernelPlan, lower_scheme, lower_schedule
from .exec import (execute_plan, make_inputs, measure_plan,
                   reference_output, verify_plan)
from .netplan import (NetworkPlan, SegmentPlan, TensorPlacement,
                      lower_cached, lower_network)
from .netexec import (compare_network, execute_network, make_network_inputs,
                      measure_network, network_runner, reference_network,
                      verify_network)
from .calibrate import (fit_calibration, run_calibration,
                        run_network_calibration, save_record, spearman)

__all__ = [
    "GridAxis", "KernelPlan", "lower_scheme", "lower_schedule",
    "execute_plan", "make_inputs", "measure_plan", "reference_output",
    "verify_plan",
    "NetworkPlan", "SegmentPlan", "TensorPlacement", "lower_cached",
    "lower_network",
    "compare_network", "execute_network", "make_network_inputs",
    "measure_network", "network_runner", "reference_network",
    "verify_network",
    "fit_calibration", "run_calibration", "run_network_calibration",
    "save_record", "spearman",
]
