"""Lower a solved ``LayerScheme`` to a concrete, executable ``KernelPlan``.

This is the bridge between the two halves of the repo: the numpy solver
produces tensor-centric directives (temporal factors + loop order + spatial
factors per memory level); this module compiles them into the exact
quantities a ``pl.pallas_call`` needs:

  * the **grid**: one axis per DRAM-level temporal loop, ordered exactly as
    the solver's outermost loop order (outer -> inner, lexicographic Pallas
    iteration);
  * per-dim **block sizes**: everything inside one grid step — the on-chip
    working set (all node GBUF tiles plus the spatial unrolling below them);
  * per-tensor **BlockSpec index maps**: a tensor's block index along an
    array axis is the grid coordinate of the dim mapped to that axis, or 0
    for dims the tensor is blocked over entirely on-chip — the direct
    analogue of the directive rule "a tensor refetches when a relevant
    outer loop advances".

Validity is re-checked at lowering time: the factors must exactly tile the
layer dims, and each tensor's per-buffer tile at every on-chip level must
fit the ``HWTemplate`` capacity the solver assumed (the scheme's own
footprint model, so the check can never diverge from what was scored).  A
plan that fails any check is returned with ``valid=False`` and a reason,
never half executable.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..hw.template import HWTemplate
from ..workloads.layers import DIMS, LayerSpec
from ..core.cost_model import CostBreakdown, evaluate_layer
from ..core.directives import LayerScheme, smallest_prime_factor

SUPPORTED_KINDS = ("conv", "fc", "attention", "pool", "eltwise")


@dataclasses.dataclass(frozen=True)
class GridAxis:
    dim: str        # blocking dim ("N", "C", "K", "X", "Y")
    steps: int      # number of grid steps along this axis


@dataclasses.dataclass
class KernelPlan:
    """A fully-resolved execution recipe for one layer scheme."""

    layer: LayerSpec
    scheme: LayerScheme            # the (possibly repaired) scheme executed
    kind: str                      # conv | fc | attention
    grid: Tuple[GridAxis, ...]     # outer -> inner
    block: Dict[str, int]          # per-dim on-chip block size per grid step
    valid: bool
    reason: str = ""
    level_footprints: Tuple[float, ...] = ()   # bytes per on-chip level
    predicted: Optional[CostBreakdown] = None  # detailed-model standalone cost

    @property
    def invalid_reason(self) -> str:
        """Why the plan cannot execute ("" for valid plans)."""
        return "" if self.valid else self.reason

    @property
    def grid_shape(self) -> Tuple[int, ...]:
        return tuple(ax.steps for ax in self.grid)

    @property
    def grid_steps(self) -> int:
        p = 1
        for ax in self.grid:
            p *= ax.steps
        return p

    def axis_of(self, dim: str) -> int:
        """Grid-axis position of ``dim`` (-1 when the dim is not blocked)."""
        for i, ax in enumerate(self.grid):
            if ax.dim == dim:
                return i
        return -1

    def index_map(self, axes: Sequence[str]) -> Callable:
        """Pallas ``BlockSpec`` index map for a tensor laid out with one
        array axis per entry of ``axes`` (a dim name, or "*" for axes that
        are never blocked, e.g. conv's R/S)."""
        pos = [self.axis_of(d) if d != "*" else -1 for d in axes]

        def imap(*gidx):
            return tuple(gidx[p] if p >= 0 else 0 for p in pos)
        return imap

    def describe(self) -> str:
        g = " x ".join(f"{ax.dim}:{ax.steps}" for ax in self.grid) or "1"
        blk = ", ".join(f"{d}={v}" for d, v in sorted(self.block.items())
                        if self.layer.dim(d) > 1)
        return (f"plan[{self.layer.name}/{self.kind}] grid({g}) "
                f"block({blk})" + ("" if self.valid else
                                   f" INVALID: {self.reason}"))


def _invalid(scheme: LayerScheme, kind: str, reason: str) -> KernelPlan:
    return KernelPlan(layer=scheme.layer, scheme=scheme, kind=kind,
                      grid=(), block={}, valid=False, reason=reason)


def _grid_axes(scheme: LayerScheme) -> List[GridAxis]:
    """DRAM-level temporal loops as grid axes, outer -> inner, following the
    solver's loop order; dims blocked but missing from the order (custom
    orders) append innermost, mirroring the cost model's nest."""
    top = scheme.levels[-1]
    axes = [GridAxis(d, top.tf(d)) for d in top.order if top.tf(d) > 1]
    listed = {ax.dim for ax in axes}
    axes += [GridAxis(d, top.tf(d)) for d in DIMS
             if top.tf(d) > 1 and d not in listed]
    return axes


def _concrete_footprints(scheme: LayerScheme, hw: HWTemplate
                         ) -> Tuple[Tuple[float, ...], str]:
    """Per-buffer footprint bytes at every on-chip level vs the capacities
    the solver assumed (returns (footprints, error)).  Uses the scheme's
    own footprint model so lowering validity can never diverge from what
    the cost model scored."""
    fps: List[float] = []
    for lv in range(len(hw.levels) - 1):
        fp = scheme.level_footprint_bytes(lv)
        cap = hw.levels[lv].capacity_bytes
        if fp > cap:
            return tuple(fps), (f"{hw.levels[lv].name} block footprint "
                                f"{fp:.0f}B > {cap:.0f}B")
        fps.append(fp)
    return tuple(fps), ""


def _repair_attention(scheme: LayerScheme, hw: HWTemplate
                      ) -> Optional[LayerScheme]:
    """Attention plans need the head dim (K) resident per block — softmax
    statistics are per (N, X) row and the PV product consumes whole rows.
    If the solver split K at the DRAM level, hoist that factor into the
    outermost on-chip level; when that overflows the buffer, demote query /
    batch / KV-position blocking to the DRAM nest to make room (the
    standard flash-attention shape: full head dim, blocked rows)."""
    top = scheme.levels[-1]
    if top.tf("K") == 1:
        return scheme
    fixed = LayerScheme(scheme.layer, [lv.copy() for lv in scheme.levels])
    gbuf = fixed.levels[-2]
    gbuf.t["K"] = gbuf.tf("K") * top.tf("K")
    fixed.levels[-1].t["K"] = 1
    _, err = _concrete_footprints(fixed, hw)
    for d in ("X", "N", "C"):
        while err and gbuf.tf(d) > 1:
            p = smallest_prime_factor(gbuf.tf(d))
            gbuf.t[d] = gbuf.tf(d) // p
            fixed.levels[-1].t[d] = fixed.levels[-1].tf(d) * p
            _, err = _concrete_footprints(fixed, hw)
        if not err:
            break
    return None if err else fixed


def lower_scheme(scheme: LayerScheme, hw: HWTemplate,
                 repair: bool = True) -> KernelPlan:
    """Compile one solved intra-layer scheme into a ``KernelPlan``.

    The returned plan's ``predicted`` cost is the detailed model evaluated
    on the *executed* scheme (standalone: all boundary tensors streamed
    from DRAM), so calibration compares like with like even when
    ``repair`` adjusted the scheme.
    """
    layer = scheme.layer
    kind = layer.kind
    if kind not in SUPPORTED_KINDS:
        return _invalid(scheme, kind, f"unsupported layer kind {kind!r}")
    if len(scheme.levels) != len(hw.levels) or len(hw.levels) < 3:
        return _invalid(scheme, kind, "level count mismatch")
    if not scheme.validate_factors():
        return _invalid(scheme, kind, "factors do not multiply to dims")
    if kind in ("conv", "pool") and not {"R", "S", "stride"} <= set(layer.meta):
        return _invalid(scheme, kind, f"{kind} layer lacks R/S/stride meta")

    if kind == "attention":
        reshaped = _repair_attention(scheme, hw) if repair else \
            (scheme if scheme.levels[-1].tf("K") == 1 else None)
        if reshaped is None:
            return _invalid(scheme, kind,
                            "attention head-dim split at DRAM level "
                            "(K rows must stay block-resident)")
        scheme = reshaped

    top = scheme.levels[-1]
    block: Dict[str, int] = {}
    for d in DIMS:
        if layer.dim(d) % top.tf(d) != 0:
            return _invalid(scheme, kind,
                            f"dim {d}={layer.dim(d)} not divisible by "
                            f"DRAM factor {top.tf(d)}")
        block[d] = layer.dim(d) // top.tf(d)

    fps, err = _concrete_footprints(scheme, hw)
    if err:
        return _invalid(scheme, kind, err)

    plan = KernelPlan(layer=layer, scheme=scheme, kind=kind,
                      grid=tuple(_grid_axes(scheme)), block=block,
                      valid=True, level_footprints=fps,
                      predicted=evaluate_layer(scheme, hw))
    return plan


def lower_schedule(schedule, graph, hw: HWTemplate,
                   repair: bool = True) -> Dict[str, KernelPlan]:
    """Lower every supported layer of a solved ``NetworkSchedule``;
    unsupported kinds come back as invalid plans (with reasons) so callers
    can see exactly what is and is not executable."""
    plans: Dict[str, KernelPlan] = {}
    for name, scheme in schedule.layer_schemes.items():
        plans[name] = lower_scheme(scheme, hw, repair=repair)
    return plans
