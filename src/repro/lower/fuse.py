"""Fused compiled segment execution: whole segments as single executables.

The interpret tier (``netexec`` with ``backend="interpret"``) runs every
layer as a separate interpret-mode ``pl.pallas_call`` with jax-array
handoffs and host round-trips at segment boundaries — bit-accurate, and
two to three orders of magnitude slower than the schedule it models
(``BENCH_network.json``: mlp 0.40 s measured vs 0.0012 s predicted).
This module is the compiled tier that kills that tax:

  * **one jitted function per chain segment** — every kernel of the
    segment, with the canonical shape adapter (``netexec.adapt_tensor``)
    traced inline, inside a single ``jax.jit`` scope.  Forwarded tensors
    (``LayerScheme.forward_bytes``, the PR-4 on-chip forwarding
    machinery) are genuinely live values inside one executable, not jax
    arrays round-tripping through Python dispatch;
  * **a whole-``NetworkPlan`` jitted entry point** — the segment
    functions chained into one executable, external activations donatable
    (weights never donated: they are the resident state a serving node
    reuses across requests);
  * **a process-wide executable cache** keyed by the plan *signature*
    (shapes + kinds + blocking + buffer schedule — everything that
    determines the traced computation), so repeated executions of the
    same plan — autotune top-k re-ranking, ``SolveServer`` measured
    re-ranking, mesh task replay — pay tracing/compilation exactly once.

Each layer's compiled kernel computes the same in-block math as its
Pallas twin in ``exec.py`` (conv/pool keep the R/S window pinned
in-block as slice + einsum/max loops), so the fused path is an
independent implementation from the ``kernels/ref.py`` oracles it is
verified against.  What the compiled tier does *not* replay is the
solver's DRAM-level grid walk: XLA owns the loop schedule inside a fused
segment, which is exactly the point — the solver's inter-layer decisions
(segmentation, forwarding) shape the executable, the intra-layer nest is
the cost model's concern and stays measurable on the interpret oracle.
"""
from __future__ import annotations

import hashlib
import json
import threading
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.backend import resolve_backend  # noqa: F401  (re-export)
from ..obs import metrics, trace
from .netexec import (_check_executable, _eltwise_operands, adapt_tensor,
                      make_network_inputs, required_input_shape)
from .netplan import NetworkPlan
from .plan import KernelPlan

# -- telemetry (repro.obs) ---------------------------------------------------
_m_cache = metrics.counter(
    "fused_cache_events_total",
    "fused-executable cache events (hit / miss / eviction)", ("event",))
_m_size = metrics.gauge("fused_cache_size",
                        "fused executables resident in the process cache")
_m_compile = metrics.histogram(
    "fused_compile_seconds",
    "wall clock per fused-executable trace+compile")


# ---------------------------------------------------------------------------
# compiled per-layer kernels (pure jnp, traced into the segment executable)
# ---------------------------------------------------------------------------

def _fc(plan: KernelPlan, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def _conv(plan: KernelPlan, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    layer = plan.layer
    R, S = int(layer.meta["R"]), int(layer.meta["S"])
    stride = int(layer.meta["stride"])
    N, C = x.shape[0], x.shape[1]
    XO, YO = layer.dim("X"), layer.dim("Y")
    acc = jnp.zeros((N, layer.dim("K"), XO, YO), jnp.float32)
    for r in range(R):                       # R/S pinned in-block, exactly
        for s in range(S):                   # like the Pallas twin
            patch = jax.lax.slice(
                x, (0, 0, r, s),
                (N, C, r + (XO - 1) * stride + 1,
                 s + (YO - 1) * stride + 1),
                (1, 1, stride, stride))      # [N, C, XO, YO]
            acc += jnp.einsum("ncxy,kc->nkxy", patch, w[:, :, r, s],
                              preferred_element_type=jnp.float32)
    return acc


def _pool(plan: KernelPlan, x: jnp.ndarray) -> jnp.ndarray:
    layer = plan.layer
    R, S = int(layer.meta["R"]), int(layer.meta["S"])
    stride = int(layer.meta["stride"])
    N, C = x.shape[0], x.shape[1]
    XO, YO = layer.dim("X"), layer.dim("Y")
    acc = jnp.full((N, C, XO, YO), -jnp.inf, jnp.float32)
    for r in range(R):
        for s in range(S):
            patch = jax.lax.slice(
                x, (0, 0, r, s),
                (N, C, r + (XO - 1) * stride + 1,
                 s + (YO - 1) * stride + 1),
                (1, 1, stride, stride))
            acc = jnp.maximum(acc, patch)
    return acc


def _eltwise(plan: KernelPlan, xs) -> jnp.ndarray:
    acc = xs[0].astype(jnp.float32)
    for x in xs[1:]:
        acc = acc + x
    return acc


def _attention(plan: KernelPlan, q: jnp.ndarray, k: jnp.ndarray,
               v: jnp.ndarray) -> jnp.ndarray:
    scale = plan.layer.dim("K") ** -0.5
    s = jnp.einsum("nqd,nkd->nqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("nqk,nkd->nqd", p, v,
                      preferred_element_type=jnp.float32)


def compiled_plan_fn(plan: KernelPlan) -> Tuple[Callable, Tuple[str, ...]]:
    """(fn, input names) — the layer-tier compiled kernel for one plan,
    used by ``exec.plan_runner(backend="compiled")`` and the per-backend
    calibration sweep.  Unlike compiled Pallas, any DRAM loop order is
    executable (XLA owns the schedule), so no revisit-order guard."""
    if not plan.valid:
        raise ValueError(
            f"cannot execute invalid plan for layer {plan.layer.name!r}: "
            f"{plan.invalid_reason}")
    if plan.kind == "fc":
        return (lambda i, w: _fc(plan, i, w)), ("I", "W")
    if plan.kind == "conv":
        return (lambda i, w: _conv(plan, i, w)), ("I", "W")
    if plan.kind == "pool":
        return (lambda i: _pool(plan, i)), ("I",)
    if plan.kind == "eltwise":
        return (lambda a, b: _eltwise(plan, (a, b))), ("A", "B")
    if plan.kind == "attention":
        return (lambda q, k, v: _attention(plan, q, k, v)), ("Q", "K", "V")
    raise ValueError(f"unsupported kind {plan.kind!r}")


# ---------------------------------------------------------------------------
# the plan signature: cache key over everything that shapes the executable
# ---------------------------------------------------------------------------

def plan_signature(nplan: NetworkPlan) -> str:
    """Content hash of the traced computation: layer shapes/kinds/meta,
    graph wiring, segment slicing and the buffer schedule.  Two plans
    with equal signatures trace to identical executables, so re-lowering
    the same schedule (autotune iterations, store-served re-executions,
    mesh replays) hits the process cache instead of re-tracing."""
    doc: Dict = {"graph": nplan.graph_name, "layers": [], "segments": []}
    for name in nplan.order:
        plan = nplan.plans[name]
        layer = plan.layer
        doc["layers"].append({
            "name": name,
            "kind": plan.kind,
            "dims": sorted((d, int(v)) for d, v in layer.dims.items()),
            "meta": sorted((k, repr(v)) for k, v in layer.meta.items()),
            "src": [s for s in layer.src if s in nplan.plans],
            "block": sorted((d, int(v)) for d, v in plan.block.items()),
            "grid": [(ax.dim, ax.steps) for ax in plan.grid],
            "forwarded": nplan.placements[name].forwarded,
        })
    for seg in nplan.segments:
        doc["segments"].append([seg.start, seg.stop,
                                round(seg.granule_frac, 12)])
    blob = json.dumps(doc, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def input_specs(nplan: NetworkPlan) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract shapes of the plan's external feed (mirrors
    ``make_network_inputs``) — what the fused executable is traced for."""
    return {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
            for k, v in make_network_inputs(nplan, seed=0).items()}


# ---------------------------------------------------------------------------
# segment + network function builders
# ---------------------------------------------------------------------------

def _layer_out(nplan: NetworkPlan, name: str, vals: Dict,
               feed: Dict) -> jnp.ndarray:
    """One layer's output during tracing: sources from already-computed
    ``vals`` (in-graph), falling back to the external ``feed`` (the
    ``.I`` inputs — and, at segment granularity, boundary tensors from
    earlier segments), the canonical adapter inline — the traced mirror
    of ``netexec._layer_fn``."""
    plan = nplan.plans[name]
    layer = plan.layer
    srcs = [s for s in layer.src if s in nplan.plans]

    def src_val(s: str) -> jnp.ndarray:
        return vals[s] if s in vals else feed[s]

    shape = required_input_shape(layer)
    if plan.kind == "eltwise":
        ops = _eltwise_operands(
            [src_val(s) for s in srcs] if srcs else [feed[f"{name}.I"]],
            layer)
        return _eltwise(plan, ops)
    x = adapt_tensor(src_val(srcs[0]) if srcs else feed[f"{name}.I"], shape)
    if plan.kind == "fc":
        return _fc(plan, x, feed[f"{name}.W"])
    if plan.kind == "conv":
        return _conv(plan, x, feed[f"{name}.W"])
    if plan.kind == "pool":
        return _pool(plan, x)
    raise ValueError(f"cannot execute layer {name!r}: kind "
                     f"{plan.kind!r} has no network-exec input feed")


def _segment_io(nplan: NetworkPlan, seg) -> Tuple[Tuple[str, ...],
                                                  Tuple[str, ...]]:
    """(consumes, produces) boundary names of one segment: tensors read
    from outside the segment (boundary tensors, external ``.I`` feeds and
    ``.W`` weights) and tensors any later consumer — or the network
    output — needs."""
    inseg = set(seg.layer_names)
    consumes: List[str] = []
    for n in seg.layer_names:
        layer = nplan.plans[n].layer
        srcs = [s for s in layer.src if s in nplan.plans]
        if srcs:
            consumes += [s for s in srcs if s not in inseg]
        else:
            consumes.append(f"{n}.I")
        if layer.kind in ("fc", "conv"):
            consumes.append(f"{n}.W")
    produces = []
    for n in seg.layer_names:
        cons = nplan.placements[n].consumers
        if not cons or any(c not in inseg for c in cons):
            produces.append(n)
    return tuple(dict.fromkeys(consumes)), tuple(produces)


class FusedNetwork:
    """The compiled tier of one ``NetworkPlan``: lazily-built jitted
    executables at two granularities (whole net, single segment), every
    variant cached on this object — which the process-wide cache in turn
    keys by plan signature, so tracing happens once per plan content.

    ``traces`` counts actual jax retraces (a Python side effect at trace
    time): the zero-retrace guarantee the executable cache is tested on.
    """

    def __init__(self, nplan: NetworkPlan):
        _check_executable(nplan)             # errors name the layer
        self.nplan = nplan
        self.signature = plan_signature(nplan)
        self.traces = 0
        self._fns: Dict[Tuple, Callable] = {}
        self._lock = threading.Lock()
        self.segment_io = [_segment_io(nplan, seg)
                           for seg in nplan.segments]

    # -- builders -----------------------------------------------------------

    def _trace_marker(self) -> None:
        self.traces += 1                     # runs at trace time only

    def _build_network(self, keep: str, donate: bool) -> Callable:
        nplan = self.nplan
        if keep == "all":
            kept = list(nplan.order)
        else:                                # "boundary": serving outputs
            kept = [n for s in self.segment_io for n in s[1]]

        def fn(acts: Dict, weights: Dict) -> Dict:
            self._trace_marker()
            feed = {**acts, **weights}
            vals: Dict[str, jnp.ndarray] = {}
            for seg in nplan.segments:       # segments chained in order:
                for n in seg.layer_names:    # forwarded AND boundary
                    vals[n] = _layer_out(nplan, n, vals, feed)  # tensors
            return {n: vals[n] for n in kept}    # stay traced values

        return jax.jit(fn, donate_argnums=(0,) if donate else ())

    def _build_segment(self, index: int) -> Callable:
        nplan = self.nplan
        seg = nplan.segments[index]

        def fn(state: Dict) -> Dict:
            self._trace_marker()
            vals: Dict[str, jnp.ndarray] = {}
            for n in seg.layer_names:
                vals[n] = _layer_out(nplan, n, vals, state)
            return {n: vals[n] for n in self.segment_io[index][1]}

        return jax.jit(fn)

    def _fn(self, key: Tuple) -> Callable:
        with self._lock:
            fn = self._fns.get(key)
            if fn is None:
                fn = (self._build_segment(key[1]) if key[0] == "seg"
                      else self._build_network(key[1], key[2]))
                self._fns[key] = fn
        return fn

    def _timed(self, fn: Callable, *args):
        """Invoke a jitted variant; when the call traced (first execution
        for its shapes), record the compile span + histogram."""
        before = self.traces
        t0 = time.perf_counter()
        out = fn(*args)
        if self.traces > before:
            dt = time.perf_counter() - t0
            _m_compile.observe(dt)
            trace.instant("fuse.compile", net=self.nplan.graph_name,
                          signature=self.signature[:12],
                          seconds=round(dt, 6))
        return out

    # -- execution ----------------------------------------------------------

    def __call__(self, inputs: Dict, keep: str = "all",
                 donate: bool = False) -> Dict[str, jnp.ndarray]:
        """Run the whole plan as one executable.  ``keep="all"`` returns
        every layer output (verification); ``keep="boundary"`` returns
        only segment-boundary/network outputs (the serving path —
        forwarded tensors never materialize).  ``donate=True`` donates
        the external activation buffers (weights are never donated);
        donated inputs must not be reused by the caller."""
        if keep not in ("all", "boundary"):
            raise ValueError(f"keep must be 'all'|'boundary', got {keep!r}")
        acts = {k: v for k, v in inputs.items() if not k.endswith(".W")}
        weights = {k: v for k, v in inputs.items() if k.endswith(".W")}
        return self._timed(self._fn(("net", keep, donate)), acts, weights)

    def run_segment(self, index: int, state: Dict) -> Dict:
        """Run one fused segment executable over a boundary-state dict
        (must hold the segment's ``consumes`` names) — the mesh executor's
        per-task unit."""
        return self._timed(self._fn(("seg", index)), state)


# ---------------------------------------------------------------------------
# the process-wide executable cache
# ---------------------------------------------------------------------------

_CACHE: "OrderedDict[str, FusedNetwork]" = OrderedDict()
_CACHE_CAP = 32
_CACHE_LOCK = threading.Lock()
_cache_counts = {"hits": 0, "misses": 0, "evictions": 0}


def fused_runner(nplan: NetworkPlan, cache: bool = True) -> FusedNetwork:
    """The compiled tier's entry point: the ``FusedNetwork`` for this
    plan, served from the process-wide executable cache when an
    equal-signature plan was fused before (zero retrace on hit)."""
    if not cache:
        return FusedNetwork(nplan)
    sig = plan_signature(nplan)
    with _CACHE_LOCK:
        hit = _CACHE.get(sig)
        if hit is not None:
            _CACHE.move_to_end(sig)
            _cache_counts["hits"] += 1
            _m_cache.inc(event="hit")
            return hit
    # build outside the lock (tracing may be slow); losing a build race
    # just wastes one construction, never corrupts the cache
    fused = FusedNetwork(nplan)
    with _CACHE_LOCK:
        if sig in _CACHE:
            _CACHE.move_to_end(sig)
            return _CACHE[sig]
        _cache_counts["misses"] += 1
        _m_cache.inc(event="miss")
        _CACHE[sig] = fused
        while len(_CACHE) > _CACHE_CAP:
            _CACHE.popitem(last=False)
            _cache_counts["evictions"] += 1
            _m_cache.inc(event="eviction")
        _m_size.set(len(_CACHE))
    return fused


def cache_stats() -> Dict[str, int]:
    with _CACHE_LOCK:
        return {"size": len(_CACHE), **_cache_counts}


def clear_cache() -> None:
    with _CACHE_LOCK:
        _CACHE.clear()
        for k in _cache_counts:
            _cache_counts[k] = 0
        _m_size.set(0)


__all__ = ["FusedNetwork", "fused_runner", "plan_signature", "input_specs",
           "compiled_plan_fn", "cache_stats", "clear_cache",
           "resolve_backend"]
