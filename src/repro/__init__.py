"""repro: KAPLA dataflow representation + solver (the paper), and the
pod-scale JAX framework it drives (models, kernels, autoshard, runtime)."""

__version__ = "1.0.0"
