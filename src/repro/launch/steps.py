"""Step builders shared by train.py, serve.py and dryrun.py."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models.api import ModelAPI
from ..optim.optimizers import Optimizer, global_norm


def build_train_step(api: ModelAPI, optimizer: Optimizer):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(api.loss_fn)(params, batch)
        new_params, new_state = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": global_norm(grads)}
        return new_params, new_state, metrics
    return train_step


def build_serve_step(api: ModelAPI):
    def serve_step(params, cache, tokens, cache_len):
        logits, cache = api.decode_step(params, cache, tokens, cache_len)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache
    return serve_step


def build_prefill_step(api: ModelAPI, max_len: int):
    def prefill_step(params, inputs):
        return api.prefill(params, inputs, max_len)
    return prefill_step


def input_structs(cfg: ModelConfig, shape: ShapeConfig,
                  ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of one cell —
    weak-type-correct, shardable, no device allocation."""
    B, S = shape.global_batch, shape.seq_len
    if shape.mode == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "cache_len": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.frontend == "embed":
        inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    else:
        inputs = jax.ShapeDtypeStruct((B, S), jnp.int32)
    batch = {"inputs": inputs}
    if shape.mode == "train":
        batch["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return batch
