"""Production mesh construction.

Importing this module never touches jax device state; meshes are built
lazily inside the function (the dry-run sets XLA_FLAGS before any import).
"""
from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; the multi-pod mesh adds a leading 'pod' axis of
    2 (512 chips).  Uses the first `prod(shape)` available devices."""
    import math

    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devs)}; the dry-run must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    return jax.make_mesh(
        shape, axes, devices=devs[:n],
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(axes=("data", "model")):
    """A 1x1 mesh over the single local device (smoke tests)."""
    import jax
    return jax.make_mesh(
        (1,) * len(axes), axes, devices=jax.devices()[:1],
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
