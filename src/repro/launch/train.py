"""End-to-end training driver.

Small-scale (CPU, reduced config) it actually trains; at pod scale the same
code path lowers under the production mesh (dryrun.py proves compilation).

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --tiny \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import SHAPES, ShapeConfig, get_config
from ..configs.base import ModelConfig
from ..data.pipeline import DataConfig, Prefetcher, synth_batch
from ..checkpoint import ckpt
from ..models.api import build_model
from ..optim.optimizers import make_optimizer
from ..runtime.fault import (NodeFailure, RecoveryPolicy, StepHeartbeat,
                             run_with_recovery)
from ..runtime.straggler import StragglerDetector
from .steps import build_train_step


def tiny_config(cfg: ModelConfig) -> ModelConfig:
    over = dict(num_layers=2, d_model=128, d_ff=256, vocab_size=1024,
                head_dim=32)
    if cfg.num_heads:
        over.update(num_heads=4,
                    num_kv_heads=2 if cfg.num_kv_heads < cfg.num_heads
                    else 4)
    if cfg.family == "moe":
        over.update(num_experts=8, top_k=2, moe_d_ff=64,
                    num_shared_experts=min(1, cfg.num_shared_experts),
                    first_dense_layers=min(1, cfg.first_dense_layers))
    if cfg.family in ("ssm", "hybrid"):
        over.update(ssm_state=16, ssm_head_dim=32)
    if cfg.attn_every:
        over.update(attn_every=1)
    if cfg.local_window:
        over.update(local_window=32)
    return dataclasses.replace(cfg, **over)


def train(arch: str, steps: int = 50, batch: int = 8, seq: int = 128,
          tiny: bool = True, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 25, resume: bool = False,
          fail_at: Optional[int] = None, log_every: int = 10,
          seed: int = 0):
    cfg = get_config(arch)
    if tiny:
        cfg = tiny_config(cfg)
    shape = ShapeConfig(f"train_{seq}", seq, batch, "train")
    api = build_model(cfg)
    optimizer = make_optimizer(cfg.optimizer, lr=1e-3)

    key = jax.random.PRNGKey(seed)
    params = api.init(key)
    opt_state = optimizer.init(params)
    start_step = 0
    if resume and ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        params, opt_state, manifest = ckpt.restore(ckpt_dir, params,
                                                   opt_state)
        start_step = manifest["step"]
        print(f"resumed from step {start_step}")

    step_fn = jax.jit(build_train_step(api, optimizer), donate_argnums=(0, 1))
    prefetch = Prefetcher(cfg, shape, DataConfig(seed=seed),
                          start_step=start_step)
    detector = StragglerDetector()
    heartbeat = StepHeartbeat(deadline_seconds=300.0)
    losses = []

    state = {"params": params, "opt": opt_state, "failed_once": False}

    def restore_fn() -> int:
        if ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
            p, o, m = ckpt.restore(ckpt_dir, state["params"], state["opt"])
            state["params"], state["opt"] = p, o
            return m["step"]
        return start_step

    def one_step(step: int):
        if fail_at is not None and step == fail_at \
                and not state["failed_once"]:
            state["failed_once"] = True        # one-shot injection
            raise NodeFailure(f"injected failure at step {step}")
        t0 = time.perf_counter()
        heartbeat.arm()
        batch_np = synth_batch(cfg, shape, step, DataConfig(seed=seed))
        batch_dev = {k: jnp.asarray(v) for k, v in batch_np.items()}
        state["params"], state["opt"], metrics = step_fn(
            state["params"], state["opt"], batch_dev)
        heartbeat.disarm()
        loss = float(metrics["loss"])
        losses.append(loss)
        detector.record("host0", time.perf_counter() - t0)
        if step % log_every == 0 or step == start_step:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({time.perf_counter() - t0:.2f}s)", flush=True)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, state["params"], state["opt"],
                      extra={"loss": loss})

    stats = run_with_recovery(one_step, start_step, steps - start_step,
                              restore_fn,
                              policy=RecoveryPolicy(backoff_seconds=0.01),
                              sleep=lambda s: None)
    prefetch.close()
    print(f"done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f} "
          f"(restarts={stats.restarts})")
    return losses, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()
    train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
          tiny=args.tiny, ckpt_dir=args.ckpt_dir, resume=args.resume,
          fail_at=args.fail_at)


if __name__ == "__main__":
    main()
