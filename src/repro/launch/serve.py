"""Batched serving driver: continuous-batching-style loop with prefill +
decode on a shared KV cache pool.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --tiny \
      --requests 8 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models.api import build_model
from .steps import build_serve_step
from .train import tiny_config


def serve(arch: str, requests: int = 8, prompt_len: int = 32, gen: int = 16,
          tiny: bool = True, seed: int = 0):
    cfg = get_config(arch)
    if tiny:
        cfg = tiny_config(cfg)
    api = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = api.init(key)
    max_len = prompt_len + gen

    rng = np.random.default_rng(seed)
    prompts = rng.integers(1, min(cfg.vocab_size, 1000),
                           size=(requests, prompt_len)).astype(np.int32)

    # --- prefill (batched) ---------------------------------------------------
    t0 = time.perf_counter()
    if cfg.frontend == "embed":
        # audio/vlm stub: prompts arrive as precomputed embeddings
        emb = rng.standard_normal(
            (requests, prompt_len, cfg.d_model)).astype(np.float32) * 0.02
        logits, cache = jax.jit(
            lambda p, x: api.prefill(p, x, max_len))(params,
                                                     jnp.asarray(emb))
    else:
        logits, cache = jax.jit(
            lambda p, x: api.prefill(p, x, max_len))(params,
                                                     jnp.asarray(prompts))
    t_prefill = time.perf_counter() - t0

    # SSM/hybrid prefill returns fresh state; replay prompts through decode
    # to build it (cheap at these sizes; production would fuse this)
    serve_step = jax.jit(build_serve_step(api))
    if cfg.family in ("ssm", "hybrid"):
        for t in range(prompt_len):
            tok, cache = serve_step(params, cache,
                                    jnp.asarray(prompts[:, t: t + 1]),
                                    jnp.asarray(t))
        next_tok = tok
    else:
        next_tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]

    # --- decode loop ----------------------------------------------------------
    outs: List[np.ndarray] = [np.asarray(next_tok)]
    t0 = time.perf_counter()
    for i in range(gen - 1):
        next_tok, cache = serve_step(params, cache, next_tok,
                                     jnp.asarray(prompt_len + i))
        outs.append(np.asarray(next_tok))
    t_decode = time.perf_counter() - t0
    gen_tokens = np.concatenate(outs, axis=1)
    print(f"prefill: {requests} x {prompt_len} tok in {t_prefill:.2f}s; "
          f"decode: {requests} x {gen} tok in {t_decode:.2f}s "
          f"({requests * max(1, gen - 1) / max(t_decode, 1e-9):.1f} tok/s)")
    return gen_tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    serve(args.arch, args.requests, args.prompt_len, args.gen)


if __name__ == "__main__":
    main()
