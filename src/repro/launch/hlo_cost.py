"""While-loop-aware FLOP/byte counting over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, regardless
of trip count — useless for scan-over-layers models (a 48-layer stack reports
1-layer FLOPs).  This module parses the partitioned HLO module, builds the
computation call graph, extracts loop trip counts from the canonical scan
pattern (induction variable compared against a constant), and accumulates:

  * dot FLOPs: 2 x prod(result dims) x prod(contracting dims)
  * elementwise/fusion output elements (1 flop/elem, minor term)
  * bytes: operands + results of dots, fusions, and memory-moving ops

multiplied through nested while loops.  Used by the dry-run for roofline
terms; validated in tests against unrolled-vs-scanned small models.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128|"
    r"f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^(?:\([^)]*\)|[\w\[\],{}<>= ]+?)\s*([a-z][\w\-]*)\(")


def _dims(dim_str: str) -> List[int]:
    return [int(d) for d in dim_str.split(",")] if dim_str else []


def _first_shape(text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return m.group(1), _dims(m.group(2))


def _all_shapes(text: str):
    for dt, dims in _SHAPE_RE.findall(text):
        yield dt, _dims(dims)


def _numel(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _bytes(text: str) -> float:
    return sum(_numel(d) * _DTYPE_BYTES[t] for t, d in _all_shapes(text))


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=")
_PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*([^,()]+(?:\([^)]*\))?)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[str]] = {}
        # per-computation symbol table: var -> shape text (for byte/dim calc)
        self.symbols: Dict[str, Dict[str, str]] = {}
        self._parse(hlo_text)
        self._memo: Dict[str, CompCost] = {}

    # ---- parsing ---------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            stripped = line.strip()
            if not line.startswith((" ", "\t")) and ("->" in line) \
                    and line.rstrip().endswith("{") \
                    and not stripped.startswith("//"):
                m = _COMP_START.match(stripped.lstrip("%"))
                if m:
                    cur = m.group(1)
                    self.computations[cur] = []
                    self.symbols[cur] = {}
                    # parameter shapes live in the header
                    header = stripped[stripped.find("(") + 1:
                                      stripped.rfind("->")]
                    for pm in _PARAM_RE.finditer(header):
                        self.symbols[cur][pm.group(1)] = pm.group(2)
                    continue
            if cur is not None and stripped.startswith(("%", "ROOT")):
                self.computations[cur].append(stripped)
                dm = _DEF_RE.match(stripped)
                if dm and "=" in stripped:
                    rhs = stripped.split("=", 1)[1]
                    # result type = text before the opcode's '('
                    self.symbols[cur][dm.group(1)] = rhs.split("(", 1)[0]

    def _operand_bytes(self, comp: str, body: str) -> float:
        """Sum shape bytes of %operands referenced inside op parentheses."""
        if "(" not in body:
            return 0.0
        inner = body[body.find("(") + 1:]
        # cut trailing attribute list (after the matching close is hard;
        # attributes contain no %refs with shapes, so scanning all is fine)
        total = 0.0
        table = self.symbols.get(comp, {})
        for m in _OPERAND_RE.finditer(inner):
            t = table.get(m.group(1))
            if t:
                total += _bytes(t)
        return total

    def _operand_shape(self, comp: str, body: str, index: int):
        """Shape of the index-th %operand of an op."""
        inner = body[body.find("(") + 1:]
        refs = _OPERAND_RE.findall(inner.split("),", 1)[0].split("), ")[0])
        if index >= len(refs):
            refs = _OPERAND_RE.findall(inner)
        if index < len(refs):
            t = self.symbols.get(comp, {}).get(refs[index])
            if t:
                return _first_shape(t)
        return None

    def root_is_inplace_dus(self, name: str) -> bool:
        """True when the computation's root is a dynamic-update-slice (or a
        convert of one): XLA aliases the target buffer, so the fusion's real
        traffic is the updated slice, not the full result."""
        lines = self.computations.get(name, [])
        if not lines:
            return False
        root = lines[-1]
        for l in lines:
            if l.startswith("ROOT"):
                root = l
        body = root.split("=", 1)[-1]
        if "dynamic-update-slice(" in body:
            return True
        if " convert(" in body or body.strip().startswith("convert("):
            ref = _OPERAND_RE.search(body[body.find("("):])
            if ref:
                src = next((l for l in lines
                            if _DEF_RE.match(l)
                            and _DEF_RE.match(l).group(1) == ref.group(1)),
                           "")
                return "dynamic-update-slice(" in src
        return False

    def is_layout_fusion(self, name: str) -> bool:
        """A fusion containing only dtype/layout ops (convert, bitcast,
        copy, transpose, reshape, broadcast of scalars) — an XLA CPU
        bf16-emulation artifact with no TPU analogue."""
        layout_ops = ("convert(", "bitcast(", "copy(", "transpose(",
                      "reshape(", "parameter(", "constant(")
        lines = self.computations.get(name, [])
        if not lines:
            return False
        for l in lines:
            body = l.split("=", 1)[-1]
            if not any(op in body for op in layout_ops):
                return False
        return True

    def _uses_only_slicing(self, name: str, var: str, depth: int = 0,
                           ) -> bool:
        """All uses of ``var`` are slicing ops (allowing one level of
        convert/bitcast indirection, XLA CPU's in-place-DUS pattern)."""
        slice_ops = ("dynamic-slice(", "slice(", "gather(",
                     "dynamic-update-slice(", "get-tuple-element(",
                     "bitcast(")
        uses = [l for l in self.computations.get(name, [])
                if f"%{var}," in l.split("=", 1)[-1]
                or f"%{var})" in l.split("=", 1)[-1]]
        if not uses:
            return False
        for u in uses:
            body = u.split("=", 1)[-1]
            if any(op in body for op in slice_ops):
                continue
            if depth < 2 and (" convert(" in body or " copy(" in body
                              or " bitcast(" in body):
                dm = _DEF_RE.match(u)
                if dm and self._uses_only_slicing(name, dm.group(1),
                                                  depth + 1):
                    continue
            return False
        return True

    # ---- trip-count extraction -------------------------------------------
    def trip_count(self, cond_name: str) -> int:
        """Canonical scan pattern: compare(iter, constant(N)), LT."""
        best = 1
        for line in self.computations.get(cond_name, []):
            m = re.search(r"constant\((\d+)\)", line)
            if m:
                best = max(best, int(m.group(1)))
        return best

    # ---- per-op costs ------------------------------------------------------
    def _dot_flops(self, comp: str, line: str) -> float:
        body = line.split("=", 1)[1]
        res = _first_shape(body.split("(", 1)[0])
        if res is None:
            return 0.0
        _, res_dims = res
        lhs = self._operand_shape(comp, body, 0)
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
        contract = 1
        if lhs and cm and cm.group(1):
            _, lhs_dims = lhs
            for idx in _dims(cm.group(1)):
                if idx < len(lhs_dims):
                    contract *= lhs_dims[idx]
        return 2.0 * _numel(res_dims) * contract

    def comp_cost(self, name: str, fused: bool = False) -> CompCost:
        key = (name, fused)
        if key in self._memo:
            return self._memo[key]
        total = CompCost()
        self._memo[key] = total            # guard recursion
        slice_ops = ("dynamic-slice", "slice", "gather",
                     "dynamic-update-slice", "get-tuple-element", "bitcast")
        for line in self.computations.get(name, []):
            rhs = line.split("=", 1)
            if len(rhs) != 2:
                continue
            body = rhs[1].strip()
            opm = _OPCODE_RE.match(body)
            opcode = opm.group(1) if opm else ""
            if opcode == "dot":
                total.flops += self._dot_flops(name, line)
                total.bytes += _bytes(body.split("(", 1)[0]) \
                    + self._operand_bytes(name, body)
            elif opcode == "while":
                b = _BODY_RE.search(line)
                c = _COND_RE.search(line)
                if b:
                    tm = _TRIP_RE.search(line)   # XLA's own trip count
                    if tm:
                        trips = int(tm.group(1))
                    else:
                        trips = self.trip_count(c.group(1)) if c else 1
                    sub = self.comp_cost(b.group(1))
                    total.flops += sub.flops * trips
                    total.bytes += sub.bytes * trips
                    total.coll_bytes += sub.coll_bytes * trips
                    for k, v in sub.coll_by_kind.items():
                        total.coll_by_kind[k] = \
                            total.coll_by_kind.get(k, 0) + v * trips
            elif opcode in ("fusion", "call", "conditional", "map",
                            "async-start"):
                sub_flops = sub_bytes = 0.0
                for cm in _CALLS_RE.finditer(line):
                    sub = self.comp_cost(cm.group(1), fused=True)
                    sub_flops += sub.flops
                    sub_bytes += sub.bytes
                    total.coll_bytes += sub.coll_bytes
                    for k, v in sub.coll_by_kind.items():
                        total.coll_by_kind[k] = \
                            total.coll_by_kind.get(k, 0) + v
                total.flops += sub_flops
                # fused kernels: internal per-op accounting (slice rules
                # included) + one result write — except in-place DUS roots,
                # whose result aliases an input buffer (slice-only traffic)
                callee_names = [cm.group(1)
                                for cm in _CALLS_RE.finditer(line)]
                inplace = any(self.root_is_inplace_dus(cn)
                              for cn in callee_names)
                if not inplace and all(self.is_layout_fusion(cn)
                                       for cn in callee_names):
                    # pure convert/layout fusion: XLA CPU materializes an
                    # f32 copy because it has no native bf16 dot; a TPU
                    # consumes the bf16 operand directly.  Charge the
                    # narrow side once.
                    res_b = _bytes(body.split("(", 1)[0])
                    op_b = self._operand_bytes(name, body)
                    total.bytes += min(res_b, op_b if op_b else res_b)
                else:
                    total.bytes += sub_bytes + \
                        (0.0 if inplace else _bytes(body.split("(", 1)[0]))
            elif any(body.startswith(c) or f" {c}(" in body
                     for c in _COLLECTIVES):
                if "-done(" in body:
                    continue
                kind = next(c for c in _COLLECTIVES
                            if body.startswith(c) or f" {c}(" in body)
                res = line.split("=", 1)[0] + "=" + \
                    body.split("(", 1)[0]
                b = _bytes(res)
                total.coll_bytes += b
                total.coll_by_kind[kind] = \
                    total.coll_by_kind.get(kind, 0) + b
                total.bytes += b
            elif opcode in ("convolution",):
                # conv flops ~ 2 x out elems x (window x in-ch); approximate
                # via shapes: result x contracted window product
                res = _first_shape(body)
                if res:
                    total.flops += 2.0 * _numel(res[1])
                total.bytes += _bytes(line)
            elif opcode in ("get-tuple-element", "tuple", "bitcast",
                            "parameter", "constant", "after-all",
                            "partition-id", "replica-id", "custom-call",
                            "rng-bit-generator"):
                pass                                # no real data movement
            elif opcode in ("dynamic-slice", "slice", "gather"):
                # touches only the sliced window, not the source buffer
                total.bytes += 2.0 * _bytes(body.split("(", 1)[0])
            elif opcode in ("dynamic-update-slice", "scatter"):
                # in-place update: read+write of the update operand only
                upd = self._operand_shape(name, body, 1)
                if upd is not None:
                    total.bytes += 2.0 * _numel(upd[1]) \
                        * _DTYPE_BYTES.get(upd[0], 4)
                else:
                    total.bytes += _bytes(body.split("(", 1)[0])
            elif opcode in ("copy", "transpose", "reshape", "convert",
                            "broadcast", "iota", "pad", "reverse",
                            "concatenate"):
                if not fused:     # inside a fusion these are free streaming
                    total.bytes += 2.0 * _bytes(body.split("(", 1)[0])
            elif opcode in ("reduce", "sort", "reduce-window",
                            "exponential", "tanh", "add", "multiply",
                            "subtract", "divide", "maximum", "minimum",
                            "select", "compare", "rsqrt", "negate", "log",
                            "and", "or", "xor", "clamp", "power", "sign",
                            "floor", "ceil", "abs", "cosine", "sine",
                            "logistic", "sqrt", "atan2", "remainder",
                            "shift-left", "shift-right-logical",
                            "shift-right-arithmetic", "is-finite", "not",
                            "expm1", "log1p", "cbrt", "round-nearest-afz",
                            "round-nearest-even", "popcnt", "clz"):
                res = _first_shape(body.split("(", 1)[0])
                if res:
                    total.flops += _numel(res[1])   # ~1 flop/elem
                if not fused:
                    total.bytes += _bytes(body.split("(", 1)[0]) \
                        + self._operand_bytes(name, body)
        if fused:
            # a fused kernel streams each parameter once (params consumed
            # only through slicing ops are already counted by slice rules)
            body_text = "\n".join(self.computations.get(name, []))
            for line in self.computations.get(name, []):
                if "parameter(" not in line:
                    continue
                dm = _DEF_RE.match(line)
                if not dm:
                    continue
                pname = dm.group(1)
                if not self._uses_only_slicing(name, pname):
                    total.bytes += _bytes(line.split("=", 1)[1]
                                          .split("(", 1)[0])
        self._memo[key] = total
        return total

    def entry_cost(self) -> CompCost:
        # the ENTRY computation is the last one parsed with "ENTRY" in HLO;
        # we detect it as the computation no one calls
        called = set()
        for name, lines in self.computations.items():
            for line in lines:
                for m in _CALLS_RE.finditer(line):
                    called.add(m.group(1))
                for m in _BODY_RE.finditer(line):
                    called.add(m.group(1))
                for m in _COND_RE.finditer(line):
                    called.add(m.group(1))
        roots = [n for n in self.computations if n not in called]
        total = CompCost()
        # prefer an entry-like root (jit_* / main); else sum all roots
        mains = [n for n in roots if "main" in n or n.startswith("jit")]
        for n in (mains or roots):
            c = self.comp_cost(n)
            total.flops += c.flops
            total.bytes += c.bytes
            total.coll_bytes += c.coll_bytes
            for k, v in c.coll_by_kind.items():
                total.coll_by_kind[k] = total.coll_by_kind.get(k, 0) + v
        return total


def analyze_hlo(hlo_text: str) -> CompCost:
    return HloCostModel(hlo_text).entry_cost()
