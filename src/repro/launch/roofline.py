"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = per-device HLO FLOPs / peak_FLOP/s
memory term     = per-device HLO bytes accessed / HBM bandwidth
collective term = per-device collective operand bytes / (links x link bw)

``cost_analysis()`` on the partitioned module is already per-device; the
collective bytes come from parsing the compiled HLO text and summing operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (they are NOT in cost_analysis).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from ..hw.template import TPUPodSpec

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?:[a-z0-9_]+\[[^\]]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|"
                       r"u64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """Sum of result-shape bytes for every collective op in the (per-device)
    HLO module, by op kind."""
    per_kind: Dict[str, float] = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue                      # avoid double counting start/done
        kind = m.group(1)
        lhs = line.split("=", 1)[0] + "=" + \
            line.split("=", 1)[1].split("(", 1)[0]
        b = _shape_bytes(lhs)
        per_kind[kind] = per_kind.get(kind, 0.0) + b
        total += b
    return total, per_kind


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    coll_by_kind: Dict[str, float]
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float                  # 6*N*D (or 6*N_active*D)
    hlo_useful_ratio: float             # MODEL_FLOPS / (chips*HLO_FLOPs)
    bottleneck: str
    arg_bytes_per_device: float = 0.0
    temp_bytes_per_device: float = 0.0

    @property
    def step_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline the projected step achieves."""
        if self.step_time <= 0:
            return 0.0
        return self.t_compute / self.step_time

    def row(self) -> str:
        return (f"{self.arch:18s} {self.shape:12s} {self.mesh:10s} "
                f"{self.t_compute * 1e3:10.2f} {self.t_memory * 1e3:10.2f} "
                f"{self.t_collective * 1e3:10.2f} {self.bottleneck:10s} "
                f"{self.hlo_useful_ratio:8.3f} "
                f"{self.roofline_fraction * 100:7.1f}%")


HEADER = (f"{'arch':18s} {'shape':12s} {'mesh':10s} {'compute_ms':>10s} "
          f"{'memory_ms':>10s} {'coll_ms':>10s} {'bottleneck':10s} "
          f"{'useful':>8s} {'rl_frac':>8s}")


def analyze(arch: str, shape_name: str, mesh_name: str, chips: int,
            cost: Dict[str, float], hlo_text: str, model_flops: float,
            pod: TPUPodSpec = TPUPodSpec(),
            mem_stats=None, coll=None) -> RooflineReport:
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    if coll is not None:
        coll_dev, by_kind = coll      # while-aware counts from hlo_cost
    else:
        coll_dev, by_kind = collective_bytes(hlo_text)
    t_c = flops_dev / pod.peak_flops_bf16
    t_m = bytes_dev / pod.hbm_bw
    t_x = coll_dev / (pod.ici_link_bw * pod.ici_links_per_chip)
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / max(1.0, flops_dev * chips)
    rep = RooflineReport(arch, shape_name, mesh_name, flops_dev, bytes_dev,
                         coll_dev, by_kind, t_c, t_m, t_x, model_flops,
                         useful, bottleneck)
    if mem_stats is not None:
        rep.arg_bytes_per_device = getattr(mem_stats,
                                           "argument_size_in_bytes", 0)
        rep.temp_bytes_per_device = getattr(mem_stats,
                                            "temp_size_in_bytes", 0)
    return rep


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens
    processed; decode processes global_batch tokens; backward adds 2x."""
    n = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch
