import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape x
mesh) cell with ShapeDtypeStruct stand-ins (no allocation), print
memory_analysis / cost_analysis, and derive roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod        # 512 chips
  PYTHONPATH=src python -m repro.launch.dryrun --out results.json
"""

import argparse
import dataclasses
import json
import math
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs import SHAPES, get_config, list_archs, shape_applicable
from ..core.autoshard import plan_sharding
from ..models.api import build_model
from ..optim.optimizers import make_optimizer
from .hlo_cost import analyze_hlo
from .mesh import make_production_mesh
from .roofline import HEADER, analyze, model_flops_for
from .steps import (build_prefill_step, build_serve_step, build_train_step,
                    input_structs)


def _shardings(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               verbose: bool = True, kv_int8: bool = False,
               ) -> Dict[str, Any]:
    """Lower + compile one (arch x shape x mesh) cell; return its record."""
    cfg = get_config(arch)
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    shape = SHAPES[shape_name]
    if shape.mode == "train" and cfg.remat == "none":
        # activation checkpointing is mandatory at these batch x depth
        # scales (non-remat residuals exceed HBM; see DESIGN.md)
        cfg = dataclasses.replace(cfg, remat="block")
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = math.prod(mesh.devices.shape)
    t0 = time.perf_counter()
    api = build_model(cfg, mesh=mesh)

    key = jax.random.PRNGKey(0)
    param_sds = jax.eval_shape(api.init, key)

    record: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                              "mesh": mesh_name, "mode": shape.mode}
    with mesh:
        if shape.mode == "train":
            optimizer = make_optimizer(cfg.optimizer)
            opt_sds = jax.eval_shape(optimizer.init, param_sds)
            plan = plan_sharding(cfg, shape, mesh, param_sds, opt_sds)
            batch_sds = input_structs(cfg, shape)
            step = build_train_step(api, optimizer)
            jstep = jax.jit(
                step,
                in_shardings=(_shardings(mesh, plan.param_specs),
                              _shardings(mesh, plan.opt_specs),
                              _shardings(mesh, plan.batch_specs)),
                out_shardings=(_shardings(mesh, plan.param_specs),
                               _shardings(mesh, plan.opt_specs), None),
                donate_argnums=(0, 1))
            lowered = jstep.lower(param_sds, opt_sds, batch_sds)
        elif shape.mode == "prefill":
            opt_sds = jax.tree_util.tree_map(lambda x: x, {})
            cache_sds = jax.eval_shape(
                lambda: api.init_cache(shape.global_batch, shape.seq_len))
            plan = plan_sharding(cfg, shape, mesh, param_sds, {},
                                 cache_shapes=cache_sds)
            batch_sds = input_structs(cfg, shape)
            step = build_prefill_step(api, shape.seq_len)
            jstep = jax.jit(
                step,
                in_shardings=(_shardings(mesh, plan.param_specs),
                              _shardings(mesh,
                                         plan.batch_specs["inputs"])),
                out_shardings=(None, _shardings(mesh, plan.cache_specs)))
            lowered = jstep.lower(param_sds, batch_sds["inputs"])
        else:                                  # decode
            cache_sds = jax.eval_shape(
                lambda: api.init_cache(shape.global_batch, shape.seq_len))
            plan = plan_sharding(cfg, shape, mesh, param_sds, {},
                                 cache_shapes=cache_sds)
            ins = input_structs(cfg, shape)
            step = build_serve_step(api)
            jstep = jax.jit(
                step,
                in_shardings=(_shardings(mesh, plan.param_specs),
                              _shardings(mesh, plan.cache_specs), None,
                              None),
                out_shardings=(None, _shardings(mesh, plan.cache_specs)),
                donate_argnums=(1,))
            lowered = jstep.lower(param_sds, cache_sds, ins["tokens"],
                                  ins["cache_len"])

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # while-loop-aware FLOP/byte/collective accounting (XLA's own
    # cost_analysis counts scan bodies once — see hlo_cost.py)
    hc = analyze_hlo(hlo)
    cost = {"flops": hc.flops, "bytes accessed": hc.bytes}
    rep = analyze(arch, shape_name, mesh_name, chips, cost, hlo,
                  model_flops_for(cfg, shape), mem_stats=mem,
                  coll=(hc.coll_bytes, hc.coll_by_kind))
    record["xla_cost_analysis"] = {
        "flops_scan_body_once": xla_cost.get("flops"),
        "bytes_scan_body_once": xla_cost.get("bytes accessed")}
    record.update({
        "status": "ok",
        "compile_seconds": round(time.perf_counter() - t0, 1),
        "plan": {"zero": plan.zero_opt, "attn_sharded": plan.attn_sharded,
                 "hbm_gb": round(plan.hbm_gb_per_chip, 2),
                 "notes": plan.notes},
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        },
        "roofline": {
            "flops_per_device": rep.flops_per_device,
            "bytes_per_device": rep.bytes_per_device,
            "collective_bytes_per_device": rep.collective_bytes_per_device,
            "coll_by_kind": rep.coll_by_kind,
            "t_compute": rep.t_compute,
            "t_memory": rep.t_memory,
            "t_collective": rep.t_collective,
            "bottleneck": rep.bottleneck,
            "model_flops": rep.model_flops,
            "useful_ratio": rep.hlo_useful_ratio,
            "roofline_fraction": rep.roofline_fraction,
        },
    })
    if verbose:
        print(f"  memory_analysis: args="
              f"{record['memory']['argument_bytes'] / 2**30:.2f}GiB "
              f"temp={record['memory']['temp_bytes'] / 2**30:.2f}GiB "
              f"per device")
        print(f"  cost_analysis: flops/dev={rep.flops_per_device:.3e} "
              f"bytes/dev={rep.bytes_per_device:.3e} "
              f"coll/dev={rep.collective_bytes_per_device:.3e}")
        print("  " + rep.row())
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 (512-chip) mesh")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run single-pod AND multi-pod")
    ap.add_argument("--out", default=None, help="write JSON records here")
    ap.add_argument("--kv-int8", action="store_true",
                    help="quantized int8 decode KV cache (perf variant)")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    failures = 0
    print(HEADER)
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                print(f"== {tag}", flush=True)
                try:
                    rec = lower_cell(arch, shape, multi_pod=mp,
                                     kv_int8=args.kv_int8)
                except Exception as e:  # a failure here is a sharding bug
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "FAILED", "error": repr(e)}
                    failures += 1
                if rec.get("status") == "skipped":
                    print(f"  skipped: {rec['reason']}")
                records.append(rec)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.out}")
    print(f"{len(records)} cells, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
