"""Mamba2-1.3B [arXiv:2405.21060; unverified] — attention-free SSD."""
from .base import ModelConfig
from .registry import register


@register
def mamba2_1_3b() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b", family="ssm",
        num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280, head_dim=64,
        ssm_state=128, ssm_head_dim=64, subquadratic=True)
