"""MusicGen-large [arXiv:2306.05284; hf] — decoder-only over EnCodec tokens.

Backbone only per the assignment: the EnCodec frontend is a stub;
``input_specs`` supplies precomputed frame embeddings."""
from .base import ModelConfig
from .registry import register


@register
def musicgen_large() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="dense",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=2048, head_dim=64, frontend="embed")
