"""Kimi-K2-1T-A32B [arXiv:2501.kimi2; unverified, paper-table] — trillion-
parameter MoE: 384 routed experts top-8 (+1 shared), first layer dense.

AdamW optimizer state (16 B/param) cannot fit 512 x 16 GB HBM for 1e12
params; the config selects the factored Adafactor optimizer and block remat
so the per-chip HBM validity check passes (see autoshard)."""
from .base import ModelConfig
from .registry import register


@register
def kimi_k2_1t_a32b() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
        d_ff=18432, vocab_size=163840, head_dim=128,
        num_experts=384, num_shared_experts=1, top_k=8, moe_d_ff=2048,
        first_dense_layers=1, optimizer="adafactor", remat="block",
        seq_shard=True)
