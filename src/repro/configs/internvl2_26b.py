"""InternVL2-26B [arXiv:2404.16821; hf] — InternViT + InternLM2 backbone.

Backbone only per the assignment: the ViT frontend is a stub;
``input_specs`` supplies precomputed patch embeddings."""
from .base import ModelConfig
from .registry import register


@register
def internvl2_26b() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", family="dense",
        num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=16384, vocab_size=92553, head_dim=128, frontend="embed")
