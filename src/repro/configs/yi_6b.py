"""Yi-6B [arXiv:2403.04652; hf] — llama-arch GQA."""
from .base import ModelConfig
from .registry import register


@register
def yi_6b() -> ModelConfig:
    return ModelConfig(
        name="yi-6b", family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=4,
        d_ff=11008, vocab_size=64000, head_dim=128)
