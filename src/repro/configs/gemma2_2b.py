"""Gemma2-2B [arXiv:2408.00118; hf] — local+global alternating attention,
attention & final logit softcapping, GQA kv=4, head_dim=256."""
from .base import ModelConfig
from .registry import register


@register
def gemma2_2b() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b", family="dense",
        num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4,
        d_ff=9216, vocab_size=256000, head_dim=256,
        local_window=4096, attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        notes="even layers local (sliding window 4096), odd layers global")
