from .base import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from .registry import get_config, list_archs

__all__ = ["SHAPES", "ModelConfig", "ShapeConfig", "get_config",
           "list_archs", "shape_applicable"]
