"""Zamba2-1.2B [arXiv:2411.15242; hf] — Mamba2 blocks + one *shared*
attention block invoked every 6 blocks (one weight copy, many consumers —
the paper's buffer-sharing analogue)."""
from .base import ModelConfig
from .registry import register


@register
def zamba2_1_2b() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32000, head_dim=64,
        ssm_state=64, ssm_head_dim=64, attn_every=6, subquadratic=True)
