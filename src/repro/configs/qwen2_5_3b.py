"""Qwen2.5-3B [hf:Qwen/Qwen2.5; hf] — GQA kv=2, QKV bias."""
from .base import ModelConfig
from .registry import register


@register
def qwen2_5_3b() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b", family="dense",
        num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
        d_ff=11008, vocab_size=151936, head_dim=128, qkv_bias=True)
