"""Registry of the 10 assigned architectures (exact configs from the
assignment, sources noted inline) — selectable via ``--arch <id>``."""
from __future__ import annotations

from typing import Callable, Dict

from .base import ModelConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
    cfg = fn()
    _REGISTRY[cfg.name] = fn
    return fn


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs():
    return sorted(_REGISTRY)


# --- import all arch modules so they self-register --------------------------
from . import (gemma2_2b, internlm2_20b, internvl2_26b, kimi_k2,        # noqa
               mamba2_1_3b, musicgen_large, qwen2_5_3b, qwen2_moe_a2_7b,
               yi_6b, zamba2_1_2b)
