"""Model/run configuration for the pod-scale JAX framework (Half B)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # gemma2-style options
    local_window: int = 0          # >0: alternate local/global attention
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    qkv_bias: bool = False
    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    # hybrid (Zamba2): one *shared* attention block every `attn_every`
    # Mamba blocks (the paper's buffer-sharing analogue: one weight copy,
    # many consumers)
    attn_every: int = 0
    # modality frontend: 'token' = token ids; 'embed' = precomputed
    # frame/patch embeddings (audio/vlm stub frontends per the assignment)
    frontend: str = "token"
    # substrate choices
    optimizer: str = "adamw"       # adamw | adafactor
    remat: str = "none"            # none | block  (activation checkpointing)
    seq_shard: bool = False        # sequence-parallel residuals over 'model'
    kv_cache_dtype: str = "bf16"   # bf16 | int8 (quantized decode cache)
    # applicability flags
    subquadratic: bool = False     # can run long_500k
    notes: str = ""

    def __post_init__(self) -> None:
        # pad vocab for clean model-axis sharding (multiple of 256)
        object.__setattr__(self, "padded_vocab", pad_to(self.vocab_size, 256))

    @property
    def q_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    def param_count(self) -> float:
        """Analytic parameter count (embeddings included once)."""
        d, L = self.d_model, self.num_layers
        hd, H, KV = self.head_dim, self.num_heads, self.num_kv_heads
        n = self.padded_vocab * d * 2          # embed + lm_head
        attn = d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d
        dense_ffn = 3 * d * self.d_ff
        if self.family == "dense":
            n += L * (attn + dense_ffn)
        elif self.family == "moe":
            routed = 3 * d * self.moe_d_ff * self.num_experts
            shared = 3 * d * self.moe_d_ff * self.num_shared_experts
            router = d * self.num_experts
            n += self.first_dense_layers * (attn + dense_ffn)
            n += (L - self.first_dense_layers) * (attn + routed + shared +
                                                  router)
        elif self.family == "ssm":
            di = self.ssm_expand * d
            mamba = d * (2 * di + 2 * self.ssm_state + self.ssm_heads) \
                + di * d
            n += L * mamba
        elif self.family == "hybrid":
            di = self.ssm_expand * d
            mamba = d * (2 * di + 2 * self.ssm_state + self.ssm_heads) \
                + di * d
            n += L * mamba + (attn + dense_ffn)   # one shared block
        return float(n)

    def active_param_count(self) -> float:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        hd, H, KV = self.head_dim, self.num_heads, self.num_kv_heads
        n = self.padded_vocab * d * 2
        attn = d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d
        n += self.first_dense_layers * (attn + 3 * d * self.d_ff)
        act = 3 * d * self.moe_d_ff * (self.top_k + self.num_shared_experts)
        n += (L - self.first_dense_layers) * (attn + act +
                                              d * self.num_experts)
        return float(n)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k requires sub-quadratic attention (SSM/hybrid only here;
    gemma2's alternating stack still contains global full-attention layers)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k skipped: pure full-attention architecture"
    return True, ""
