"""Public kernel API: jit'd wrappers that dispatch to Pallas TPU kernels on
TPU backends and to memory-efficient pure-jnp implementations elsewhere
(CPU dry-run / tests).  Both paths are validated against ``ref.py``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .backend import on_tpu, resolve_impl
from .flash_attention import flash_attention
from .ssd_scan import ssd_intra_chunk

# kept as an alias: external callers probed this before the shared
# backend-selection helper (kernels.backend) became the source of truth
_on_tpu = on_tpu


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _chunked_attention_jnp(q, k, v, causal, window, logit_softcap, scale,
                           block_k: int = 512, return_lse: bool = False):
    """Online-softmax attention in pure jnp (lax.scan over KV blocks): the
    S x S score matrix never materializes, so compiled HBM bytes match the
    flash kernel's — keeping CPU dry-run rooflines honest."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    qpk = H // KV
    scale = scale if scale is not None else D ** -0.5
    block_k = min(block_k, Sk)
    nk = Sk // block_k
    qf = q.astype(jnp.float32) * scale
    q_offset = Sk - Sq
    qpos = jnp.arange(Sq) + q_offset

    kb = k.reshape(B, KV, nk, block_k, D)
    vb = v.reshape(B, KV, nk, block_k, D)

    def step(carry, ik):
        acc, m, l = carry
        kc = jnp.repeat(kb[:, :, ik], qpk, axis=1).astype(jnp.float32)
        vc = jnp.repeat(vb[:, :, ik], qpk, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kc)
        if logit_softcap > 0:
            s = jnp.tanh(s / logit_softcap) * logit_softcap
        kpos = ik * block_k + jnp.arange(block_k)
        mask = jnp.ones((Sq, block_k), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(mask[None, None], jnp.exp(s - m_new[..., None]), 0.0)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vc)
        l = l * alpha + jnp.sum(p, axis=-1)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    m0 = jnp.full((B, H, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), jnp.arange(nk))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    if return_lse:
        return out, m + jnp.log(jnp.maximum(l, 1e-30))
    return out


# ---------------------------------------------------------------------------
# Flash attention with a custom VJP: the backward pass RECOMPUTES the chunk
# probabilities from (q, k, v, lse) instead of letting autodiff save every
# per-chunk intermediate of the forward scan.  Residual memory drops from
# O(S^2 / block) stacked tensors to O(S x D) — the single biggest memory-term
# lever in the train-cell roofline (§Perf iteration 3).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_flash_vjp(causal: bool, window: int, logit_softcap: float,
                    scale_key: float, block_k: int):
    scale = scale_key

    def fwd_only(q, k, v):
        return _chunked_attention_jnp(q, k, v, causal, window,
                                      logit_softcap, scale, block_k)

    @jax.custom_vjp
    def attn(q, k, v):
        return fwd_only(q, k, v)

    def attn_fwd(q, k, v):
        out, lse = _chunked_attention_jnp(q, k, v, causal, window,
                                          logit_softcap, scale, block_k,
                                          return_lse=True)
        return out, (q, k, v, out, lse)

    def attn_bwd(res, do):
        q, k, v, o, lse = res
        B, H, Sq, D = q.shape
        KV, Sk = k.shape[1], k.shape[2]
        qpk = H // KV
        bk = min(block_k, Sk)
        nk = Sk // bk
        qf = q.astype(jnp.float32)
        dof = do.astype(jnp.float32)
        delta = jnp.sum(dof * o.astype(jnp.float32), axis=-1)   # [B,H,Sq]
        q_offset = Sk - Sq
        qpos = jnp.arange(Sq) + q_offset
        kb = k.reshape(B, KV, nk, bk, D)
        vb = v.reshape(B, KV, nk, bk, D)

        def chunk(dq, ik):
            kc = jnp.repeat(kb[:, :, ik], qpk, axis=1).astype(jnp.float32)
            vc = jnp.repeat(vb[:, :, ik], qpk, axis=1).astype(jnp.float32)
            s_raw = jnp.einsum("bhqd,bhkd->bhqk", qf, kc) * scale
            if logit_softcap > 0:
                t = jnp.tanh(s_raw / logit_softcap)
                s = t * logit_softcap
            else:
                s = s_raw
            kpos = ik * bk + jnp.arange(bk)
            mask = jnp.ones((Sq, bk), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window > 0:
                mask &= kpos[None, :] > qpos[:, None] - window
            p = jnp.where(mask[None, None],
                          jnp.exp(s - lse[..., None]), 0.0)     # [B,H,q,bk]
            dv_c = jnp.einsum("bhqk,bhqd->bhkd", p, dof)
            dp = jnp.einsum("bhqd,bhkd->bhqk", dof, vc)
            ds = p * (dp - delta[..., None])
            if logit_softcap > 0:
                ds = ds * (1.0 - jnp.square(t))
            ds = ds * scale
            dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds, kc)
            dk_c = jnp.einsum("bhqk,bhqd->bhkd", ds, qf)
            # GQA: fold q-head groups back onto shared KV heads
            dk_c = dk_c.reshape(B, KV, qpk, bk, D).sum(axis=2)
            dv_c = dv_c.reshape(B, KV, qpk, bk, D).sum(axis=2)
            return dq, (dk_c, dv_c)

        dq0 = jnp.zeros((B, H, Sq, D), jnp.float32)
        dq, (dk_chunks, dv_chunks) = jax.lax.scan(chunk, dq0,
                                                  jnp.arange(nk))
        dk = jnp.moveaxis(dk_chunks, 0, 2).reshape(B, KV, Sk, D)
        dv = jnp.moveaxis(dv_chunks, 0, 2).reshape(B, KV, Sk, D)
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))

    attn.defvjp(attn_fwd, attn_bwd)
    return attn


def flash_attention_vjp(q, k, v, causal=True, window=0, logit_softcap=0.0,
                        scale=None, block_k: int = 512):
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    fn = _make_flash_vjp(bool(causal), int(window), float(logit_softcap),
                         float(scale), int(block_k))
    return fn(q, k, v)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, window: int = 0,
              logit_softcap: float = 0.0, scale: Optional[float] = None,
              impl: str = "auto") -> jnp.ndarray:
    """Multi-head GQA attention.  q: [B,H,S,D]; k,v: [B,KV,S,D]."""
    impl = resolve_impl(impl)
    if impl == "pallas":
        return flash_attention(q, k, v, causal=causal, window=window,
                               logit_softcap=logit_softcap, scale=scale)
    if impl == "pallas_interpret":
        return flash_attention(q, k, v, causal=causal, window=window,
                               logit_softcap=logit_softcap, scale=scale,
                               block_q=min(128, q.shape[2]),
                               block_k=min(128, k.shape[2]), interpret=True)
    if impl == "ref":
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 logit_softcap=logit_softcap, scale=scale)
    return flash_attention_vjp(q, k, v, causal=causal, window=window,
                               logit_softcap=logit_softcap, scale=scale)


def quantize_kv(x: jnp.ndarray):
    """Per-(batch, head, position) symmetric int8 quantization of a KV
    entry [..., D] -> (int8 payload, f32 scale[..., 1]).  Halves the decode
    cache HBM stream and footprint vs bf16 (§Perf, Cell A iteration 4)."""
    xf = x.astype(jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1, keepdims=True),
                    1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / s), -127, 127).astype(jnp.int8)
    return q, s


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, cache_len: jnp.ndarray,
                     window: int = 0, logit_softcap: float = 0.0,
                     scale: Optional[float] = None,
                     k_scale: Optional[jnp.ndarray] = None,
                     v_scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Single-token decode vs. a KV cache.

    q: [B, H, 1, D]; caches: [B, KV, Smax, D]; cache_len: [] current length
    (the new token's K/V must already be written at cache_len - 1).
    With k_scale/v_scale the caches are int8 payloads dequantized on the
    fly (per-position scales [B, KV, Smax, 1])."""
    B, H, _, D = q.shape
    KV, Smax = k_cache.shape[1], k_cache.shape[2]
    qpk = H // KV
    scale = scale if scale is not None else D ** -0.5
    # GQA-aware: fold the q-head groups into a batched einsum against the
    # *unreplicated* cache — the cache (the dominant HBM stream in decode)
    # is read once, not q_per_kv times, and stays bf16 on the wire with f32
    # accumulation (preferred_element_type).
    # explicit per-layer-slice f32 casts: XLA CPU has no native bf16 dot and
    # would otherwise hoist an f32 copy of the WHOLE cache into the scan
    # carry (2x cache HBM); casting the slice keeps the conversion local
    # (free on TPU where the MXU consumes bf16 directly)
    qg = (q.astype(jnp.float32) * scale).reshape(B, KV, qpk, D)
    kf = k_cache.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale.astype(jnp.float32)
    s = jnp.einsum("bgqd,bgkd->bgqk", qg, kf)
    if logit_softcap > 0:
        s = jnp.tanh(s / logit_softcap) * logit_softcap
    kpos = jnp.arange(Smax)
    mask = kpos[None, None, None, :] < cache_len
    win = jnp.asarray(window)          # may be traced (per-layer windows)
    mask &= jnp.where(win > 0, kpos[None, None, None, :] >= cache_len - win,
                      True)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    vf = v_cache.astype(jnp.float32)
    if v_scale is not None:
        vf = vf * v_scale.astype(jnp.float32)
    o = jnp.einsum("bgqk,bgkd->bgqd", p, vf)
    return o.reshape(B, H, 1, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# SSD (Mamba2)
# ---------------------------------------------------------------------------

def ssd(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
        b: jnp.ndarray, c: jnp.ndarray, chunk: int = 128,
        impl: str = "auto"):
    """Chunked SSD forward.

    x: [B,S,H,P]; dt: [B,S,H] (positive); a_log: [H]; b,c: [B,S,N] (G=1).
    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    NC = S // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))                   # [H]
    dtf = dt.astype(jnp.float32)
    ad = dtf * a[None, None, :]                               # [B,S,H]

    # chunked layouts
    x_c = x.reshape(B, NC, chunk, H, P)
    dt_c = dtf.reshape(B, NC, chunk, H)
    ad_c = ad.reshape(B, NC, chunk, H)
    b_c = b.reshape(B, NC, chunk, N).astype(jnp.float32)
    c_c = c.reshape(B, NC, chunk, N).astype(jnp.float32)
    acum = jnp.cumsum(ad_c, axis=2)                           # [B,NC,Lc,H]
    a_end = acum[:, :, -1]                                    # [B,NC,H]

    # per-chunk state contributions: sum_j exp(a_end - acum_j) dt_j x_j b_j^T
    w = jnp.exp(a_end[:, :, None] - acum) * dt_c              # [B,NC,Lc,H]
    states = jnp.einsum("bclh,bclhp,bcln->bchpn",
                        w, x_c.astype(jnp.float32), b_c)      # [B,NC,H,P,N]

    # inter-chunk recurrence (sequential over NC, cheap)
    def step(h, inp):
        s_prev, dec = inp
        h = h * dec[..., None, None] + s_prev
        return h, h

    decay_chunk = jnp.exp(a_end)                              # [B,NC,H]
    s_shift = jnp.concatenate(
        [jnp.zeros_like(states[:, :1]), states[:, :-1]], axis=1)
    _, h0 = jax.lax.scan(
        step, jnp.zeros((B, H, P, N), jnp.float32),
        (jnp.moveaxis(s_shift, 1, 0), jnp.moveaxis(decay_chunk, 1, 0)))
    h0 = jnp.moveaxis(h0, 0, 1)                               # [B,NC,H,P,N]
    final_state = h0[:, -1] * decay_chunk[:, -1][..., None, None] \
        + states[:, -1]

    # inter-chunk output term
    y_inter = jnp.einsum("bcln,bchpn,bclh->bclhp",
                         c_c, h0, jnp.exp(acum))

    # intra-chunk quadratic term: Pallas kernel on TPU, jnp otherwise
    impl = resolve_impl(impl)
    if impl in ("pallas", "pallas_interpret"):
        xk = jnp.moveaxis(x_c, 3, 1)                          # [B,H,NC,Lc,P]
        dtk = jnp.moveaxis(dt_c, 3, 1)
        acumk = jnp.moveaxis(acum, 3, 1)
        y_intra = ssd_intra_chunk(xk, dtk, acumk, b_c, c_c,
                                  interpret=impl == "pallas_interpret")
        y_intra = jnp.moveaxis(y_intra, 1, 3)                 # [B,NC,Lc,H,P]
    else:
        li = jnp.arange(chunk)
        tri = li[:, None] >= li[None, :]
        scores = jnp.einsum("bcln,bcmn->bclm", c_c, b_c)
        decay = jnp.exp(acum[:, :, :, None, :] - acum[:, :, None, :, :])
        scores = scores[..., None] * decay * dt_c[:, :, None]  # [B,NC,l,m,H]
        scores = jnp.where(tri[None, None, :, :, None], scores, 0.0)
        y_intra = jnp.einsum("bclmh,bcmhp->bclhp", scores,
                             x_c.astype(jnp.float32))

    y = (y_inter + y_intra).reshape(B, S, H, P).astype(x.dtype)
    return y, final_state


ssd_decode = ref.ssd_decode_ref
