"""Flash attention Pallas TPU kernel (blocked online-softmax).

TPU adaptation of the FlashAttention insight: tile Q into VMEM-resident
blocks, stream K/V blocks through VMEM, and keep running (max, sum, acc)
statistics in VMEM scratch so the S x S score matrix never materializes in
HBM.  Block shapes are MXU-aligned (multiples of 128 in the contracting and
lane dims).  Supports GQA (q-head groups share a KV head), causal masking,
sliding-window (local) attention, and logit soft-capping (Gemma2).

Validated against ``ref.attention_ref`` with ``interpret=True`` on CPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_k: int, seq_k: int, causal: bool,
                  window: int, logit_softcap: float, scale: float,
                  q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)            # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)            # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if logit_softcap > 0:
        s = jnp.tanh(s / logit_softcap) * logit_softcap

    # positions: queries may be right-aligned into a longer KV (decode)
    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0) \
        + q_offset
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    m_ref[...] = m_cur
    acc_ref[...] = acc_ref[...] * alpha[:, None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / denom[:, None]) \
            .astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "logit_softcap", "block_q",
                     "block_k", "interpret", "scale"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = 0,
                    logit_softcap: float = 0.0,
                    scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: [B, H, Sq, D]; k, v: [B, KV, Sk, D]; H % KV == 0."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    qpk = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    scale = scale if scale is not None else D ** -0.5
    grid = (B, H, Sq // block_q, Sk // block_k)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_k=Sk,
        causal=causal, window=window, logit_softcap=logit_softcap,
        scale=scale, q_offset=Sk - Sq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, qpk_=qpk: (b, h // qpk_, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, qpk_=qpk: (b, h // qpk_, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),    # running accumulator
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running sum
        ],
        interpret=interpret,
    )(q, k, v)
