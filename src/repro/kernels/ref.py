"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the ground truth for the interpret-mode kernel tests and the
small-shape CPU fallbacks.  Naive O(S^2) attention / O(S) sequential SSM —
clarity over efficiency.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return jnp.tanh(x / cap) * cap if cap > 0 else x


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """FC-layer oracle: x [N, C] @ w [C, K] -> [N, K] (f32 accumulation)."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                   precision=jax.lax.Precision.HIGHEST).astype(x.dtype)


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """Conv-layer oracle: x [N, C, XI, YI], w [K, C, R, S] -> [N, K, XO, YO]
    with VALID padding (the solver's layer specs bake the halo into the
    input extent, so no implicit padding exists)."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        precision=jax.lax.Precision.HIGHEST).astype(x.dtype)


def pool2d_ref(x: jnp.ndarray, r: int, s: int, stride: int = 2) -> jnp.ndarray:
    """Max-pool oracle: x [N, C, XI, YI] -> [N, C, XO, YO], VALID padding
    (pool layer specs bake the window extent into the input, like conv)."""
    return jax.lax.reduce_window(
        x.astype(jnp.float32), -jnp.inf, jax.lax.max,
        window_dimensions=(1, 1, r, s),
        window_strides=(1, 1, stride, stride),
        padding="VALID").astype(x.dtype)


def eltwise_ref(*xs: jnp.ndarray) -> jnp.ndarray:
    """N-ary element-wise sum oracle (residual adds, gate merges; channel
    concatenation is a sum of channel-embedded operands, see
    ``lower.netexec``).  All operands must share one shape."""
    out = xs[0].astype(jnp.float32)
    for x in xs[1:]:
        out = out + x.astype(jnp.float32)
    return out.astype(xs[0].dtype)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, window: int = 0,
                  logit_softcap: float = 0.0,
                  scale: float | None = None) -> jnp.ndarray:
    """Naive attention oracle.

    q: [B, H, Sq, D]; k, v: [B, KV, Sk, D] with H a multiple of KV (GQA).
    window > 0: local (sliding-window) attention of that width.
    """
    B, H, Sq, D = q.shape
    KV = k.shape[1]
    qpk = H // KV
    k = jnp.repeat(k, qpk, axis=1)
    v = jnp.repeat(v, qpk, axis=1)
    scale = scale if scale is not None else D ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = softcap(logits, logit_softcap)
    Sk = k.shape[2]
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)     # right-aligned (decode)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
            b: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Sequential state-space-duality (Mamba2) oracle.

    x:  [B, S, H, P]   per-head inputs
    dt: [B, S, H]      softplus'd step sizes (positive)
    a_log: [H]         per-head decay (A = -exp(a_log) < 0)
    b, c: [B, S, N]    shared-across-heads (G=1) input/output projections
    returns y: [B, S, H, P]
    """
    Bsz, S, H, P = x.shape
    N = b.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))                 # [H]
    dt = dt.astype(jnp.float32)
    decay = jnp.exp(dt * a[None, None, :])                  # [B,S,H]

    def step(h, inputs):
        xt, dtt, dect, bt, ct = inputs
        # h: [B,H,P,N]
        h = h * dect[..., None, None] + \
            (dtt[..., None] * xt)[..., None] * bt[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt, 1, 0), jnp.moveaxis(decay, 1, 0),
          jnp.moveaxis(b.astype(jnp.float32), 1, 0),
          jnp.moveaxis(c.astype(jnp.float32), 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def ssd_decode_ref(h: jnp.ndarray, x: jnp.ndarray, dt: jnp.ndarray,
                   a_log: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray):
    """One SSD decode step.  h: [B,H,P,N]; x: [B,H,P]; dt: [B,H];
    b, c: [B,N].  Returns (h', y [B,H,P])."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    decay = jnp.exp(dt.astype(jnp.float32) * a[None, :])
    h = h * decay[..., None, None] + \
        (dt[..., None] * x.astype(jnp.float32))[..., None] \
        * b[:, None, None, :].astype(jnp.float32)
    y = jnp.einsum("bhpn,bn->bhp", h, c.astype(jnp.float32))
    return h, y.astype(x.dtype)
