"""Backend selection — the single source of truth for interpret-vs-compiled.

Two tiers of the stack used to carry their own ad-hoc flags: the kernel
API (``kernels.ops``) dispatched on an ``impl`` string with a private
``_on_tpu()`` probe, and the lowering tier (``lower.exec`` /
``lower.netexec``) threaded a bare ``interpret: bool``.  Both now resolve
through this module, so "what actually runs" is decided in exactly one
place:

kernel-impl tier (``kernels.ops``: attention / ssd wrappers)
    ``resolve_impl("auto")`` -> ``"pallas"`` on TPU, ``"jnp"`` elsewhere.

execution-backend tier (``lower.exec`` / ``lower.netexec`` / ``lower.fuse``)
    =============  ========================================================
    ``interpret``  per-layer ``pl.pallas_call(interpret=True)`` — the
                   bit-accuracy **oracle**; runs everywhere, slowly.
    ``pallas``     per-layer compiled ``pl.pallas_call`` — TPU silicon.
    ``compiled``   fused XLA segments (``lower.fuse``): every kernel of a
                   chain segment traced into **one** jitted executable —
                   the default measured path.
    =============  ========================================================

``resolve_backend`` also accepts the legacy ``interpret`` bool so existing
call sites keep their meaning: ``interpret=True`` -> ``"interpret"``,
``interpret=False`` -> ``"pallas"``.
"""
from __future__ import annotations

from typing import Optional

import jax

#: execution backends of the lowering tier (see module docstring)
BACKENDS = ("interpret", "pallas", "compiled")

#: the default measured path: fused XLA segments, fast on every platform
DEFAULT_BACKEND = "compiled"

#: the numerics oracle every other backend is verified against
ORACLE_BACKEND = "interpret"


def on_tpu() -> bool:
    """True when jax's default backend is a TPU."""
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def default_impl() -> str:
    """Kernel-impl default: Pallas TPU kernels on TPU, pure-jnp elsewhere."""
    return "pallas" if on_tpu() else "jnp"


def resolve_impl(impl: str = "auto") -> str:
    """Resolve a kernel ``impl`` string (``kernels.ops`` dispatch)."""
    return default_impl() if impl == "auto" else impl


def resolve_backend(backend: Optional[str] = None,
                    interpret: Optional[bool] = None) -> str:
    """Resolve an execution backend name for the lowering tier.

    ``backend`` wins when given; otherwise the legacy ``interpret`` bool
    maps to its historical meaning; with neither, the default measured
    path (``compiled``) is chosen.
    """
    if backend is not None:
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; expected one "
                             f"of {BACKENDS}")
        return backend
    if interpret is not None:
        return "interpret" if interpret else "pallas"
    return DEFAULT_BACKEND


def backend_interprets(backend: str) -> bool:
    """Whether per-layer pallas_calls under this backend interpret (the
    flag handed through to ``pl.pallas_call``)."""
    return backend == "interpret"


__all__ = ["BACKENDS", "DEFAULT_BACKEND", "ORACLE_BACKEND", "on_tpu",
           "default_impl", "resolve_impl", "resolve_backend",
           "backend_interprets"]
