"""Mamba2 SSD (state-space duality) chunked scan — Pallas TPU kernel.

TPU adaptation: the SSD decomposition splits the sequence into chunks; the
intra-chunk term is a decay-masked attention-like matmul chain (MXU-friendly,
the compute hot spot) and the inter-chunk term is a cheap associative scan
over per-chunk states.  The Pallas kernel below computes the intra-chunk
quadratic term per (batch, head, chunk) with VMEM-resident blocks; the
inter-chunk recurrence stays in jnp (``ops.ssd``).

Validated against ``ref.ssd_ref`` with ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_intra_kernel(x_ref, dt_ref, acum_ref, b_ref, c_ref, y_ref, *,
                      chunk: int):
    x = x_ref[0, 0, 0].astype(jnp.float32)        # [Lc, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)      # [Lc]
    acum = acum_ref[0, 0, 0].astype(jnp.float32)  # [Lc]
    b = b_ref[0, 0].astype(jnp.float32)           # [Lc, N]
    c = c_ref[0, 0].astype(jnp.float32)           # [Lc, N]

    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))  # [Lc, Lc]
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    mi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(acum[:, None] - acum[None, :])
    scores = scores * decay * dt[None, :]
    scores = jnp.where(li >= mi, scores, 0.0)
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())))
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(x: jnp.ndarray, dt: jnp.ndarray, acum: jnp.ndarray,
                    b: jnp.ndarray, c: jnp.ndarray,
                    interpret: bool = False) -> jnp.ndarray:
    """Intra-chunk SSD term.

    x:    [B, H, NC, Lc, P]
    dt:   [B, H, NC, Lc]       (positive step sizes)
    acum: [B, H, NC, Lc]       (within-chunk cumsum of dt * A)
    b,c:  [B, NC, Lc, N]       (G=1: shared across heads)
    returns y_intra: [B, H, NC, Lc, P]
    """
    B, H, NC, Lc, P = x.shape
    N = b.shape[-1]
    grid = (B, H, NC)
    kernel = functools.partial(_ssd_intra_kernel, chunk=Lc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, Lc, P), lambda i, j, k: (i, j, k, 0, 0)),
            pl.BlockSpec((1, 1, 1, Lc), lambda i, j, k: (i, j, k, 0)),
            pl.BlockSpec((1, 1, 1, Lc), lambda i, j, k: (i, j, k, 0)),
            pl.BlockSpec((1, 1, Lc, N), lambda i, j, k: (i, k, 0, 0)),
            pl.BlockSpec((1, 1, Lc, N), lambda i, j, k: (i, k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Lc, P),
                               lambda i, j, k: (i, j, k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, dt, acum, b, c)
