"""Solver flight recorder: explainable scheduling decisions.

The solver stack reports only the winning schedule; this module captures
**why** it won.  An ``ExplainSink`` is threaded through the solvers
(``core.solver.kapla`` / ``interlayer`` / ``multinode``) when a solve is
run with ``explain=True`` and collects, per solve:

* the candidate **funnel** — enumerated -> validity-pruned (with the
  failing rule and the first overflowing layer) -> Pareto-pruned -> DP
  winner, per (start, stop) segment group;
* per-term **cost attribution** for the winner (MAC / REGF / GBUF / NoC
  / DRAM energy, roofline cycle terms, PE/node occupancy) whose term sum
  equals the schedule's scored energy;
* the top-k **runners-up** with cost deltas against the winner;
* the multi-node placement funnel, when the third tier ran.

The record is a plain JSON-safe dict: it attaches to
``NetworkSchedule.explain``, round-trips through ``to_json``/
``from_json`` and therefore persists inside ``ScheduleStore`` records
with no store changes.  ``render`` turns a record into the human
funnel-table + attribution-bar report behind
``python -m repro.obs explain``.

This module is rendering + collection only — it never imports the
solver, so ``repro.obs`` stays dependency-free and cycle-free.
"""
from __future__ import annotations

from typing import Dict, List, Optional

#: energy attribution term order (mirrors cost_model.ENERGY_TERMS; kept
#: here so rendering needs no solver import)
TERM_ORDER = ("mac_energy", "regf_energy", "gbuf_energy", "noc_energy",
              "dram_energy")

TERM_LABELS = {"mac_energy": "mac", "regf_energy": "regf",
               "gbuf_energy": "gbuf", "noc_energy": "noc",
               "dram_energy": "dram"}


class ExplainSink:
    """Collector the solvers write explain sections into.

    Deliberately dumb: a dict of named sections plus ``to_json``.  The
    solver layers own the section shapes; this class only guarantees the
    record stays a plain JSON value."""

    __slots__ = ("record",)

    def __init__(self):
        self.record: Dict = {"version": 1}

    def set(self, key: str, value) -> None:
        self.record[key] = value

    def set_funnel(self, funnel: Dict) -> None:
        """The inter-layer candidate funnel (``interlayer.funnel_from_
        batch``): per-(start, stop) enumerated/valid/kept counts, totals
        matching ``PruneStats``, and per-rule pruning attribution."""
        self.record["funnel"] = funnel

    def set_winner(self, winner: Dict) -> None:
        self.record["winner"] = winner

    def set_runners_up(self, runners: List[Dict]) -> None:
        self.record["runners_up"] = runners

    def set_multinode(self, info: Dict) -> None:
        self.record["multinode"] = info

    def to_json(self) -> Dict:
        return self.record


# ---------------------------------------------------------------------------
# rendering
# ---------------------------------------------------------------------------

def _bar(frac: float, width: int = 24) -> str:
    frac = min(1.0, max(0.0, frac))
    n = int(round(frac * width))
    return "#" * n + "." * (width - n)


def _fmt_seg(seg: Dict) -> str:
    gf = seg.get("granule_frac", 1.0)
    tag = "" if gf >= 1.0 else f" gf=1/{round(1.0 / gf)}"
    pipe = seg.get("pipelined")
    mode = "" if pipe is None else (" pipe" if pipe else " coarse")
    return f"[{seg['start']}:{seg['stop']}){tag}{mode}"


def render(record: Dict, width: int = 24) -> str:
    """Human-readable explain report: funnel table, attribution bars,
    runners-up, optional multi-node section."""
    lines: List[str] = []
    graph = record.get("graph", "?")
    obj = record.get("objective", "?")
    lines.append(f"explain[{graph}] objective={obj}")

    funnel = record.get("funnel")
    if funnel:
        tot = funnel.get("totals", {})
        en = tot.get("enumerated", 0)
        va = tot.get("after_validity", 0)
        ke = tot.get("after_pareto", 0)
        lines.append("candidate funnel (enumerated -> valid -> "
                     "pareto-kept):")
        vp = (en - va) / en * 100.0 if en else 0.0
        pp = (va - ke) / va * 100.0 if va else 0.0
        lines.append(f"  total {en:>7} -> {va:>7} -> {ke:>7}   "
                     f"({vp:.1f}% validity-pruned, "
                     f"{pp:.1f}% pareto-pruned)")
        for rule, info in sorted(funnel.get("pruned_by_rule",
                                            {}).items()):
            count = info.get("count", 0)
            if not count:
                continue
            layers = info.get("layers", {})
            top = sorted(layers.items(), key=lambda kv: -kv[1])[:3]
            at = ", ".join(f"{n} x{c}" for n, c in top)
            lines.append(f"  pruned by {rule}: {count}"
                         + (f"  (first overflow: {at})" if at else ""))
        win_groups = funnel.get("winner_groups")
        if win_groups:
            lines.append("  per winning segment "
                         "(enumerated / valid / kept):")
            shown = win_groups[:18]
            for g in shown:
                lines.append(f"    [{g['start']}:{g['stop']})"
                             f"  {g['enumerated']:>5} / {g['valid']:>5}"
                             f" / {g['kept']:>5}")
            if len(win_groups) > len(shown):
                lines.append(f"    ... ({len(win_groups) - len(shown)}"
                             " more segments)")

    winner = record.get("winner")
    if winner:
        lines.append(f"winner: energy {winner.get('energy_pj', 0):.4g} pJ"
                     f", latency {winner.get('latency_cycles', 0):.4g} cyc"
                     f", {len(winner.get('segments', []))} segment(s)")
        segs = winner.get("segments", [])
        if segs:
            lines.append("  chain: "
                         + " ".join(_fmt_seg(s) for s in segs))
        attrib = winner.get("attribution", {})
        total = sum(attrib.get(t, 0.0) for t in TERM_ORDER)
        if total > 0:
            lines.append("cost attribution (pJ):")
            for t in TERM_ORDER:
                v = attrib.get(t, 0.0)
                frac = v / total
                lines.append(f"  {TERM_LABELS[t]:<5} {_bar(frac, width)}"
                             f" {frac * 100.0:>5.1f}%  {v:.4g}")
        occ = winner.get("occupancy")
        if occ:
            lines.append(f"occupancy: {occ.get('avg_nodes_used', 0):.1f}"
                         f"/{occ.get('grid_nodes', 0)} nodes, "
                         f"{occ.get('avg_pes_used', 0):.1f}"
                         f"/{occ.get('pes_per_node', 0)} PEs per layer")
        cyc = winner.get("cycle_terms")
        if cyc:
            lines.append("roofline cycle terms: "
                         + ", ".join(f"{k}={v:.4g}"
                                     for k, v in sorted(cyc.items())))

    runners = record.get("runners_up") or []
    if runners:
        lines.append("runners-up (score delta vs winner):")
        for r in runners:
            segs = r.get("segments", [])
            chain = " ".join(_fmt_seg(s) for s in segs)
            lines.append(f"  #{r['rank']}  +{r['delta_frac'] * 100.0:.2f}%"
                         f"  {len(segs)} segment(s): {chain}")

    mn = record.get("multinode")
    if mn:
        f = mn.get("funnel", {})
        lines.append(f"multinode: {f.get('total', 0)} placements -> "
                     f"{f.get('after_validity', 0)} valid -> "
                     f"{f.get('kept', 0)} kept on the DP frontier")
        win = mn.get("winner")
        if win:
            parts = " ".join(
                f"segs[{p[0]}:{p[1]})->nodes{p[2]}"
                for p in win.get("parts", []))
            lines.append(f"  winner cost {win.get('cost', 0):.4g}: {parts}")
        for r in mn.get("runners_up", []):
            parts = " ".join(f"segs[{p[0]}:{p[1]})->nodes{p[2]}"
                             for p in r.get("parts", []))
            lines.append(f"  #{r['rank']}  +{r['delta_frac'] * 100.0:.2f}%"
                         f"  {parts}")
    return "\n".join(lines)


__all__ = ["ExplainSink", "render", "TERM_ORDER", "TERM_LABELS"]
