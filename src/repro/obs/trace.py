"""Thread-aware span tracing with Chrome trace-event export.

The tracing half of the observability layer (``repro.obs``): call sites
mark *spans* (timed regions) and *instant events* (annotated moments —
a degradation decision, an injected fault, a backup dispatch), and an
enabled tracer turns a run into a Perfetto-viewable timeline.

Design constraints, in order:

1. **Disabled is free.**  No tracer is installed by default; ``span()``
   then returns a shared no-op context manager and ``instant()`` returns
   immediately — one global read + ``None`` check on the hot path, no
   allocation beyond the caller's kwargs.  The solver's inner loops stay
   uninstrumented entirely; spans sit at segment/request granularity.
2. **Thread-aware.**  Events record the OS thread ident and name, so the
   solver's segment pool, the server's executor hops and the mesh's
   worker nodes each get their own timeline row in the viewer.
3. **Zero dependencies.**  stdlib only; the export target is the Chrome
   trace-event JSON format (``{"traceEvents": [...]}``), which Perfetto
   (https://ui.perfetto.dev) and ``chrome://tracing`` both load.

Usage::

    from repro.obs import trace

    with trace.tracing("run.trace.json"):
        with trace.span("solve.segment", graph="resnet", seg="0:4") as sp:
            ...
            sp.set(pipelined=True)          # late-bound attributes
        trace.instant("service.degrade", rung="greedy", reason="deadline")

Span/event names are dotted ``subsystem.action`` (``solve.segment``,
``service.request``, ``mesh.task``, ``fault.injected``); attributes are
JSON-safe scalars and land in the event's ``args``.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Dict, List, Optional


class _NoopSpan:
    """Shared constant no-op: what ``span()`` hands out while tracing is
    disabled.  ``set`` swallows late-bound attributes."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> None:
        pass


NOOP_SPAN = _NoopSpan()


class Span:
    """One live timed region; records itself into the tracer on exit."""

    __slots__ = ("_tracer", "name", "args", "t0")

    def __init__(self, tracer: "Tracer", name: str, args: Dict):
        self._tracer = tracer
        self.name = name
        self.args = args

    def set(self, **attrs) -> None:
        """Attach attributes decided after the span opened (e.g. the
        resolved request path)."""
        self.args.update(attrs)

    def __enter__(self) -> "Span":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self.args.setdefault("error", exc_type.__name__)
        self._tracer._complete(self.name, self.t0, time.perf_counter(),
                               self.args)
        return False


class Tracer:
    """An event buffer with Chrome trace-event export.

    Thread-safe; events carry (name, phase, t0, dur, thread ident,
    thread name, args) with times relative to the tracer's epoch.
    ``events`` rows are dicts — tests assert on them directly, the
    exporter maps them to trace-event JSON.
    """

    def __init__(self):
        self.epoch = time.perf_counter()
        self._lock = threading.Lock()
        self.events: List[Dict] = []
        self.dropped = 0
        self.max_events = 1_000_000     # runaway-trace backstop

    # -- recording -----------------------------------------------------------
    def _append(self, ev: Dict) -> None:
        with self._lock:
            if len(self.events) >= self.max_events:
                self.dropped += 1
                return
            self.events.append(ev)

    def _complete(self, name: str, t0: float, t1: float,
                  args: Dict) -> None:
        t = threading.current_thread()
        self._append({"name": name, "ph": "X",
                      "ts": t0 - self.epoch, "dur": t1 - t0,
                      "tid": t.ident, "tname": t.name, "args": args})

    def span(self, name: str, **args) -> Span:
        return Span(self, name, args)

    def instant(self, name: str, **args) -> None:
        t = threading.current_thread()
        self._append({"name": name, "ph": "i",
                      "ts": time.perf_counter() - self.epoch,
                      "tid": t.ident, "tname": t.name, "args": args})

    # -- querying (tests, summaries) -----------------------------------------
    def find(self, name: str) -> List[Dict]:
        """Events with this exact name, in record order."""
        with self._lock:
            return [e for e in self.events if e["name"] == name]

    def counts(self) -> Dict[str, int]:
        with self._lock:
            out: Dict[str, int] = {}
            for e in self.events:
                out[e["name"]] = out.get(e["name"], 0) + 1
            return out

    # -- export --------------------------------------------------------------
    def to_chrome(self) -> Dict:
        """The buffer as Chrome trace-event JSON (Perfetto-loadable):
        ``X`` complete events with µs timestamps, ``i`` thread-scoped
        instants, plus ``M`` thread-name metadata rows."""
        pid = os.getpid()
        out: List[Dict] = []
        threads: Dict[int, str] = {}
        with self._lock:
            events = list(self.events)
        for e in events:
            threads.setdefault(e["tid"], e["tname"])
            row = {"name": e["name"], "ph": e["ph"], "pid": pid,
                   "tid": e["tid"], "ts": e["ts"] * 1e6,
                   "cat": e["name"].split(".", 1)[0],
                   "args": e["args"]}
            if e["ph"] == "X":
                row["dur"] = e["dur"] * 1e6
            else:
                row["s"] = "t"          # thread-scoped instant
            out.append(row)
        for tid, tname in sorted(threads.items()):
            out.append({"name": "thread_name", "ph": "M", "pid": pid,
                        "tid": tid, "args": {"name": tname}})
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


# -- activation ---------------------------------------------------------------
# process-global, like runtime.inject: worker threads spawned inside the
# enabled scope (segment pool, node pool, server executor) must see it.
_tracer: Optional[Tracer] = None


def enabled() -> bool:
    return _tracer is not None


def current() -> Optional[Tracer]:
    return _tracer


def enable(tracer: Optional[Tracer] = None) -> Tracer:
    """Install (and return) the process-wide tracer."""
    global _tracer
    _tracer = tracer if tracer is not None else Tracer()
    return _tracer


def disable() -> Optional[Tracer]:
    """Remove the process-wide tracer; returns it for export."""
    global _tracer
    t = _tracer
    _tracer = None
    return t


@contextmanager
def tracing(path: Optional[str] = None, tracer: Optional[Tracer] = None):
    """Enable tracing for a scope; export to ``path`` on exit (even on
    error — a crashed chaos run still yields its timeline)::

        with trace.tracing("chaos.trace.json") as t:
            run()
    """
    t = enable(tracer)
    try:
        yield t
    finally:
        disable()
        if path is not None:
            t.save(path)


# -- the hot-path entry points ------------------------------------------------

def span(name: str, **args):
    """A timed region (context manager).  No-op constant when tracing is
    disabled."""
    t = _tracer
    if t is None:
        return NOOP_SPAN
    return t.span(name, **args)


def instant(name: str, **args) -> None:
    """An annotated moment (degradation decision, injected fault, backup
    dispatch...).  No-op when tracing is disabled."""
    t = _tracer
    if t is None:
        return
    t.instant(name, **args)


# -- trace-file summaries (the ``python -m repro.obs`` backend) ---------------

def load_events(path: str) -> List[Dict]:
    """Load a Chrome trace-event file back into event rows."""
    with open(path) as f:
        d = json.load(f)
    return d["traceEvents"] if isinstance(d, dict) else d


def summarize_events(events: List[Dict]) -> Dict:
    """Aggregate a trace-event list: per-name span count/total/max µs,
    instant-event counts, thread rows."""
    spans: Dict[str, Dict] = {}
    instants: Dict[str, int] = {}
    threads: Dict[int, str] = {}
    for e in events:
        ph = e.get("ph")
        if ph == "M":
            if e.get("name") == "thread_name":
                threads[e["tid"]] = e.get("args", {}).get("name", "?")
            continue
        name = e.get("name", "?")
        if ph == "X":
            s = spans.setdefault(name, {"count": 0, "total_us": 0.0,
                                        "max_us": 0.0})
            s["count"] += 1
            dur = float(e.get("dur", 0.0))
            s["total_us"] += dur
            s["max_us"] = max(s["max_us"], dur)
        elif ph == "i":
            instants[name] = instants.get(name, 0) + 1
    return {"n_events": len(events), "spans": spans,
            "instants": instants,
            "threads": {str(k): v for k, v in sorted(threads.items())}}


def _nest_spans(events: List[Dict]) -> List[Dict]:
    """Build per-(pid, tid) containment forests over the ``X`` events.

    Chrome complete events carry no explicit parent links; within one
    thread timeline, span A contains span B iff B's [ts, ts+dur) sits
    inside A's.  Returns the root nodes; each node is
    ``{event, children, self_us}`` with self time = own duration minus
    the durations of direct children."""
    by_thread: Dict = {}
    for e in events:
        if e.get("ph") != "X":
            continue
        key = (e.get("pid", 0), e.get("tid", 0))
        by_thread.setdefault(key, []).append(e)
    roots: List[Dict] = []
    for evs in by_thread.values():
        # sort by start asc, then duration desc: a parent sorts before
        # any span it contains, so a simple open-span stack nests them
        evs.sort(key=lambda e: (float(e.get("ts", 0.0)),
                                -float(e.get("dur", 0.0))))
        stack: List[Dict] = []
        for e in evs:
            ts = float(e.get("ts", 0.0))
            dur = float(e.get("dur", 0.0))
            node = {"event": e, "children": [], "self_us": dur}
            while stack:
                top = stack[-1]
                t0 = float(top["event"].get("ts", 0.0))
                t1 = t0 + float(top["event"].get("dur", 0.0))
                if ts < t1 and ts + dur <= t1 + 1e-9:
                    break
                stack.pop()
            if stack:
                stack[-1]["children"].append(node)
                stack[-1]["self_us"] -= dur
            else:
                roots.append(node)
            stack.append(node)
    return roots


def self_times(events: List[Dict]) -> Dict[str, Dict]:
    """Per-name self time (span duration minus direct children): where
    the wall clock actually went, with double-counting from nesting
    removed.  Returns ``{name: {count, total_us, self_us}}``."""
    out: Dict[str, Dict] = {}

    def walk(node: Dict) -> None:
        name = node["event"].get("name", "?")
        s = out.setdefault(name, {"count": 0, "total_us": 0.0,
                                  "self_us": 0.0})
        s["count"] += 1
        s["total_us"] += float(node["event"].get("dur", 0.0))
        s["self_us"] += max(0.0, node["self_us"])
        for c in node["children"]:
            walk(c)

    for r in _nest_spans(events):
        walk(r)
    return out


def critical_path(events: List[Dict]) -> List[Dict]:
    """The longest root-to-leaf chain of nested spans: start from the
    longest root and descend into the largest child at every level.
    Each step reports name/duration/self time and its share of the root.
    An approximation of "what must get faster for the run to get
    faster" for the dominant serial timeline."""
    roots = _nest_spans(events)
    if not roots:
        return []
    node = max(roots, key=lambda n: float(n["event"].get("dur", 0.0)))
    root_dur = float(node["event"].get("dur", 0.0)) or 1.0
    path: List[Dict] = []
    while node is not None:
        dur = float(node["event"].get("dur", 0.0))
        path.append({"name": node["event"].get("name", "?"),
                     "dur_us": dur,
                     "self_us": max(0.0, node["self_us"]),
                     "frac_of_root": dur / root_dur,
                     "args": node["event"].get("args", {})})
        node = max(node["children"],
                   key=lambda n: float(n["event"].get("dur", 0.0)),
                   default=None)
    return path


__all__ = ["Tracer", "Span", "NOOP_SPAN", "span", "instant", "enabled",
           "enable", "disable", "current", "tracing", "load_events",
           "summarize_events", "self_times", "critical_path"]
