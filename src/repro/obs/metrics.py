"""Process-wide metrics registry: labeled counters, gauges, histograms.

The always-on half of the observability layer (``repro.obs``): where
tracing (``obs.trace``) answers *when and why did this happen*, metrics
answer *how often and how much, over the process lifetime*.  One global
``REGISTRY`` aggregates every subsystem — store hits, solver memo hits,
request sources, mesh recovery events, injected faults, latency drift —
so a single ``snapshot()`` (JSON) or ``exposition()`` (Prometheus text)
covers the whole stack.

Design constraints:

* **Zero dependencies**, stdlib only.
* **Cheap.**  An update is a flag check, a label-tuple build and a
  locked dict add — nanoseconds against the millisecond-scale operations
  being counted.  ``off()`` (see ``repro.obs``) turns updates into the
  flag check alone, the overhead-bench baseline.
* **Per-instance thin views.**  Components that used to keep ad-hoc
  ``stats()`` dicts (``ScheduleStore``, ``SolveServer``, ...) hold a
  ``CounterGroup``: per-instance integers whose every increment is
  mirrored into a shared labeled counter, so old ``stats()`` shapes
  survive unchanged while the registry sees the union of all instances.

Naming scheme (kept Prometheus-conventional): ``<subsystem>_<what>``
with ``_total`` for counters and ``_seconds``/``_ratio`` units for
histograms — e.g. ``store_events_total{event="hits"}``,
``service_request_seconds{source="cached"}``, ``latency_drift_ratio``
(labeled ``{source, backend}``: predicted-vs-measured drift is a
different series per execution backend, interpreter seconds and fused
compiled-XLA seconds being different units).  The compiled tier adds
``fused_cache_events_total{event}`` / ``fused_cache_size`` /
``fused_compile_seconds`` (``repro.lower.fuse``).
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

#: kill switch (set via repro.obs.off()): updates become a flag check.
#: Exists so the overhead bench has a true no-observability baseline.
_off = False


def set_off(flag: bool) -> None:
    global _off
    _off = bool(flag)


def is_off() -> bool:
    return _off


#: default latency buckets (seconds) — sub-ms solver ops up to minute-
#: scale autotune runs
LATENCY_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)

#: buckets for measured/predicted latency ratios: 1.0 = perfect model,
#: log-ish spread both ways so calibration decay is visible in either
#: direction
DRIFT_BUCKETS = (0.1, 0.25, 0.5, 0.75, 0.9, 1.0, 1.1, 1.25, 1.5, 2.0,
                 3.0, 5.0, 10.0, 25.0, 100.0)


def escape_label_value(v: str) -> str:
    """Prometheus text-format label-value escaping: backslash, double
    quote and newline must be escaped inside the quoted value."""
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n",
                                                               "\\n")


def quantile_from_buckets(bounds: Sequence[float],
                          cumulative: Sequence[float],
                          count: float, q: float) -> float:
    """Estimate the ``q``-quantile from cumulative bucket counts by
    linear interpolation inside the straddling bucket (Prometheus
    ``histogram_quantile`` semantics: the first finite bucket's lower
    edge is 0, observations past the last finite bound clamp to it).

    ``bounds`` are the finite upper edges (ascending) and ``cumulative``
    the matching cumulative counts; ``count`` is the series total
    (the ``+Inf`` bucket)."""
    if count <= 0:
        return float("nan")
    target = q * count
    prev_cum = 0.0
    prev_bound = 0.0
    for b, c in zip(bounds, cumulative):
        if c >= target:
            in_bucket = c - prev_cum
            if in_bucket <= 0:
                return float(b)
            frac = (target - prev_cum) / in_bucket
            return float(prev_bound + (b - prev_bound) * frac)
        prev_cum, prev_bound = c, b
    return float(bounds[-1]) if len(bounds) else float("nan")


def series_quantiles(series: Dict,
                     qs: Sequence[float] = (0.5, 0.95, 0.99)
                     ) -> Dict[str, float]:
    """Quantiles of one snapshot histogram series (the ``series()`` /
    ``snapshot()`` dict shape: cumulative ``buckets`` with a ``+Inf``
    key plus ``count``) — usable on live and JSON-loaded snapshots
    alike, e.g. by the drift watchdog over ``BENCH_*.json`` records."""
    buckets = series.get("buckets", {})
    finite = sorted((float(k), v) for k, v in buckets.items()
                    if k not in ("+Inf", "inf"))
    bounds = [b for b, _ in finite]
    cum = [c for _, c in finite]
    count = series.get("count", 0)
    return {f"p{round(q * 100)}": quantile_from_buckets(bounds, cum,
                                                        count, q)
            for q in qs}


class Metric:
    """Base: a named family of labeled series."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()
        self._series: Dict[Tuple, float] = {}

    def _key(self, labels: Mapping[str, object]) -> Tuple:
        if tuple(sorted(labels)) != tuple(sorted(self.labelnames)):
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared "
                f"{sorted(self.labelnames)}")
        return tuple(str(labels[k]) for k in self.labelnames)

    def value(self, **labels) -> float:
        with self._lock:
            return self._series.get(self._key(labels), 0.0)

    def series(self) -> List[Dict]:
        with self._lock:
            items = sorted(self._series.items())
        return [{"labels": dict(zip(self.labelnames, k)), "value": v}
                for k, v in items]

    def snapshot(self) -> Dict:
        return {"kind": self.kind, "help": self.help,
                "labelnames": list(self.labelnames),
                "series": self.series()}

    # Prometheus text exposition -------------------------------------------
    def _fmt_labels(self, key: Tuple, extra: str = "") -> str:
        parts = [f'{n}="{escape_label_value(v)}"'
                 for n, v in zip(self.labelnames, key)]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}" if parts else ""

    def expose(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        with self._lock:
            items = sorted(self._series.items())
        for k, v in items:
            lines.append(f"{self.name}{self._fmt_labels(k)} {v}")
        return lines


class Counter(Metric):
    """Monotone event count (negative deltas tolerated for the few
    legacy counters that reconcile, e.g. a solve retracted after a
    fallback)."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if _off:
            return
        key = self._key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def expose(self) -> List[str]:
        # Prometheus convention: counter sample names carry a _total
        # suffix.  Families already named *_total are left alone.
        name = self.name if self.name.endswith("_total") \
            else self.name + "_total"
        lines = [f"# HELP {name} {self.help}",
                 f"# TYPE {name} {self.kind}"]
        with self._lock:
            items = sorted(self._series.items())
        for k, v in items:
            lines.append(f"{name}{self._fmt_labels(k)} {v}")
        return lines


class Gauge(Metric):
    """A point-in-time value (alive nodes, fleet median, queue depth)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        if _off:
            return
        key = self._key(labels)
        with self._lock:
            self._series[key] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        if _off:
            return
        key = self._key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels) -> None:
        self.inc(-amount, **labels)


class Histogram(Metric):
    """Cumulative-bucket histogram (Prometheus semantics: ``le`` buckets
    plus ``+Inf``, with per-series ``sum`` and ``count``)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = LATENCY_BUCKETS):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(buckets))
        # per label-key: [bucket counts..., +Inf count], sum, count
        self._h: Dict[Tuple, List] = {}

    def observe(self, value: float, **labels) -> None:
        if _off:
            return
        key = self._key(labels)
        i = bisect.bisect_left(self.buckets, value)
        with self._lock:
            h = self._h.get(key)
            if h is None:
                h = [[0] * (len(self.buckets) + 1), 0.0, 0]
                self._h[key] = h
            h[0][i] += 1
            h[1] += value
            h[2] += 1

    def value(self, **labels) -> float:
        """The series count (histograms have no single value)."""
        with self._lock:
            h = self._h.get(self._key(labels))
            return 0 if h is None else h[2]

    def series(self) -> List[Dict]:
        with self._lock:
            items = sorted(self._h.items())
        out = []
        for k, (counts, total, n) in items:
            cum, buckets = 0, {}
            for b, c in zip(self.buckets, counts):
                cum += c
                buckets[str(b)] = cum
            buckets["+Inf"] = n
            out.append({"labels": dict(zip(self.labelnames, k)),
                        "buckets": buckets, "sum": total, "count": n})
        return out

    def quantile(self, q: float, **labels) -> float:
        """Interpolated ``q``-quantile of one live series (see
        ``quantile_from_buckets``; NaN when the series is empty)."""
        with self._lock:
            h = self._h.get(self._key(labels))
            if h is None:
                return float("nan")
            counts, _, n = h
        cum, cumulative = 0, []
        for c in counts[:-1]:
            cum += c
            cumulative.append(cum)
        return quantile_from_buckets(self.buckets, cumulative, n, q)

    def expose(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} histogram"]
        for s in self.series():
            key = tuple(s["labels"][n] for n in self.labelnames)
            for le, c in s["buckets"].items():
                extra = 'le="%s"' % le
                lines.append(f"{self.name}_bucket"
                             f"{self._fmt_labels(key, extra)} {c}")
            lines.append(f"{self.name}_sum{self._fmt_labels(key)} "
                         f"{s['sum']}")
            lines.append(f"{self.name}_count{self._fmt_labels(key)} "
                         f"{s['count']}")
        return lines


class Registry:
    """Name -> metric family.  ``counter``/``gauge``/``histogram`` are
    get-or-create and idempotent — every call site can declare the
    metric it uses; redeclaring with a different kind or labelset is a
    bug and raises."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Metric] = {}

    def _get_or_create(self, cls, name: str, help: str,
                       labelnames: Sequence[str], **kw) -> Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, labelnames, **kw)
                self._metrics[name] = m
                return m
        if not isinstance(m, cls) or \
                m.labelnames != tuple(labelnames):
            raise ValueError(
                f"metric {name!r} redeclared as {cls.kind}"
                f"{tuple(labelnames)} but exists as {m.kind}"
                f"{m.labelnames}")
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS
                  ) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   buckets=buckets)

    def get(self, name: str) -> Optional[Metric]:
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> Dict:
        """JSON-safe snapshot of every family (the ``stats --json`` /
        ``BENCH_obs.json`` payload)."""
        with self._lock:
            metrics = dict(self._metrics)
        return {name: m.snapshot() for name, m in sorted(metrics.items())}

    def exposition(self) -> str:
        """Prometheus text-format exposition of the whole registry."""
        with self._lock:
            metrics = dict(self._metrics)
        lines: List[str] = []
        for _, m in sorted(metrics.items()):
            lines.extend(m.expose())
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        """Drop every family (tests)."""
        with self._lock:
            self._metrics.clear()


#: the process-wide registry every subsystem publishes into
REGISTRY = Registry()


def counter(name: str, help: str = "",
            labelnames: Sequence[str] = ()) -> Counter:
    return REGISTRY.counter(name, help, labelnames)


def gauge(name: str, help: str = "",
          labelnames: Sequence[str] = ()) -> Gauge:
    return REGISTRY.gauge(name, help, labelnames)


def histogram(name: str, help: str = "", labelnames: Sequence[str] = (),
              buckets: Sequence[float] = LATENCY_BUCKETS) -> Histogram:
    return REGISTRY.histogram(name, help, labelnames, buckets=buckets)


class CounterGroup:
    """Per-instance counter block mirrored into one shared labeled
    counter (``<subsystem>_events_total{event=...}``).

    The re-homing seam for the stack's legacy ``stats()`` dicts: each
    ``ScheduleStore``/``SolveServer``/... instance keeps its own integer
    view (so existing tests and stats shapes are untouched), while the
    process registry accumulates the union across instances."""

    def __init__(self, subsystem: str, names: Sequence[str],
                 registry: Optional[Registry] = None):
        self.subsystem = subsystem
        self._vals = {n: 0 for n in names}
        self._lock = threading.Lock()
        self._metric = (registry if registry is not None
                        else REGISTRY).counter(
            f"{subsystem}_events_total",
            f"{subsystem} counter events (all instances)",
            labelnames=("event",))

    def inc(self, name: str, amount: int = 1) -> None:
        with self._lock:
            self._vals[name] += amount      # KeyError = undeclared event
        self._metric.inc(amount, event=name)

    def __getitem__(self, name: str) -> int:
        with self._lock:
            return self._vals[name]

    def view(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._vals)


__all__ = ["Metric", "Counter", "Gauge", "Histogram", "Registry",
           "REGISTRY", "counter", "gauge", "histogram", "CounterGroup",
           "LATENCY_BUCKETS", "DRIFT_BUCKETS", "set_off", "is_off",
           "escape_label_value", "quantile_from_buckets",
           "series_quantiles"]
