"""Unified observability layer: spans, metrics, trace export.

The measurement substrate under the whole stack — solver, schedule
service, store, mesh executor, fault injection — with zero dependencies
and a free disabled path:

``obs.trace``
    Thread-aware span tracing (``with span("solve.segment", ...)``) and
    instant events (``instant("mesh.repartition", reason=...)``).  A
    no-op global-read fast path when disabled; Chrome trace-event JSON
    (Perfetto-loadable) when enabled.
``obs.metrics``
    A process-wide registry of labeled counters / gauges / histograms
    with a JSON ``snapshot()`` and Prometheus text ``exposition()``.
    Always on (updates are nanoseconds); ``off()`` exists so the
    overhead bench has a true zero-observability baseline.

Three switches::

    obs.off()                  # nothing recorded at all (baseline)
    obs.on()                   # metrics only (the production default)
    with trace.tracing(path):  # metrics + spans, exported on exit
        ...

``obs.explain``
    The solver flight recorder: candidate funnel, winner cost
    attribution and runners-up, collected when a solve runs with
    ``explain=True`` and rendered by ``python -m repro.obs explain``.
``obs.watch``
    The drift watchdog: predicted-vs-measured latency health, rolling
    per-backend baselines, calibration fit-quality and bench-regression
    checks (``python -m repro.obs watch [--gate]``).

``python -m repro.obs summarize TRACE.json`` aggregates an exported
trace (``--critical-path`` adds self-time and the dominant chain);
``python -m repro.obs metrics [--prom]`` dumps the registry.
See README "Observability" for the event/metric naming scheme.
"""
from . import explain, metrics, trace, watch
from .metrics import (REGISTRY, Counter, CounterGroup, Gauge, Histogram,
                      Registry, counter, gauge, histogram)
from .trace import Tracer, instant, span, tracing


def off() -> None:
    """Disable all observability: tracing off, metric updates skipped.
    The overhead-measurement baseline — not the production default."""
    trace.disable()
    metrics.set_off(True)


def on() -> None:
    """Restore the production default: metrics on, tracing off (enable
    tracing separately via ``trace.tracing``/``trace.enable``)."""
    metrics.set_off(False)


__all__ = ["metrics", "trace", "explain", "watch", "span", "instant",
           "tracing", "Tracer", "REGISTRY", "Registry", "Counter",
           "Gauge", "Histogram", "CounterGroup", "counter", "gauge",
           "histogram", "off", "on"]
