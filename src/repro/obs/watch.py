"""Drift watchdog: predicted-vs-measured latency health over time.

The solver's trustworthiness rests on its calibrated cost model; this
module watches the places where model and hardware meet and flags decay:

* **live drift**: every ``netexec.record_latency_drift`` call (serving,
  calibration sweeps, autotune) lands in the
  ``latency_drift_ratio{source, backend}`` histogram *and* in a small
  sample ring here (``note_sample``), so the watchdog can summarize
  recent measured/predicted ratios per backend with p50/p95/p99;
* **rolling baselines**: per-series EWMA of the drift median persisted
  in a state file — a backend whose current median moves away from its
  own history gets flagged, without hard-coding what "normal" drift is
  for an interpreter vs a compiled tier;
* **calibration fit quality**: a committed ``BENCH_calibration.json`` is
  re-checked from its raw (cycle-terms, measured-seconds) pairs — the
  stored coefficients must still *explain* the stored measurements
  (R² and rank correlation).  A corrupted or stale fit fails loudly
  even though the record "looks" complete;
* **bench regressions**: current ``BENCH_*.json`` records are compared
  against committed baselines — quality metrics (spearman, availability)
  must not drop, timing metrics must not blow up.

``python -m repro.obs watch`` renders the report; ``--gate`` exits
non-zero on any *error* finding, the CI hook.  Zero dependencies, and no
solver imports — the watchdog reads records, it never runs solves.
"""
from __future__ import annotations

import collections
import json
import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from .metrics import is_off, series_quantiles

# -- thresholds (module constants so tests can reference them) ----------------

#: calibration coefficients must still explain the measured pairs
R2_MIN = 0.5
#: ...and order them the way the hardware did
RANK_CORR_MIN = 0.8
#: quality metrics (spearman, availability, speedup) may drop this much
QUALITY_DROP_TOL = 0.10
#: timing metrics may grow this much before a warning (CI machines vary)
TIME_GROWTH_TOL = 0.50
#: absolute floor below which timing deltas are ignored (seconds)
TIME_ABS_FLOOR = 1e-3
#: a drift median this far from its rolling baseline is flagged
BASELINE_RATIO_TOL = 2.0
#: EWMA smoothing for the rolling baselines
EWMA_ALPHA = 0.3


# -- live sample ring ---------------------------------------------------------

_ring_lock = threading.Lock()
_samples: collections.deque = collections.deque(maxlen=512)


def note_sample(predicted_seconds: Optional[float],
                measured_seconds: float, source: str = "netexec",
                backend: str = "interpret") -> None:
    """Record one predicted/measured pair into the watchdog's ring.

    Called by ``lower.netexec.record_latency_drift`` next to the
    histogram observe; the ring keeps the raw recent pairs (the
    histogram only keeps bucket counts), bounded and cheap."""
    if is_off():
        return
    if not predicted_seconds or predicted_seconds <= 0.0:
        return
    if not math.isfinite(measured_seconds) or measured_seconds <= 0.0:
        return
    with _ring_lock:
        _samples.append({"predicted": predicted_seconds,
                         "measured": measured_seconds,
                         "ratio": measured_seconds / predicted_seconds,
                         "source": source, "backend": backend})


def recent_samples() -> List[Dict]:
    with _ring_lock:
        return list(_samples)


def clear_samples() -> None:
    with _ring_lock:
        _samples.clear()


def samples_report() -> Dict[str, Dict]:
    """Recent ring samples grouped by ``source|backend``: count and
    median ratio (exact — the ring has the raw values, unlike the
    bucketed histogram)."""
    groups: Dict[str, List[float]] = {}
    for s in recent_samples():
        groups.setdefault(f"{s['source']}|{s['backend']}",
                          []).append(s["ratio"])
    out = {}
    for key, ratios in sorted(groups.items()):
        ratios.sort()
        n = len(ratios)
        med = ratios[n // 2] if n % 2 else \
            0.5 * (ratios[n // 2 - 1] + ratios[n // 2])
        out[key] = {"count": n, "median_ratio": med,
                    "min_ratio": ratios[0], "max_ratio": ratios[-1]}
    return out


# -- pure-python fit statistics (obs stays numpy-free) ------------------------

def _mean(xs: Sequence[float]) -> float:
    return sum(xs) / len(xs)


def _ranks(xs: Sequence[float]) -> List[float]:
    order = sorted(range(len(xs)), key=lambda i: xs[i])
    ranks = [0.0] * len(xs)
    i = 0
    while i < len(order):
        j = i
        while j + 1 < len(order) and xs[order[j + 1]] == xs[order[i]]:
            j += 1
        avg = (i + j) / 2.0 + 1.0          # tie-averaged 1-based rank
        for k in range(i, j + 1):
            ranks[order[k]] = avg
        i = j + 1
    return ranks


def rank_correlation(x: Sequence[float], y: Sequence[float]) -> float:
    """Spearman rank correlation, stdlib-only (mirrors
    ``lower.calibrate.spearman`` without the numpy dependency)."""
    rx, ry = _ranks(x), _ranks(y)
    mx, my = _mean(rx), _mean(ry)
    num = sum((a - mx) * (b - my) for a, b in zip(rx, ry))
    den = math.sqrt(sum((a - mx) ** 2 for a in rx)
                    * sum((b - my) ** 2 for b in ry))
    return num / den if den > 0 else 0.0


def r_squared(y: Sequence[float], yhat: Sequence[float]) -> float:
    my = _mean(y)
    ss_tot = sum((v - my) ** 2 for v in y)
    ss_res = sum((v - p) ** 2 for v, p in zip(y, yhat))
    return 1.0 - ss_res / ss_tot if ss_tot > 0 else 0.0


# -- calibration record health ------------------------------------------------

def _finding(findings: List[Dict], severity: str, check: str,
             subject: str, message: str) -> None:
    findings.append({"severity": severity, "check": check,
                     "subject": subject, "message": message})


def check_calibration_record(record: Dict, name: str = "calibration",
                             findings: Optional[List[Dict]] = None
                             ) -> Dict:
    """Re-derive the fit quality of a calibration record from its own
    raw pairs.  The stored coefficients are applied to the stored cycle
    terms and compared against the stored measurements — a record whose
    coefficients were corrupted (or refit against different data) no
    longer explains its pairs, however plausible each field looks alone.

    Note the checks are *fit-quality* (R², rank correlation), not ratio
    checks: the affine fit has a negative intercept on the committed
    interpreter record, so small predictions legitimately go non-
    positive and measured/predicted ratios are meaningless there."""
    findings = findings if findings is not None else []
    cal = record.get("calibration")
    pairs = record.get("pairs") or []
    out: Dict = {"name": name, "n_pairs": len(pairs)}
    if not cal:
        _finding(findings, "error", "calibration", name,
                 "record has no fitted calibration block")
        out["ok"] = False
        return out
    if len(pairs) < 3:
        _finding(findings, "error", "calibration", name,
                 f"only {len(pairs)} measured pairs (need >= 3 to "
                 "judge the fit)")
        out["ok"] = False
        return out
    meas = [p["measured_seconds"] for p in pairs]
    pred = [cal["a_compute"] * p["cyc_compute"]
            + cal["a_dram"] * p["cyc_dram"]
            + cal["a_gbuf"] * p["cyc_gbuf"]
            + cal["a_step"] * p["grid_steps"]
            + cal["intercept"] for p in pairs]
    out["r2"] = r_squared(meas, pred)
    out["rank_corr"] = rank_correlation(pred, meas)
    out["backend"] = cal.get("backend", record.get("backend", "?"))
    stored = record.get("spearman_calibrated")
    if stored is not None:
        out["stored_rank_corr"] = stored
        if abs(stored - out["rank_corr"]) > 0.05:
            _finding(findings, "error", "calibration", name,
                     f"stored spearman_calibrated {stored:.3f} does not "
                     f"match recomputed {out['rank_corr']:.3f} — record "
                     "is stale or inconsistent with its own pairs")
    if out["r2"] < R2_MIN:
        _finding(findings, "error", "calibration", name,
                 f"fit no longer explains its measurements: R2 "
                 f"{out['r2']:.3f} < {R2_MIN} — recalibrate")
    if out["rank_corr"] < RANK_CORR_MIN:
        _finding(findings, "error", "calibration", name,
                 f"fit mis-orders its measurements: rank corr "
                 f"{out['rank_corr']:.3f} < {RANK_CORR_MIN} — "
                 "recalibrate")
    out["ok"] = not any(f["severity"] == "error"
                        and f["subject"] == name for f in findings)
    return out


# -- bench-record regression check --------------------------------------------

#: metric-key classification for the generic record walk
_HIGHER_BETTER = ("speedup", "spearman", "availability", "_per_sec")
_LOWER_BETTER = ("seconds", "overhead", "rel_err")


def _classify_key(key: str) -> Optional[str]:
    k = key.lower()
    for pat in _HIGHER_BETTER:
        if pat in k:
            return "higher"
    for pat in _LOWER_BETTER:
        if pat in k:
            return "lower"
    return None


def _walk_numbers(d, path="") -> Dict[str, float]:
    out: Dict[str, float] = {}
    if isinstance(d, dict):
        for k, v in d.items():
            out.update(_walk_numbers(v, f"{path}.{k}" if path else k))
    elif isinstance(d, (int, float)) and not isinstance(d, bool):
        if math.isfinite(d):
            out[path] = float(d)
    return out


def check_bench_regression(name: str, current: Dict, baseline: Dict,
                           findings: Optional[List[Dict]] = None,
                           time_tol: float = TIME_GROWTH_TOL,
                           quality_tol: float = QUALITY_DROP_TOL
                           ) -> Dict:
    """Compare a current bench record against its committed baseline.

    Quality metrics (spearman / availability) dropping by more than
    ``quality_tol`` are **errors**; speedup/throughput drops and timing
    growth beyond ``time_tol`` are **warnings** (CI machines differ, the
    trend matters more than one sample)."""
    findings = findings if findings is not None else []
    cur = _walk_numbers(current)
    base = _walk_numbers(baseline)
    compared, regressions = 0, []
    for path, bval in sorted(base.items()):
        cval = cur.get(path)
        kind = _classify_key(path.rsplit(".", 1)[-1])
        if cval is None or kind is None:
            continue
        compared += 1
        if kind == "higher":
            if bval > 0 and cval < bval * (1.0 - quality_tol):
                key = path.rsplit(".", 1)[-1].lower()
                hard = "spearman" in key or "availability" in key
                sev = "error" if hard else "warn"
                msg = (f"{path}: {cval:.4g} dropped from baseline "
                       f"{bval:.4g} (-{(1 - cval / bval) * 100:.1f}%)")
                _finding(findings, sev, "bench", name, msg)
                regressions.append({"path": path, "current": cval,
                                    "baseline": bval, "severity": sev})
        else:
            if cval > bval * (1.0 + time_tol) \
                    and cval - bval > TIME_ABS_FLOOR:
                msg = (f"{path}: {cval:.4g} grew from baseline "
                       f"{bval:.4g} (+{(cval / bval - 1) * 100:.1f}%)")
                _finding(findings, "warn", "bench", name, msg)
                regressions.append({"path": path, "current": cval,
                                    "baseline": bval,
                                    "severity": "warn"})
    return {"name": name, "compared": compared,
            "regressions": regressions,
            "ok": not any(r["severity"] == "error"
                          for r in regressions)}


# -- drift quantiles + rolling EWMA baselines ---------------------------------

def drift_from_snapshot(snapshot: Dict) -> Dict[str, Dict]:
    """Per-``source|backend`` drift summary from a registry snapshot
    (live ``REGISTRY.snapshot()`` or a JSON file of one): count plus
    interpolated p50/p95/p99 of ``latency_drift_ratio``."""
    fam = snapshot.get("latency_drift_ratio")
    if not fam:
        return {}
    out: Dict[str, Dict] = {}
    for s in fam.get("series", []):
        labels = s.get("labels", {})
        key = f"{labels.get('source', '?')}|{labels.get('backend', '?')}"
        q = series_quantiles(s)
        out[key] = {"count": s.get("count", 0), **q}
    return out


def load_state(path: str) -> Dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {"version": 1, "baselines": {}}


def save_state(state: Dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(state, f, indent=2)
        f.write("\n")


def update_baselines(state: Dict, drift: Dict[str, Dict],
                     findings: Optional[List[Dict]] = None,
                     alpha: float = EWMA_ALPHA,
                     ratio_tol: float = BASELINE_RATIO_TOL) -> Dict:
    """Fold the current per-series drift medians into the rolling EWMA
    baselines; a median ``ratio_tol``x away from its own history (either
    direction) is flagged.  Returns the mutated state."""
    findings = findings if findings is not None else []
    baselines = state.setdefault("baselines", {})
    for key, summary in sorted(drift.items()):
        p50 = summary.get("p50")
        if p50 is None or not math.isfinite(p50) or p50 <= 0:
            continue
        b = baselines.get(key)
        if b is None:
            baselines[key] = {"ewma_p50": p50, "n": 1}
            summary["baseline_p50"] = p50
            continue
        prior = b["ewma_p50"]
        summary["baseline_p50"] = prior
        rel = p50 / prior if prior > 0 else float("inf")
        summary["vs_baseline"] = rel
        if rel > ratio_tol or rel < 1.0 / ratio_tol:
            _finding(findings, "warn", "drift", key,
                     f"drift median {p50:.3g} is {rel:.2f}x its rolling "
                     f"baseline {prior:.3g}")
        b["ewma_p50"] = (1.0 - alpha) * prior + alpha * p50
        b["n"] = b.get("n", 0) + 1
    return state


# -- the watchdog run ---------------------------------------------------------

def run_watch(calibrations: Sequence[Tuple[str, Dict]] = (),
              benches: Sequence[Tuple[str, Dict, Dict]] = (),
              snapshot: Optional[Dict] = None,
              state: Optional[Dict] = None) -> Dict:
    """One watchdog pass over everything it was given:
    ``calibrations`` are ``(name, record)`` pairs, ``benches`` are
    ``(name, current, baseline)`` triples, ``snapshot`` a metrics
    registry snapshot, ``state`` the rolling-baseline state (mutated in
    place when given).  Returns the JSON-safe report; ``report["ok"]``
    is False iff any error-severity finding fired (the ``--gate``
    bit)."""
    findings: List[Dict] = []
    report: Dict = {"version": 1, "findings": findings}
    report["calibration"] = {
        name: check_calibration_record(rec, name, findings)
        for name, rec in calibrations}
    report["bench"] = {
        name: check_bench_regression(name, cur, base, findings)
        for name, cur, base in benches}
    if snapshot is not None:
        drift = drift_from_snapshot(snapshot)
        if state is not None:
            update_baselines(state, drift, findings)
        report["drift"] = drift
    samples = samples_report()
    if samples:
        report["samples"] = samples
    report["n_errors"] = sum(1 for f in findings
                             if f["severity"] == "error")
    report["n_warnings"] = sum(1 for f in findings
                               if f["severity"] == "warn")
    report["ok"] = report["n_errors"] == 0
    return report


def render_report(report: Dict) -> str:
    """Human rendering of a ``run_watch`` report."""
    lines: List[str] = []
    ok = report.get("ok", False)
    lines.append(f"drift watchdog: {'OK' if ok else 'FAILING'} "
                 f"({report.get('n_errors', 0)} error(s), "
                 f"{report.get('n_warnings', 0)} warning(s))")
    for name, c in sorted(report.get("calibration", {}).items()):
        if "r2" in c:
            lines.append(f"  calibration[{name}] backend="
                         f"{c.get('backend', '?')}: R2 {c['r2']:.3f}, "
                         f"rank corr {c['rank_corr']:.3f} over "
                         f"{c['n_pairs']} pairs -> "
                         f"{'ok' if c.get('ok') else 'FAIL'}")
        else:
            lines.append(f"  calibration[{name}]: "
                         f"{'ok' if c.get('ok') else 'FAIL'}")
    for name, b in sorted(report.get("bench", {}).items()):
        lines.append(f"  bench[{name}]: {b['compared']} metrics vs "
                     f"baseline, {len(b['regressions'])} regressed")
    for key, d in sorted(report.get("drift", {}).items()):
        extra = ""
        if "vs_baseline" in d:
            extra = f", {d['vs_baseline']:.2f}x rolling baseline"
        lines.append(f"  drift[{key}]: n={d.get('count', 0)} "
                     f"p50={d.get('p50', float('nan')):.3g} "
                     f"p95={d.get('p95', float('nan')):.3g} "
                     f"p99={d.get('p99', float('nan')):.3g}{extra}")
    for key, s in sorted(report.get("samples", {}).items()):
        lines.append(f"  samples[{key}]: n={s['count']} median ratio "
                     f"{s['median_ratio']:.3g}")
    for f in report.get("findings", []):
        lines.append(f"  {f['severity'].upper()} {f['check']}"
                     f"[{f['subject']}]: {f['message']}")
    return "\n".join(lines)


__all__ = ["note_sample", "recent_samples", "clear_samples",
           "samples_report", "rank_correlation", "r_squared",
           "check_calibration_record", "check_bench_regression",
           "drift_from_snapshot", "load_state", "save_state",
           "update_baselines", "run_watch", "render_report",
           "R2_MIN", "RANK_CORR_MIN"]
