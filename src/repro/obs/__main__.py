"""Observability CLI.

    python -m repro.obs summarize TRACE.json [--json]
    python -m repro.obs metrics [SNAPSHOT.json] [--prom | --json]

``summarize`` aggregates an exported Chrome trace-event file (per-span
count / total / max duration, instant-event counts, thread rows) — the
quick look before opening the file in Perfetto (https://ui.perfetto.dev).
``metrics`` renders a registry snapshot: from a ``BENCH_obs.json`` /
``stats --json`` style file when given (any JSON whose top level or
``metrics`` key is a registry snapshot), else the live in-process
registry (empty in a fresh CLI process — useful mainly under a driver
that populated it).  ``--prom`` emits Prometheus text exposition.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from . import metrics, trace


def cmd_summarize(args) -> int:
    events = trace.load_events(args.trace)
    s = trace.summarize_events(events)
    if args.json:
        json.dump(s, sys.stdout, indent=1)
        print()
        return 0
    print(f"{args.trace}: {s['n_events']} events, "
          f"{len(s['threads'])} threads")
    if s["spans"]:
        print("spans (count / total ms / max ms):")
        width = max(len(n) for n in s["spans"])
        for name in sorted(s["spans"],
                           key=lambda n: -s["spans"][n]["total_us"]):
            sp = s["spans"][name]
            print(f"  {name:<{width}}  {sp['count']:>6}  "
                  f"{sp['total_us'] / 1e3:>10.2f}  "
                  f"{sp['max_us'] / 1e3:>10.2f}")
    if s["instants"]:
        print("instant events:")
        for name in sorted(s["instants"]):
            print(f"  {name}: {s['instants'][name]}")
    print("open in Perfetto: https://ui.perfetto.dev (drag the file in)")
    return 0


def _snapshot_from_file(path: str) -> dict:
    with open(path) as f:
        d = json.load(f)
    # accept a bare registry snapshot or a record embedding one
    if isinstance(d, dict) and "metrics" in d and \
            isinstance(d["metrics"], dict):
        return d["metrics"]
    return d


def cmd_metrics(args) -> int:
    if args.snapshot:
        snap = _snapshot_from_file(args.snapshot)
    else:
        snap = metrics.REGISTRY.snapshot()
    if args.prom:
        if args.snapshot:
            # rebuild a registry from the snapshot for text exposition
            reg = metrics.Registry()
            for name, fam in snap.items():
                if fam.get("kind") == "histogram":
                    continue            # buckets are not re-loadable 1:1
                cls = {"counter": reg.counter,
                       "gauge": reg.gauge}.get(fam.get("kind"))
                if cls is None:
                    continue
                m = cls(name, fam.get("help", ""),
                        tuple(fam.get("labelnames", ())))
                for s in fam.get("series", []):
                    m.inc(s["value"], **s["labels"])
            print(reg.exposition(), end="")
        else:
            print(metrics.REGISTRY.exposition(), end="")
        return 0
    if args.json:
        json.dump(snap, sys.stdout, indent=1)
        print()
        return 0
    for name in sorted(snap):
        fam = snap[name]
        print(f"{name} ({fam.get('kind', '?')}) — "
              f"{fam.get('help', '')}")
        for s in fam.get("series", []):
            labels = ",".join(f"{k}={v}" for k, v in s["labels"].items())
            if "count" in s:            # histogram series
                mean = s["sum"] / s["count"] if s["count"] else 0.0
                print(f"  {{{labels}}} count={s['count']} "
                      f"mean={mean:.6g} sum={s['sum']:.6g}")
            else:
                print(f"  {{{labels}}} {s['value']:g}")
    if not snap:
        print("(registry is empty)")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="verb", required=True)

    p = sub.add_parser("summarize", help="aggregate an exported trace")
    p.add_argument("trace", help="Chrome trace-event JSON file")
    p.add_argument("--json", action="store_true",
                   help="machine-readable summary")
    p.set_defaults(fn=cmd_summarize)

    p = sub.add_parser("metrics", help="dump a metrics snapshot")
    p.add_argument("snapshot", nargs="?", default=None,
                   help="snapshot JSON file (default: live registry)")
    p.add_argument("--prom", action="store_true",
                   help="Prometheus text exposition")
    p.add_argument("--json", action="store_true",
                   help="raw snapshot JSON")
    p.set_defaults(fn=cmd_metrics)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
