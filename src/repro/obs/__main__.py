"""Observability CLI.

    python -m repro.obs summarize TRACE.json [--critical-path] [--json]
    python -m repro.obs metrics [SNAPSHOT.json] [--prom | --json]
    python -m repro.obs explain <sig|net> [--batch N] [--store-dir DIR]
    python -m repro.obs watch [--calibration REC.json ...]
                              [--bench CUR.json=BASE.json ...]
                              [--metrics SNAPSHOT.json] [--state FILE]
                              [--out BENCH_drift.json] [--gate] [--json]

``summarize`` aggregates an exported Chrome trace-event file (per-span
count / total / max duration, instant-event counts, thread rows) — the
quick look before opening the file in Perfetto (https://ui.perfetto.dev).
``--critical-path`` adds per-span *self* time (nesting removed) and the
dominant root-to-leaf span chain.  Given a metrics-snapshot JSON instead
of a trace, it renders the registry families with interpolated
p50/p95/p99 for every histogram series.
``metrics`` renders a registry snapshot: from a ``BENCH_obs.json`` /
``stats --json`` style file when given (any JSON whose top level or
``metrics`` key is a registry snapshot), else the live in-process
registry (empty in a fresh CLI process — useful mainly under a driver
that populated it).  ``--prom`` emits Prometheus text exposition.
``explain`` renders a solver flight-recorder record: from a stored
schedule (by signature or net name, searching ``--store-dir``), else by
solving the named net fresh with ``explain=True``.
``watch`` runs the drift watchdog (calibration fit quality, bench
regressions vs committed baselines, drift quantiles + rolling EWMA
baselines); ``--gate`` exits non-zero on any error finding (CI hook).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
from typing import List, Optional

from . import metrics, trace, watch
from .explain import render as render_explain
from .metrics import series_quantiles


def _fmt_q(v: float) -> str:
    return "n/a" if not math.isfinite(v) else f"{v:.4g}"


def _looks_like_snapshot(d) -> bool:
    return isinstance(d, dict) and "traceEvents" not in d and any(
        isinstance(v, dict) and "kind" in v and "series" in v
        for v in d.values())


def _render_snapshot(snap: dict) -> None:
    for name in sorted(snap):
        fam = snap[name]
        print(f"{name} ({fam.get('kind', '?')}) — "
              f"{fam.get('help', '')}")
        for s in fam.get("series", []):
            labels = ",".join(f"{k}={v}" for k, v in s["labels"].items())
            if "count" in s:            # histogram series
                mean = s["sum"] / s["count"] if s["count"] else 0.0
                q = series_quantiles(s)
                print(f"  {{{labels}}} count={s['count']} "
                      f"mean={mean:.6g} sum={s['sum']:.6g} "
                      f"p50={_fmt_q(q['p50'])} p95={_fmt_q(q['p95'])} "
                      f"p99={_fmt_q(q['p99'])}")
            else:
                print(f"  {{{labels}}} {s['value']:g}")
    if not snap:
        print("(registry is empty)")


def cmd_summarize(args) -> int:
    with open(args.trace) as f:
        d = json.load(f)
    if _looks_like_snapshot(d):
        # a metrics snapshot, not a trace: families + quantiles
        snap = d.get("metrics", d) if "metrics" in d and \
            isinstance(d.get("metrics"), dict) else d
        if args.json:
            out = {name: {"quantiles": [
                {"labels": s["labels"], **series_quantiles(s)}
                for s in fam.get("series", []) if "count" in s]}
                for name, fam in snap.items()}
            json.dump(out, sys.stdout, indent=1)
            print()
            return 0
        _render_snapshot(snap)
        return 0
    events = d["traceEvents"] if isinstance(d, dict) else d
    s = trace.summarize_events(events)
    if args.critical_path:
        s["self_times"] = trace.self_times(events)
        s["critical_path"] = trace.critical_path(events)
    if args.json:
        json.dump(s, sys.stdout, indent=1)
        print()
        return 0
    print(f"{args.trace}: {s['n_events']} events, "
          f"{len(s['threads'])} threads")
    if s["spans"]:
        print("spans (count / total ms / max ms):")
        width = max(len(n) for n in s["spans"])
        for name in sorted(s["spans"],
                           key=lambda n: -s["spans"][n]["total_us"]):
            sp = s["spans"][name]
            print(f"  {name:<{width}}  {sp['count']:>6}  "
                  f"{sp['total_us'] / 1e3:>10.2f}  "
                  f"{sp['max_us'] / 1e3:>10.2f}")
    if s["instants"]:
        print("instant events:")
        for name in sorted(s["instants"]):
            print(f"  {name}: {s['instants'][name]}")
    if args.critical_path:
        st = s["self_times"]
        if st:
            print("self time (count / total ms / self ms):")
            width = max(len(n) for n in st)
            for name in sorted(st, key=lambda n: -st[n]["self_us"]):
                r = st[name]
                print(f"  {name:<{width}}  {r['count']:>6}  "
                      f"{r['total_us'] / 1e3:>10.2f}  "
                      f"{r['self_us'] / 1e3:>10.2f}")
        cp = s["critical_path"]
        if cp:
            print("critical path (longest nested span chain):")
            for step in cp:
                print(f"  {step['name']}  "
                      f"{step['dur_us'] / 1e3:.2f} ms total, "
                      f"{step['self_us'] / 1e3:.2f} ms self "
                      f"({step['frac_of_root'] * 100:.0f}% of root)")
    print("open in Perfetto: https://ui.perfetto.dev (drag the file in)")
    return 0


def _snapshot_from_file(path: str) -> dict:
    with open(path) as f:
        d = json.load(f)
    # accept a bare registry snapshot or a record embedding one
    if isinstance(d, dict) and "metrics" in d and \
            isinstance(d["metrics"], dict):
        return d["metrics"]
    return d


def cmd_metrics(args) -> int:
    if args.snapshot:
        snap = _snapshot_from_file(args.snapshot)
    else:
        snap = metrics.REGISTRY.snapshot()
    if args.prom:
        if args.snapshot:
            # rebuild a registry from the snapshot for text exposition
            reg = metrics.Registry()
            for name, fam in snap.items():
                if fam.get("kind") == "histogram":
                    continue            # buckets are not re-loadable 1:1
                cls = {"counter": reg.counter,
                       "gauge": reg.gauge}.get(fam.get("kind"))
                if cls is None:
                    continue
                m = cls(name, fam.get("help", ""),
                        tuple(fam.get("labelnames", ())))
                for s in fam.get("series", []):
                    m.inc(s["value"], **s["labels"])
            print(reg.exposition(), end="")
        else:
            print(metrics.REGISTRY.exposition(), end="")
        return 0
    if args.json:
        json.dump(snap, sys.stdout, indent=1)
        print()
        return 0
    _render_snapshot(snap)
    return 0


# -- explain ------------------------------------------------------------------

def _explain_from_store(target: str, store_dir: Optional[str]):
    """Find a stored schedule by exact signature or by graph name;
    returns its explain block (or None twice on no match)."""
    from ..service.store import DEFAULT_ROOT, ScheduleStore
    root = store_dir or DEFAULT_ROOT
    if not os.path.isdir(root):
        return None, None
    store = ScheduleStore(root)
    sigs = store.signatures()
    if target in sigs:
        rec = store.get_record(target)
        return rec, (rec.schedule or {}).get("explain") if rec else None
    for sig in sigs:
        rec = store.get_record(sig)
        if rec is not None and rec.graph_name == target:
            return rec, (rec.schedule or {}).get("explain")
    return None, None


def cmd_explain(args) -> int:
    target = args.target
    rec, record = _explain_from_store(target, args.store_dir)
    if rec is not None and record is None:
        print(f"stored schedule {rec.signature} for {rec.graph_name} "
              "has no explain block (solved without explain=True); "
              "solving fresh", file=sys.stderr)
    if record is None:
        # not stored (or stored without a record): solve the net fresh
        from ..core.solver import solve
        from ..hw.presets import eyeriss_multinode
        from ..workloads.nets import get_net
        name, batch = target, args.batch
        if "/b" in target:              # accept "resnet/b64" directly
            name, _, b = target.rpartition("/b")
            batch = int(b)
        try:
            net = get_net(name, batch=batch)
        except Exception:
            print(f"explain: {target!r} is neither a stored "
                  "signature/net nor a registered net name",
                  file=sys.stderr)
            return 1
        sched = solve(net, eyeriss_multinode(), explain=True)
        record = sched.explain
    if record is None:
        print(f"explain: no record produced for {target!r}",
              file=sys.stderr)
        return 1
    if args.json:
        json.dump(record, sys.stdout, indent=1)
        print()
        return 0
    print(render_explain(record))
    return 0


# -- watch --------------------------------------------------------------------

def cmd_watch(args) -> int:
    calibrations = []
    cal_paths = list(args.calibration or [])
    if not cal_paths and not args.bench and not args.metrics \
            and os.path.exists("BENCH_calibration.json"):
        cal_paths = ["BENCH_calibration.json"]    # bare-run default
    for path in cal_paths:
        with open(path) as f:
            calibrations.append((os.path.basename(path), json.load(f)))
    benches = []
    for spec in args.bench or []:
        cur_path, sep, base_path = spec.partition("=")
        if not sep:
            print(f"watch: --bench wants CURRENT.json=BASELINE.json, "
                  f"got {spec!r}", file=sys.stderr)
            return 2
        with open(cur_path) as f:
            cur = json.load(f)
        with open(base_path) as f:
            base = json.load(f)
        benches.append((os.path.basename(cur_path), cur, base))
    snapshot = None
    if args.metrics:
        snapshot = _snapshot_from_file(args.metrics)
    elif metrics.REGISTRY.get("latency_drift_ratio") is not None:
        snapshot = metrics.REGISTRY.snapshot()
    state = watch.load_state(args.state) if args.state else None
    report = watch.run_watch(calibrations=calibrations, benches=benches,
                             snapshot=snapshot, state=state)
    if args.state and state is not None:
        watch.save_state(state, args.state)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
    if args.json:
        json.dump(report, sys.stdout, indent=1)
        print()
    else:
        print(watch.render_report(report))
    if args.gate and not report["ok"]:
        return 1
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="verb", required=True)

    p = sub.add_parser("summarize", help="aggregate an exported trace "
                       "(or a metrics snapshot, with quantiles)")
    p.add_argument("trace", help="Chrome trace-event JSON file (or a "
                   "metrics snapshot JSON)")
    p.add_argument("--critical-path", action="store_true",
                   help="add self-time table and the dominant nested "
                        "span chain")
    p.add_argument("--json", action="store_true",
                   help="machine-readable summary")
    p.set_defaults(fn=cmd_summarize)

    p = sub.add_parser("metrics", help="dump a metrics snapshot")
    p.add_argument("snapshot", nargs="?", default=None,
                   help="snapshot JSON file (default: live registry)")
    p.add_argument("--prom", action="store_true",
                   help="Prometheus text exposition")
    p.add_argument("--json", action="store_true",
                   help="raw snapshot JSON")
    p.set_defaults(fn=cmd_metrics)

    p = sub.add_parser("explain", help="render a solver flight-recorder "
                       "record (funnel, attribution, runners-up)")
    p.add_argument("target", help="store signature, stored net name "
                   "(e.g. resnet/b64), or registered net name")
    p.add_argument("--batch", type=int, default=64,
                   help="batch size when solving fresh (default 64)")
    p.add_argument("--store-dir", default=None,
                   help="schedule store to search (default: "
                        ".repro_store / $REPRO_STORE_DIR)")
    p.add_argument("--json", action="store_true",
                   help="raw explain record JSON")
    p.set_defaults(fn=cmd_explain)

    p = sub.add_parser("watch", help="drift watchdog: calibration fit "
                       "quality, bench regressions, drift baselines")
    p.add_argument("--calibration", action="append", default=[],
                   metavar="REC.json",
                   help="calibration record(s) to health-check "
                        "(default: ./BENCH_calibration.json if present)")
    p.add_argument("--bench", action="append", default=[],
                   metavar="CUR.json=BASE.json",
                   help="bench record vs committed baseline "
                        "(repeatable)")
    p.add_argument("--metrics", default=None, metavar="SNAPSHOT.json",
                   help="metrics snapshot with latency_drift_ratio "
                        "(default: live registry when populated)")
    p.add_argument("--state", default=None, metavar="FILE",
                   help="rolling EWMA baseline state file "
                        "(read + updated)")
    p.add_argument("--out", default=None, metavar="BENCH_drift.json",
                   help="write the full report JSON here")
    p.add_argument("--gate", action="store_true",
                   help="exit non-zero on any error finding (CI)")
    p.add_argument("--json", action="store_true",
                   help="print the report as JSON")
    p.set_defaults(fn=cmd_watch)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
