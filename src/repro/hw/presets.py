"""Hardware presets from KAPLA §V (Methodology) + the TPU-pod target.

Energy numbers follow the paper's modeling choices (16-bit MAC = 1 pJ, NoC =
0.61 pJ/bit/hop, McPAT-style SRAM/regfile energies, LPDDR4 DRAM).  Per-byte
figures are representative of the 28 nm magnitudes; relative ordering
(REGF << GBUF << NoC << DRAM) is what the solver comparisons depend on.
"""
from __future__ import annotations

from .template import HWTemplate, MemLevel, TPUPodSpec


def eyeriss_multinode(nodes: int = 16, pe: int = 8, regf_bytes: int = 64,
                      gbuf_bytes: int = 32 * 1024,
                      dram_ports: int = 1) -> HWTemplate:
    """16x16 nodes, each 8x8 PEs, 64 B REGF/PE, 32 kB GBUF/node (paper Fig 1).

    Row-stationary PE mapping, buffer sharing enabled at the node level.
    """
    return HWTemplate(
        name=f"eyeriss_{nodes}x{nodes}",
        levels=(
            MemLevel("REGF", regf_bytes, 0.06, 4.0),
            MemLevel("GBUF", gbuf_bytes, 0.6, 16.0, array=(pe, pe),
                     same_level_transfer=True),       # systolic-ish PE links
            MemLevel("DRAM", float("inf"), 32.0, 12.8, array=(nodes, nodes),
                     same_level_transfer=True),       # buffer sharing
        ),
        mac_energy_pj=1.0,
        noc_hop_energy_pj_per_byte=0.61 * 8,
        freq_hz=500e6,
        pe_dataflow="row_stationary",
        dram_ports=dram_ports)


def tpu_like_edge() -> HWTemplate:
    """Single node, 16x16 systolic PE array, 512 B REGF/PE, 256 kB GBUF."""
    return HWTemplate(
        name="tpu_edge",
        levels=(
            MemLevel("REGF", 512, 0.06, 4.0),
            MemLevel("GBUF", 256 * 1024, 1.2, 32.0, array=(16, 16),
                     same_level_transfer=True),
            MemLevel("DRAM", float("inf"), 32.0, 12.8, array=(1, 1)),
        ),
        mac_energy_pj=1.0,
        noc_hop_energy_pj_per_byte=0.61 * 8,
        freq_hz=500e6,
        pe_dataflow="systolic")


def tpu_v5e_pod() -> TPUPodSpec:
    return TPUPodSpec()


PRESETS = {
    "eyeriss_multinode": eyeriss_multinode,
    "tpu_like_edge": tpu_like_edge,
}
