"""Generic hardware configuration template (KAPLA §III-C).

A machine is a hierarchy of memory levels (inner -> outer).  Each level has a
per-buffer capacity, bandwidth, per-byte access energy, a spatial array of
units *below* it (the PE array below GBUF, the node array below DRAM), and a
flag for whether same-level (neighbor) transfers are supported (systolic flow
at the PE level, buffer sharing at the node level).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class MemLevel:
    name: str
    capacity_bytes: float          # per-buffer capacity (inf for DRAM)
    access_energy_pj_per_byte: float
    bandwidth_bytes_per_cycle: float
    # spatial array of units at this level (units each holding one buffer of
    # the *previous* (inner) level); (1, 1) for the innermost level.
    array: Tuple[int, int] = (1, 1)
    same_level_transfer: bool = False   # systolic / buffer-sharing support
    multicast: bool = True              # next-level bus/tree multicast

    @property
    def num_units(self) -> int:
        return self.array[0] * self.array[1]


@dataclasses.dataclass(frozen=True)
class HWTemplate:
    """levels are ordered inner -> outer, e.g. (REGF, GBUF, DRAM).

    ``levels[i].array`` is the fan-out of level-(i-1) buffers under one
    level-i buffer; e.g. GBUF.array = PE array shape, DRAM.array = node grid.
    """

    name: str
    levels: Tuple[MemLevel, ...]
    mac_energy_pj: float
    noc_hop_energy_pj_per_byte: float
    freq_hz: float
    pe_dataflow: str                    # 'row_stationary' | 'systolic'
    temporal_layer_pipe: bool = True
    spatial_layer_pipe: bool = True
    bytes_per_elem: int = 2
    # independent DRAM channels/ports. Estimator-only: the optimistic
    # lower bounds (estimate.py / estimate_batch.py) see an aggregate
    # off-chip bandwidth of dram.bandwidth_bytes_per_cycle * dram_ports;
    # the detailed judges keep modeling a single port pool.
    dram_ports: int = 1

    def __post_init__(self) -> None:
        if self.pe_dataflow not in ("row_stationary", "systolic"):
            raise ValueError(f"unknown pe_dataflow {self.pe_dataflow!r}")

    @property
    def regf(self) -> MemLevel:
        return self.levels[0]

    @property
    def gbuf(self) -> MemLevel:
        return self.levels[1]

    @property
    def dram(self) -> MemLevel:
        return self.levels[-1]

    @property
    def pe_array(self) -> Tuple[int, int]:
        return self.levels[1].array

    @property
    def node_array(self) -> Tuple[int, int]:
        return self.levels[-1].array

    @property
    def num_pes_per_node(self) -> int:
        return self.levels[1].num_units

    @property
    def num_nodes(self) -> int:
        return self.levels[-1].num_units

    @property
    def total_pes(self) -> int:
        return self.num_pes_per_node * self.num_nodes

    def avg_noc_hops(self, nodes_used):
        """Mean Manhattan hop count within a roughly-square region.

        Accepts a scalar or an array of node counts (the batched cost model
        scores many candidates at once) — keep this the single definition of
        the NoC hop formula for both the scalar and vectorized judges."""
        side = np.maximum(1.0, np.asarray(nodes_used, dtype=float) ** 0.5)
        hops = 2.0 * side / 3.0
        return float(hops) if np.ndim(nodes_used) == 0 else hops

    def with_(self, **updates) -> "HWTemplate":
        return dataclasses.replace(self, **updates)


# ---------------------------------------------------------------------------
# TPU-pod abstraction used by the JAX half of the framework (Half B).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TPUPodSpec:
    """Roofline constants for the production target (per grading spec)."""

    name: str = "tpu_v5e_pod"
    peak_flops_bf16: float = 197e12          # per chip
    hbm_bw: float = 819e9                    # bytes/s per chip
    hbm_bytes: float = 16 * 2 ** 30          # per chip
    ici_link_bw: float = 50e9                # bytes/s per link
    ici_links_per_chip: int = 4              # 2D torus (v5e)
    dci_bw: float = 25e9                     # bytes/s per chip, pod-to-pod
    vmem_bytes: float = 128 * 2 ** 20 / 8    # ~16 MiB usable VMEM
    mxu_tile: Tuple[int, int] = (128, 128)
