"""Schedule service: the tier above solver and lowering.

Three cooperating pieces turn the solver + lowering stack into a system
that *keeps* its winners and serves them to many concurrent clients:

  store
      ``store.ScheduleStore`` — a persistent, content-addressed schedule
      store: canonical signatures of (graph, hardware, solver options)
      built on the packed per-layer arrays the inter-layer solver itself
      consumes (``signature.schedule_signature``), an on-disk JSON layout
      with atomic writes, versioned records wrapping
      ``NetworkSchedule.to_json``, and hit/miss/eviction stats.  A
      *family* signature (batch-size stripped) lets a near-miss — same
      graph, different batch — seed a warm-start solve instead of a cold
      one.
  serve
      ``server.SolveServer`` + ``client.LocalClient`` — an async batched
      solve front-end: clients enqueue ``SolveRequest``s, a coalescing
      loop dedupes identical in-flight signatures, batches distinct
      segments across requests into the solver's ThreadPoolExecutor path
      (``kapla.solve_many``), and answers from the store when fresh.
      ``python -m repro.service`` exposes solve | get | stats | warm |
      autotune verbs.
  autotune
      ``autotune.autotune_network`` — measured re-ranking: the k best
      chains from ``kapla.solve_topk`` are each lowered
      (``lower_network``) and executed (``netexec``), and the
      measured-fastest schedule is promoted into the store with its
      measured latency recorded alongside the predicted cost.
"""
from .signature import family_signature, schedule_signature, solver_options
from .store import ScheduleStore, StoreError, StoreRecord
from .client import (LocalClient, ServiceError, ServiceResult,
                     SolveRequest, StoreGuard, attach_mesh_plan,
                     resolve_request)
from .server import SolveServer, serve_batch, serve_batch_settled
from .autotune import autotune_network

__all__ = [
    "family_signature", "schedule_signature", "solver_options",
    "ScheduleStore", "StoreError", "StoreRecord",
    "LocalClient", "ServiceError", "ServiceResult", "SolveRequest",
    "StoreGuard", "attach_mesh_plan", "resolve_request",
    "SolveServer", "serve_batch", "serve_batch_settled",
    "autotune_network",
]
