"""Measured top-k autotuning: re-rank the solver's best-k schedules by
real (executed) runtime and promote the measured winner into the store.

The analytical model picks an argmin; the autotuner checks it: the k best
valid chains from ``kapla.solve_topk`` are each compiled to a
``NetworkPlan`` (``lower_network``), executed end-to-end through the
Pallas network executor (``netexec``), verified against the whole-graph
reference pass, and timed.  The measured-fastest schedule is written to
the store for the request's signature with its measured latency recorded
alongside the predicted cost — subsequent ``solve`` hits serve the
schedule that actually ran fastest, not merely the one predicted to.

Rank agreement between predicted and measured latency across the
candidates (Spearman) is the per-request trust signal, the service-tier
counterpart of the calibration sweeps in ``repro.lower.calibrate``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from ..core.solver.kapla import solve_topk
from ..hw.template import HWTemplate
from ..workloads.layers import LayerGraph
from .signature import schedule_signature, solver_options
from .store import ScheduleStore


def autotune_network(graph: LayerGraph, hw: HWTemplate,
                     store: Optional[ScheduleStore] = None, k: int = 3,
                     iters: int = 2, interpret: bool = True, seed: int = 0,
                     max_workers: Optional[int] = None,
                     tol: float = 1e-3, **options) -> Dict:
    """Autotune one network; returns a JSON-safe report.  Candidates that
    fail to lower or verify are skipped with reasons — the report's
    ``candidates`` are the ones that really executed."""
    # execution lives behind jax; keep the service core numpy-only
    from ..lower.calibrate import spearman
    from ..lower.netexec import (compare_network, make_network_inputs,
                                 measure_network, network_runner)
    from ..lower.netplan import lower_network

    opts = solver_options(**options)
    t0 = time.perf_counter()
    cands = solve_topk(graph, hw, k=k, max_workers=max_workers, **opts)
    entries: List[Dict] = []
    skipped: List[Dict] = []
    for rank, sched in enumerate(cands):
        nplan = lower_network(sched, graph, hw)
        bad = nplan.invalid_layers()
        if bad:
            skipped.append({"rank": rank, "reason": "; ".join(
                f"{n}: {r}" for n, r in bad)})
            continue
        inputs = make_network_inputs(nplan, seed)
        run = network_runner(nplan, inputs, interpret=interpret, jit=True)
        ver = compare_network(nplan, run(), inputs, tol)
        if not ver.ok:
            skipped.append({"rank": rank,
                            "reason": f"numerics {ver.max_rel_err:.2e} at "
                                      f"{ver.worst_layer}"})
            continue
        measured = measure_network(nplan, iters=iters, warmup=0,
                                   runner=run)
        entries.append({
            "rank": rank,
            "n_segments": 0 if sched.chain is None
            else len(sched.chain.segments),
            "predicted_cycles": sched.total_latency_cycles,
            "predicted_energy_pj": sched.total_energy_pj,
            "max_rel_err": ver.max_rel_err,
            "measured_seconds": measured,
        })
    report: Dict = {
        "net": graph.name,
        "hw": hw.name,
        "options": opts,
        "k_requested": k,
        "n_candidates": len(cands),
        "n_executed": len(entries),
        "candidates": entries,
        "skipped": skipped,
        "autotune_seconds": time.perf_counter() - t0,
    }
    if not entries:
        return report
    preds = [e["predicted_cycles"] for e in entries]
    if len(entries) >= 2 and len(set(preds)) > 1:
        report["rank_agreement"] = spearman(
            preds, [e["measured_seconds"] for e in entries])
    elif len(entries) >= 2:
        # all candidates predicted exactly equal: rank agreement is
        # undefined, not zero
        report["rank_agreement"] = None
    best = min(entries, key=lambda e: e["measured_seconds"])
    argmin = next((e for e in entries if e["rank"] == 0), None)
    report["promoted_rank"] = best["rank"]
    report["promoted_measured_seconds"] = best["measured_seconds"]
    if argmin is not None:
        report["argmin_measured_seconds"] = argmin["measured_seconds"]
    sig = schedule_signature(graph, hw, opts)
    report["signature"] = sig
    if store is not None:
        measured_meta = {
            "measured_seconds": best["measured_seconds"],
            "predicted_cycles": best["predicted_cycles"],
            "rank": best["rank"],
            "backend": "interpret" if interpret else "compiled",
            "rank_agreement": report.get("rank_agreement"),
            "n_candidates_executed": len(entries),
        }
        store.put(cands[best["rank"]], graph, hw, opts, sig=sig,
                  measured=measured_meta)
        report["promoted"] = True
    return report


__all__ = ["autotune_network"]
