"""Measured top-k autotuning: re-rank the solver's best-k schedules by
real (executed) runtime and promote the measured winner into the store.

The analytical model picks an argmin; the autotuner checks it: the k best
valid chains from ``kapla.solve_topk`` are each compiled to a
``NetworkPlan`` (``lower_network``), executed end-to-end through the
Pallas network executor (``netexec``), verified against the whole-graph
reference pass, and timed.  The measured-fastest schedule is written to
the store for the request's signature with its measured latency recorded
alongside the predicted cost — subsequent ``solve`` hits serve the
schedule that actually ran fastest, not merely the one predicted to.

Rank agreement between predicted and measured latency across the
candidates (Spearman) is the per-request trust signal, the service-tier
counterpart of the calibration sweeps in ``repro.lower.calibrate``.
"""
from __future__ import annotations

import math
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeout
from typing import Dict, List, Optional

from ..core.solver.kapla import solve_topk
from ..hw.template import HWTemplate
from ..obs import trace
from ..runtime import inject
from ..workloads.layers import LayerGraph
from .signature import schedule_signature, solver_options
from .store import ScheduleStore


class _Skip(Exception):
    """Internal: candidate disqualified for a recorded reason."""


def _run_candidate(rank: int, sched, graph: LayerGraph, hw: HWTemplate,
                   seed: int, iters: int, backend: str,
                   tol: float) -> Dict:
    """Lower + verify + measure one candidate (raises ``_Skip`` with the
    disqualification reason).  Runs inside the per-candidate worker so a
    timeout can abandon it."""
    with trace.span("autotune.candidate", rank=rank, graph=graph.name,
                    backend=backend):
        return _run_candidate_impl(rank, sched, graph, hw, seed, iters,
                                   backend, tol)


def _run_candidate_impl(rank: int, sched, graph: LayerGraph,
                        hw: HWTemplate, seed: int, iters: int,
                        backend: str, tol: float) -> Dict:
    # execution lives behind jax; keep the service core numpy-only
    from ..lower.netexec import (compare_network, make_network_inputs,
                                 measure_network, network_runner)
    from ..lower.netplan import lower_network

    # chaos hook: slow sleeps here (counts against the candidate
    # timeout), error raises, nan poisons the measurement below
    spec = inject.maybe_fault("autotune.measure", key=str(rank))
    nplan = lower_network(sched, graph, hw)
    bad = nplan.invalid_layers()
    if bad:
        raise _Skip("; ".join(f"{n}: {r}" for n, r in bad))
    inputs = make_network_inputs(nplan, seed)
    run = network_runner(nplan, inputs, jit=True, backend=backend)
    ver = compare_network(nplan, run(), inputs, tol)
    if not ver.ok:
        raise _Skip(f"numerics {ver.max_rel_err:.2e} at "
                    f"{ver.worst_layer}")
    measured = measure_network(
        nplan, iters=iters, warmup=0, runner=run,
        predicted_seconds=sched.total_latency_cycles / hw.freq_hz,
        drift_source="autotune", backend=backend)
    if spec is not None and spec.kind == "nan":
        measured = float("nan")
    return {
        "rank": rank,
        "n_segments": 0 if sched.chain is None
        else len(sched.chain.segments),
        "predicted_cycles": sched.total_latency_cycles,
        "predicted_energy_pj": sched.total_energy_pj,
        "max_rel_err": ver.max_rel_err,
        "measured_seconds": measured,
    }


def autotune_network(graph: LayerGraph, hw: HWTemplate,
                     store: Optional[ScheduleStore] = None, k: int = 3,
                     iters: int = 2, interpret: Optional[bool] = None,
                     seed: int = 0,
                     max_workers: Optional[int] = None,
                     tol: float = 1e-3,
                     candidate_timeout_s: Optional[float] = None,
                     backend: Optional[str] = None,
                     explain: bool = False,
                     **options) -> Dict:
    """Autotune one network; returns a JSON-safe report.  Candidates that
    fail to lower or verify — or that crash, return a non-finite
    measurement, or exceed ``candidate_timeout_s`` — are disqualified
    with a recorded reason instead of aborting the run; the report's
    ``candidates`` are the ones that really executed.

    Measured re-ranking runs on the fused compiled tier by default
    (``backend=None`` + ``interpret=None`` resolves to the process
    default): top-k candidates of the same graph share a plan-signature
    keyed executable cache, so re-measuring a candidate never re-traces.
    Pass ``backend="interpret"`` (or legacy ``interpret=True``) to rank
    on the bit-accuracy oracle instead."""
    from ..kernels.backend import resolve_backend
    from ..lower.calibrate import spearman

    backend = resolve_backend(backend, interpret)

    opts = solver_options(**options)
    t0 = time.perf_counter()
    cands = solve_topk(graph, hw, k=k, max_workers=max_workers,
                       explain=explain, **opts)
    entries: List[Dict] = []
    skipped: List[Dict] = []
    for rank, sched in enumerate(cands):
        try:
            if candidate_timeout_s is None:
                entry = _run_candidate(rank, sched, graph, hw, seed,
                                       iters, backend, tol)
            else:
                # a fresh single-thread pool per candidate: a hung
                # measurement is abandoned (the thread leaks until it
                # returns, the run does not)
                ex = ThreadPoolExecutor(max_workers=1)
                try:
                    entry = ex.submit(
                        _run_candidate, rank, sched, graph, hw, seed,
                        iters, backend, tol
                    ).result(timeout=candidate_timeout_s)
                finally:
                    ex.shutdown(wait=False)
        except _Skip as e:
            skipped.append({"rank": rank, "reason": str(e)})
            continue
        except FutureTimeout:
            skipped.append({"rank": rank, "reason":
                            f"timeout after {candidate_timeout_s}s"})
            continue
        except Exception as e:          # crash disqualifies, never aborts
            skipped.append({"rank": rank, "reason": f"crashed: {e!r}"})
            continue
        if not math.isfinite(entry["measured_seconds"]):
            skipped.append({"rank": rank, "reason":
                            "non-finite measurement"})
            continue
        entries.append(entry)
    report: Dict = {
        "net": graph.name,
        "hw": hw.name,
        "options": opts,
        "k_requested": k,
        "n_candidates": len(cands),
        "n_executed": len(entries),
        "candidates": entries,
        "skipped": skipped,
        "autotune_seconds": time.perf_counter() - t0,
    }
    if not entries:
        return report
    preds = [e["predicted_cycles"] for e in entries]
    if len(entries) >= 2 and len(set(preds)) > 1:
        report["rank_agreement"] = spearman(
            preds, [e["measured_seconds"] for e in entries])
    elif len(entries) >= 2:
        # all candidates predicted exactly equal: rank agreement is
        # undefined, not zero
        report["rank_agreement"] = None
    best = min(entries, key=lambda e: e["measured_seconds"])
    argmin = next((e for e in entries if e["rank"] == 0), None)
    report["promoted_rank"] = best["rank"]
    report["promoted_measured_seconds"] = best["measured_seconds"]
    if argmin is not None:
        report["argmin_measured_seconds"] = argmin["measured_seconds"]
    sig = schedule_signature(graph, hw, opts)
    report["signature"] = sig
    if store is not None:
        measured_meta = {
            "measured_seconds": best["measured_seconds"],
            "predicted_cycles": best["predicted_cycles"],
            "rank": best["rank"],
            "backend": backend,
            "rank_agreement": report.get("rank_agreement"),
            "n_candidates_executed": len(entries),
        }
        try:
            store.put(cands[best["rank"]], graph, hw, opts, sig=sig,
                      measured=measured_meta)
            report["promoted"] = True
        except Exception as e:      # a broken store loses the promotion,
            report["promoted"] = False      # never the measurements
            report["promote_error"] = repr(e)
    return report


__all__ = ["autotune_network"]
