"""Persistent, content-addressed schedule store.

On-disk layout (sqlite-free, human-inspectable) under one store dir:

    <root>/
      records/<signature>.json      one versioned record per solve
      index.jsonl                   append-only put log (sig, family,
                                    graph, batch, timestamp)

Records wrap ``NetworkSchedule.to_json`` with the signature, the family
signature, the normalized solver options, hardware name and the layer
order, plus an optional ``measured`` block the autotuner fills in when it
promotes a measured-fastest schedule.  All writes are atomic (temp file +
``os.replace``; index appends are single short lines), so a killed writer
never leaves a torn record.

Reads are content-addressed: ``get(signature)`` either misses or returns
a schedule that re-scores bit-identically to the original solve
(parity-tested).  A graph whose layer *names* differ from the stored ones
(same signature — signatures never see names) is re-bound positionally.
``warm_records(family)`` returns near-misses — same graph family,
different batch — whose chains can seed a warm-start solve
(``kapla.seed_chains_from``).

Eviction is LRU over record-file mtimes (hits refresh the mtime), bounded
by ``max_entries``; hit/miss/eviction counts are exposed via ``stats()``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time
from typing import Dict, List, Mapping, Optional, Sequence

from ..core.solver.kapla import NetworkSchedule
from ..hw.template import HWTemplate
from ..workloads.layers import LayerGraph
from .signature import family_signature, schedule_signature, solver_options

STORE_VERSION = 1
#: default store dir (overridable per-store or via REPRO_STORE_DIR)
DEFAULT_ROOT = os.environ.get("REPRO_STORE_DIR", ".repro_store")


@dataclasses.dataclass
class StoreRecord:
    """One versioned store entry (the JSON record, typed)."""

    signature: str
    family: str
    graph_name: str
    batch: int
    options: Dict
    hw_name: str
    created: float
    predicted_energy_pj: float
    predicted_latency_cycles: float
    layer_order: List[str]
    schedule: Dict                      # NetworkSchedule.to_json()
    measured: Optional[Dict] = None     # autotune promotion metadata
    version: int = STORE_VERSION

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Mapping) -> "StoreRecord":
        known = {f.name for f in dataclasses.fields(StoreRecord)}
        return StoreRecord(**{k: v for k, v in d.items() if k in known})


def _graph_batch(graph: LayerGraph) -> int:
    return graph.layers[0].dim("N") if graph.layers else 1


def _atomic_write(path: str, text: str) -> None:
    d = os.path.dirname(path)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


class ScheduleStore:
    """Content-addressed schedule store rooted at ``root`` (created on
    first use).  Thread-compatible for the in-process server: all state
    lives on disk; counters are advisory."""

    def __init__(self, root: str = DEFAULT_ROOT, max_entries: int = 512):
        self.root = root
        self.records_dir = os.path.join(root, "records")
        self.index_path = os.path.join(root, "index.jsonl")
        os.makedirs(self.records_dir, exist_ok=True)
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.warm_hits = 0
        # family -> [signatures], replayed from the index, filtered to
        # records that still exist (evicted entries drop out naturally)
        self._family: Dict[str, List[str]] = {}
        self._replay_index()

    # -- signatures (convenience passthroughs) -------------------------------
    def signature(self, graph: LayerGraph, hw: HWTemplate,
                  options: Optional[Mapping] = None) -> str:
        return schedule_signature(graph, hw, options)

    def family(self, graph: LayerGraph, hw: HWTemplate,
               options: Optional[Mapping] = None) -> str:
        return family_signature(graph, hw, options)

    # -- paths / existence ---------------------------------------------------
    def _rec_path(self, sig: str) -> str:
        return os.path.join(self.records_dir, f"{sig}.json")

    def has(self, sig: str) -> bool:
        return os.path.exists(self._rec_path(sig))

    def __len__(self) -> int:
        return sum(1 for n in os.listdir(self.records_dir)
                   if n.endswith(".json"))

    def signatures(self) -> List[str]:
        return sorted(n[:-5] for n in os.listdir(self.records_dir)
                      if n.endswith(".json"))

    # -- index ---------------------------------------------------------------
    def _replay_index(self) -> None:
        if not os.path.exists(self.index_path):
            return
        with open(self.index_path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    e = json.loads(line)
                except ValueError:
                    continue                    # torn tail line: skip
                if self.has(e.get("sig", "")):
                    fam = self._family.setdefault(e.get("family", ""), [])
                    if e["sig"] not in fam:
                        fam.append(e["sig"])

    def _index_append(self, entry: Dict) -> None:
        with open(self.index_path, "a") as f:
            f.write(json.dumps(entry) + "\n")

    # -- core API ------------------------------------------------------------
    def get_record(self, sig: str) -> Optional[StoreRecord]:
        path = self._rec_path(sig)
        try:
            with open(path) as f:
                rec = StoreRecord.from_json(json.load(f))
        except (OSError, ValueError, TypeError):
            self.misses += 1
            return None
        self.hits += 1
        now = time.time()
        os.utime(path, (now, now))              # LRU touch
        return rec

    def get(self, sig: str, graph: Optional[LayerGraph] = None
            ) -> Optional[NetworkSchedule]:
        """The stored schedule for ``sig``, re-bound to ``graph`` when
        given (positionally if the graph's layer names differ from the
        stored ones — signatures are name-insensitive)."""
        rec = self.get_record(sig)
        if rec is None:
            return None
        return self._bind(rec, graph)

    def _bind(self, rec: StoreRecord, graph: Optional[LayerGraph]
              ) -> NetworkSchedule:
        sj = rec.schedule
        if graph is None:
            return NetworkSchedule.from_json(sj)
        names = list(sj["layer_schemes"].keys())
        if all(n in graph.by_name for n in names):
            return NetworkSchedule.from_json(sj, graph)
        if len(names) != len(graph.layers):
            raise ValueError(
                f"record {rec.signature[:12]} has {len(names)} layers, "
                f"graph {graph.name!r} has {len(graph.layers)}")
        # positional re-bind: stored order is the solve's topological
        # order, which the signature guarantees matches the graph's
        order = rec.layer_order or names
        mapping = {old: l.name for old, l in zip(order, graph.layers)}
        sj = dict(sj)
        sj["graph_name"] = graph.name
        sj["layer_schemes"] = {mapping[n]: v
                               for n, v in sj["layer_schemes"].items()}
        sj["layer_costs"] = {mapping[n]: v
                             for n, v in sj.get("layer_costs", {}).items()}
        return NetworkSchedule.from_json(sj, graph)

    def put(self, schedule: NetworkSchedule, graph: LayerGraph,
            hw: HWTemplate, options: Optional[Mapping] = None,
            sig: Optional[str] = None, family: Optional[str] = None,
            measured: Optional[Dict] = None) -> StoreRecord:
        """Insert (or overwrite) the record for one solved schedule;
        returns the written record.  Invalid schedules are refused."""
        if not schedule.valid:
            raise ValueError("refusing to store an invalid schedule")
        opts = solver_options(**dict(options or {}))
        sig = sig if sig is not None else self.signature(graph, hw, opts)
        family = family if family is not None \
            else self.family(graph, hw, opts)
        rec = StoreRecord(
            signature=sig, family=family, graph_name=graph.name,
            batch=_graph_batch(graph), options=opts, hw_name=hw.name,
            created=time.time(),
            predicted_energy_pj=schedule.total_energy_pj,
            predicted_latency_cycles=schedule.total_latency_cycles,
            layer_order=[l.name for l in graph.layers],
            schedule=schedule.to_json(), measured=measured)
        _atomic_write(self._rec_path(sig), json.dumps(rec.to_json(),
                                                      indent=1))
        self._index_append({"sig": sig, "family": family,
                            "graph": graph.name, "batch": rec.batch,
                            "t": rec.created})
        fam = self._family.setdefault(family, [])
        if sig not in fam:
            fam.append(sig)
        self._evict_to_capacity()
        return rec

    # -- warm-start near-misses ----------------------------------------------
    def warm_records(self, family: str, exclude: Sequence[str] = ()
                     ) -> List[StoreRecord]:
        """Records in the same graph family (same layers/hardware/options,
        different batch), newest first — warm-start seeds."""
        out: List[StoreRecord] = []
        for sig in reversed(self._family.get(family, [])):
            if sig in exclude or not self.has(sig):
                continue
            try:
                with open(self._rec_path(sig)) as f:
                    out.append(StoreRecord.from_json(json.load(f)))
            except (OSError, ValueError, TypeError):
                continue
        if out:
            self.warm_hits += 1
        return out

    # -- eviction ------------------------------------------------------------
    def _evict_to_capacity(self) -> None:
        names = [n for n in os.listdir(self.records_dir)
                 if n.endswith(".json")]
        if len(names) <= self.max_entries:
            return
        paths = [os.path.join(self.records_dir, n) for n in names]
        paths.sort(key=lambda p: os.path.getmtime(p))   # oldest first
        for p in paths[:len(paths) - self.max_entries]:
            try:
                os.unlink(p)
                self.evictions += 1
            except OSError:
                pass
        # drop evicted sigs from the family map
        for fam, sigs in self._family.items():
            self._family[fam] = [s for s in sigs if self.has(s)]

    # -- stats ---------------------------------------------------------------
    def stats(self) -> Dict:
        return {"root": self.root, "entries": len(self),
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "warm_hits": self.warm_hits,
                "families": sum(1 for v in self._family.values() if v)}


__all__ = ["ScheduleStore", "StoreRecord", "STORE_VERSION", "DEFAULT_ROOT"]
