"""Persistent, content-addressed schedule store — with self-healing.

On-disk layout (sqlite-free, human-inspectable) under one store dir:

    <root>/
      records/<signature>.json      one versioned record per solve
      index.jsonl                   append-only put log (sig, family,
                                    graph, batch, timestamp)
      quarantine/<signature>.json   corrupt records, moved aside on read

Records wrap ``NetworkSchedule.to_json`` with the signature, the family
signature, the normalized solver options, hardware name, the layer
order, a sha256 ``checksum`` over the record body, plus an optional
``measured`` block the autotuner fills in when it promotes a
measured-fastest schedule.  All writes are atomic (temp file +
``os.replace``; index appends are single short lines), so a killed
writer never leaves a torn record.

Failure semantics (the resilience contract the service tier builds on):

* a **missing** record is a miss (``None``);
* a **corrupt** record (unparseable JSON, checksum mismatch, wrong
  shape) is quarantined to ``<root>/quarantine/`` — never silently
  re-read — and reads as a miss; ``corrupt``/``quarantined`` counters
  track it;
* a **store I/O failure** (disk error, injected fault) raises the typed
  ``StoreError`` so callers (the server's circuit breaker) can degrade
  to solve-without-caching instead of crashing;
* a **damaged index** (torn tail from a killed appender, garbage bytes)
  is rebuilt from the records dir on open — records are the source of
  truth, the index is a cache; stale ``*.tmp`` files from killed writers
  are swept on open.  Killing a ``put`` mid-write therefore always
  leaves a store that loads clean.

Reads are content-addressed: ``get(signature)`` either misses or returns
a schedule that re-scores bit-identically to the original solve
(parity-tested).  A graph whose layer *names* differ from the stored ones
(same signature — signatures never see names) is re-bound positionally.
``warm_records(family)`` returns near-misses — same graph family,
different batch — whose chains can seed a warm-start solve
(``kapla.seed_chains_from``).

Eviction is LRU over record-file mtimes (hits refresh the mtime), bounded
by ``max_entries``; hit/miss/eviction counts are exposed via ``stats()``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import time
from typing import Dict, List, Mapping, Optional, Sequence

from ..core.solver.kapla import NetworkSchedule
from ..hw.template import HWTemplate
from ..obs import metrics
from ..runtime import inject
from ..workloads.layers import LayerGraph
from .signature import family_signature, schedule_signature, solver_options

STORE_VERSION = 2
#: default store dir (overridable per-store or via REPRO_STORE_DIR)
DEFAULT_ROOT = os.environ.get("REPRO_STORE_DIR", ".repro_store")


class StoreError(RuntimeError):
    """A store I/O failure (not a miss, not corruption): the record may
    be fine but the store could not be reached.  The server's circuit
    breaker counts these and degrades to solve-without-caching."""


class _Corrupt(ValueError):
    """Internal: a record that parsed wrongly or failed its checksum."""


@dataclasses.dataclass
class StoreRecord:
    """One versioned store entry (the JSON record, typed)."""

    signature: str
    family: str
    graph_name: str
    batch: int
    options: Dict
    hw_name: str
    created: float
    predicted_energy_pj: float
    predicted_latency_cycles: float
    layer_order: List[str]
    schedule: Dict                      # NetworkSchedule.to_json()
    measured: Optional[Dict] = None     # autotune promotion metadata
    version: int = STORE_VERSION
    checksum: Optional[str] = None      # sha256 over the body (see below)

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Mapping) -> "StoreRecord":
        known = {f.name for f in dataclasses.fields(StoreRecord)}
        return StoreRecord(**{k: v for k, v in d.items() if k in known})


def record_checksum(d: Mapping) -> str:
    """sha256 over the canonical JSON of the record minus its
    ``checksum`` field — what ``put`` stamps and reads verify."""
    body = {k: v for k, v in d.items() if k != "checksum"}
    return hashlib.sha256(
        json.dumps(body, sort_keys=True).encode()).hexdigest()


def _graph_batch(graph: LayerGraph) -> int:
    return graph.layers[0].dim("N") if graph.layers else 1


def _atomic_write(path: str, text: str) -> None:
    d = os.path.dirname(path)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


class ScheduleStore:
    """Content-addressed schedule store rooted at ``root`` (created on
    first use).  Thread-compatible for the in-process server: all state
    lives on disk; counters are advisory."""

    def __init__(self, root: str = DEFAULT_ROOT, max_entries: int = 512):
        self.root = root
        self.records_dir = os.path.join(root, "records")
        self.index_path = os.path.join(root, "index.jsonl")
        self.quarantine_dir = os.path.join(root, "quarantine")
        os.makedirs(self.records_dir, exist_ok=True)
        self.max_entries = max_entries
        # per-instance counters mirrored into the process registry as
        # store_events_total{event=...} (repro.obs.metrics)
        self._events = metrics.CounterGroup("store", (
            "reads", "writes", "hits", "misses", "evictions",
            "warm_hits", "corrupt", "quarantined", "io_errors",
            "rebuilds"))
        # family -> [signatures], replayed from the index, filtered to
        # records that still exist (evicted entries drop out naturally)
        self._family: Dict[str, List[str]] = {}
        self._sweep_tmp()
        damaged = self._replay_index()
        if damaged or (len(self) > 0 and not os.path.exists(self.index_path)):
            self.rebuild_index()

    # -- counter views (the numbers live in obs.metrics via CounterGroup) ----
    @property
    def hits(self) -> int:
        return self._events["hits"]

    @property
    def misses(self) -> int:
        return self._events["misses"]

    @property
    def evictions(self) -> int:
        return self._events["evictions"]

    @property
    def warm_hits(self) -> int:
        return self._events["warm_hits"]

    @property
    def corrupt(self) -> int:
        return self._events["corrupt"]

    @property
    def quarantined(self) -> int:
        return self._events["quarantined"]

    @property
    def io_errors(self) -> int:
        return self._events["io_errors"]

    @property
    def rebuilds(self) -> int:
        return self._events["rebuilds"]

    # -- signatures (convenience passthroughs) -------------------------------
    def signature(self, graph: LayerGraph, hw: HWTemplate,
                  options: Optional[Mapping] = None) -> str:
        return schedule_signature(graph, hw, options)

    def family(self, graph: LayerGraph, hw: HWTemplate,
               options: Optional[Mapping] = None) -> str:
        return family_signature(graph, hw, options)

    # -- paths / existence ---------------------------------------------------
    def _rec_path(self, sig: str) -> str:
        return os.path.join(self.records_dir, f"{sig}.json")

    def has(self, sig: str) -> bool:
        return os.path.exists(self._rec_path(sig))

    def __len__(self) -> int:
        return sum(1 for n in os.listdir(self.records_dir)
                   if n.endswith(".json"))

    def signatures(self) -> List[str]:
        return sorted(n[:-5] for n in os.listdir(self.records_dir)
                      if n.endswith(".json"))

    # -- crash hygiene -------------------------------------------------------
    def _sweep_tmp(self) -> None:
        """Remove temp files a killed writer left behind (``put`` is
        tmp + ``os.replace``; a crash between the two strands a tmp)."""
        for d in (self.records_dir, self.root):
            try:
                names = os.listdir(d)
            except OSError:
                continue
            for n in names:
                if n.endswith(".tmp"):
                    try:
                        os.unlink(os.path.join(d, n))
                    except OSError:
                        pass

    def _quarantine(self, sig: str) -> None:
        """Move a corrupt record aside (never silently re-read it)."""
        self._events.inc("corrupt")
        path = self._rec_path(sig)
        try:
            os.makedirs(self.quarantine_dir, exist_ok=True)
            os.replace(path, os.path.join(self.quarantine_dir,
                                          f"{sig}.json"))
            self._events.inc("quarantined")
        except OSError:
            # quarantine is best-effort; at worst the next read re-detects
            pass
        for fam, sigs in self._family.items():
            if sig in sigs:
                self._family[fam] = [s for s in sigs if s != sig]

    # -- index ---------------------------------------------------------------
    def _replay_index(self) -> int:
        """Replay ``index.jsonl`` into the family map; returns the number
        of damaged (undecodable) lines so the caller can rebuild."""
        if not os.path.exists(self.index_path):
            return 0
        damaged = 0
        try:
            with open(self.index_path) as f:
                lines = f.readlines()
        except OSError:
            return 1
        for line in lines:
            line = line.strip()
            if not line:
                continue
            try:
                e = json.loads(line)
                sig, fam = e["sig"], e["family"]
            except (ValueError, TypeError, KeyError):
                damaged += 1                # torn tail or garbage
                continue
            if self.has(sig):
                sigs = self._family.setdefault(fam, [])
                if sig not in sigs:
                    sigs.append(sig)
        return damaged

    def rebuild_index(self) -> int:
        """Rebuild ``index.jsonl`` and the family map from the records
        dir — records are the source of truth, the index is a cache.
        Corrupt records found on the way are quarantined.  Returns the
        number of indexed records."""
        self._family = {}
        entries: List[str] = []
        for sig in self.signatures():
            try:
                rec = self._read_record(sig)
            except _Corrupt:
                self._quarantine(sig)
                continue
            except StoreError:
                continue
            if rec is None:
                continue
            entries.append(json.dumps(
                {"sig": rec.signature, "family": rec.family,
                 "graph": rec.graph_name, "batch": rec.batch,
                 "t": rec.created}) + "\n")
            sigs = self._family.setdefault(rec.family, [])
            if rec.signature not in sigs:
                sigs.append(rec.signature)
        try:
            _atomic_write(self.index_path, "".join(entries))
        except OSError as e:
            raise StoreError(f"index rebuild failed: {e}") from e
        self._events.inc("rebuilds")
        return len(entries)

    def _index_append(self, entry: Dict) -> None:
        spec = inject.maybe_fault("store.index", key=entry.get("sig", ""))
        line = json.dumps(entry) + "\n"
        if spec is not None and spec.kind == "corrupt":
            line = line[:max(1, len(line) // 2)]    # torn appender
        try:
            with open(self.index_path, "a") as f:
                f.write(line)
        except OSError as e:
            raise StoreError(f"index append failed: {e}") from e

    # -- record I/O ----------------------------------------------------------
    def _read_record(self, sig: str) -> Optional[StoreRecord]:
        """Read + verify one record.  None on a miss; ``_Corrupt`` on a
        damaged record (caller quarantines); ``StoreError`` on I/O
        failure."""
        path = self._rec_path(sig)
        try:
            spec = inject.maybe_fault("store.read", key=sig)
        except inject.InjectedFault as e:
            self._events.inc("io_errors")
            raise StoreError(str(e)) from e
        if spec is not None and spec.kind == "corrupt":
            inject.truncate_file(path)
        self._events.inc("reads")
        try:
            with open(path) as f:
                d = json.load(f)
        except FileNotFoundError:
            return None
        except OSError as e:
            self._events.inc("io_errors")
            raise StoreError(f"record read failed: {e}") from e
        except ValueError as e:
            raise _Corrupt(f"unparseable record {sig[:12]}: {e}") from e
        try:
            rec = StoreRecord.from_json(d)
        except TypeError as e:
            raise _Corrupt(f"malformed record {sig[:12]}: {e}") from e
        if rec.checksum is not None and record_checksum(d) != rec.checksum:
            raise _Corrupt(f"checksum mismatch on {sig[:12]}")
        return rec

    # -- core API ------------------------------------------------------------
    def get_record(self, sig: str) -> Optional[StoreRecord]:
        try:
            rec = self._read_record(sig)
        except _Corrupt:
            self._quarantine(sig)
            self._events.inc("misses")
            return None
        if rec is None:
            self._events.inc("misses")
            return None
        self._events.inc("hits")
        path = self._rec_path(sig)
        now = time.time()
        try:
            os.utime(path, (now, now))          # LRU touch
        except OSError:
            pass
        return rec

    def get(self, sig: str, graph: Optional[LayerGraph] = None
            ) -> Optional[NetworkSchedule]:
        """The stored schedule for ``sig``, re-bound to ``graph`` when
        given (positionally if the graph's layer names differ from the
        stored ones — signatures are name-insensitive)."""
        rec = self.get_record(sig)
        if rec is None:
            return None
        return self._bind(rec, graph)

    def _bind(self, rec: StoreRecord, graph: Optional[LayerGraph]
              ) -> NetworkSchedule:
        sj = rec.schedule
        if graph is None:
            return NetworkSchedule.from_json(sj)
        names = list(sj["layer_schemes"].keys())
        if all(n in graph.by_name for n in names):
            return NetworkSchedule.from_json(sj, graph)
        if len(names) != len(graph.layers):
            raise ValueError(
                f"record {rec.signature[:12]} has {len(names)} layers, "
                f"graph {graph.name!r} has {len(graph.layers)}")
        # positional re-bind: stored order is the solve's topological
        # order, which the signature guarantees matches the graph's
        order = rec.layer_order or names
        mapping = {old: l.name for old, l in zip(order, graph.layers)}
        sj = dict(sj)
        sj["graph_name"] = graph.name
        sj["layer_schemes"] = {mapping[n]: v
                               for n, v in sj["layer_schemes"].items()}
        sj["layer_costs"] = {mapping[n]: v
                             for n, v in sj.get("layer_costs", {}).items()}
        return NetworkSchedule.from_json(sj, graph)

    def put(self, schedule: NetworkSchedule, graph: LayerGraph,
            hw: HWTemplate, options: Optional[Mapping] = None,
            sig: Optional[str] = None, family: Optional[str] = None,
            measured: Optional[Dict] = None) -> StoreRecord:
        """Insert (or overwrite) the record for one solved schedule;
        returns the written record.  Invalid schedules are refused.
        Raises ``StoreError`` on I/O failure (the record is atomic: it is
        either fully written or absent)."""
        if not schedule.valid:
            raise ValueError("refusing to store an invalid schedule")
        opts = solver_options(**dict(options or {}))
        sig = sig if sig is not None else self.signature(graph, hw, opts)
        family = family if family is not None \
            else self.family(graph, hw, opts)
        rec = StoreRecord(
            signature=sig, family=family, graph_name=graph.name,
            batch=_graph_batch(graph), options=opts, hw_name=hw.name,
            created=time.time(),
            predicted_energy_pj=schedule.total_energy_pj,
            predicted_latency_cycles=schedule.total_latency_cycles,
            layer_order=[l.name for l in graph.layers],
            schedule=schedule.to_json(), measured=measured)
        d = rec.to_json()
        rec.checksum = d["checksum"] = record_checksum(d)
        try:
            spec = inject.maybe_fault("store.write", key=sig)
        except inject.InjectedFault as e:
            self._events.inc("io_errors")
            raise StoreError(str(e)) from e
        path = self._rec_path(sig)
        try:
            _atomic_write(path, json.dumps(d, indent=1))
        except OSError as e:
            self._events.inc("io_errors")
            raise StoreError(f"record write failed: {e}") from e
        self._events.inc("writes")
        if spec is not None and spec.kind == "corrupt":
            inject.truncate_file(path)          # writer killed mid-put
        self._index_append({"sig": sig, "family": family,
                            "graph": graph.name, "batch": rec.batch,
                            "t": rec.created})
        fam = self._family.setdefault(family, [])
        if sig not in fam:
            fam.append(sig)
        self._evict_to_capacity()
        return rec

    # -- warm-start near-misses ----------------------------------------------
    def warm_records(self, family: str, exclude: Sequence[str] = ()
                     ) -> List[StoreRecord]:
        """Records in the same graph family (same layers/hardware/options,
        different batch), newest first — warm-start seeds.  Corrupt
        records encountered on the way are quarantined and skipped;
        I/O failures raise ``StoreError``."""
        out: List[StoreRecord] = []
        for sig in list(reversed(self._family.get(family, []))):
            if sig in exclude or not self.has(sig):
                continue
            try:
                rec = self._read_record(sig)
            except _Corrupt:
                self._quarantine(sig)
                continue
            if rec is not None:
                out.append(rec)
        if out:
            self._events.inc("warm_hits")
        return out

    # -- eviction ------------------------------------------------------------
    def _evict_to_capacity(self) -> None:
        names = [n for n in os.listdir(self.records_dir)
                 if n.endswith(".json")]
        if len(names) <= self.max_entries:
            return
        paths = [os.path.join(self.records_dir, n) for n in names]
        paths.sort(key=lambda p: os.path.getmtime(p))   # oldest first
        for p in paths[:len(paths) - self.max_entries]:
            try:
                os.unlink(p)
                self._events.inc("evictions")
            except OSError:
                pass
        # drop evicted sigs from the family map
        for fam, sigs in self._family.items():
            self._family[fam] = [s for s in sigs if self.has(s)]

    # -- stats ---------------------------------------------------------------
    def stats(self) -> Dict:
        return {"root": self.root, "entries": len(self),
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "warm_hits": self.warm_hits,
                "corrupt": self.corrupt, "quarantined": self.quarantined,
                "io_errors": self.io_errors, "rebuilds": self.rebuilds,
                "families": sum(1 for v in self._family.values() if v)}


__all__ = ["ScheduleStore", "StoreRecord", "StoreError", "record_checksum",
           "STORE_VERSION", "DEFAULT_ROOT"]
