"""Canonical content signatures for (LayerGraph, HWTemplate, options).

A signature addresses one solve: two requests with the same signature are
guaranteed to see the same solver inputs, so the store can answer the
second from the first's schedule.  The signature is built from

  * the packed per-layer arrays the inter-layer solver actually consumes
    (``estimate_batch.pack_fingerprint`` — MACs, tensor sizes, energy
    terms, DRAM variants, producer/consumer index ranges);
  * each layer's canonical intra-layer signature
    (``memo.layer_signature`` — shape/tensor structure with the identity
    stripped) plus its exact source-edge *indices*;
  * every ``HWTemplate`` field, and the solver options.

It is insensitive exactly where the solver is: layer *names* never enter
(renaming a graph's layers reuses the cache), while layer *order* does
(the DP walks the topological list), as do batch size, hardware fields
and options.

The *family* signature additionally strips the batch dimension (every
layer's N pinned to 1, packed arrays dropped) — two requests in the same
family differ only in batch size, so a family near-miss can seed a
warm-start solve (``kapla.seed_chains_from``)."""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, Mapping, Optional

from ..core.estimate_batch import pack_fingerprint
from ..core.solver.interlayer import graph_pack
from ..core.solver.memo import layer_signature
from ..hw.template import HWTemplate
from ..workloads.layers import LayerGraph

#: options that change what ``kapla.solve`` computes (defaults mirror it)
DEFAULT_OPTIONS: Dict = {"k_s": 4, "max_seg_len": 4, "objective": "energy"}


def solver_options(**overrides) -> Dict:
    """Normalized solver-option dict: unknown keys rejected, defaults
    filled in, insertion order fixed — the canonical form both signatures
    and store records use."""
    bad = set(overrides) - set(DEFAULT_OPTIONS)
    if bad:
        raise ValueError(f"unknown solver options {sorted(bad)}")
    return {k: overrides.get(k, v) for k, v in DEFAULT_OPTIONS.items()}


def _hw_blob(hw: HWTemplate) -> bytes:
    return json.dumps(dataclasses.asdict(hw), sort_keys=True).encode()


def _edge_indices(graph: LayerGraph) -> list:
    idx = {l.name: i for i, l in enumerate(graph.layers)}
    return [sorted(idx[s] for s in l.src if s in idx)
            for l in graph.layers]


def schedule_signature(graph: LayerGraph, hw: HWTemplate,
                       options: Optional[Mapping] = None) -> str:
    """Content address of one solve request (hex sha256)."""
    opts = solver_options(**dict(options or {}))
    h = hashlib.sha256()
    h.update(pack_fingerprint(graph_pack(graph, hw)))
    for l in graph.layers:
        h.update(repr(layer_signature(l)).encode())
    h.update(json.dumps(_edge_indices(graph)).encode())
    h.update(_hw_blob(hw))
    h.update(json.dumps(opts, sort_keys=True).encode())
    return h.hexdigest()


def family_signature(graph: LayerGraph, hw: HWTemplate,
                     options: Optional[Mapping] = None) -> str:
    """Batch-insensitive signature: identical for two graphs that differ
    only in every layer's N dimension (the warm-start near-miss key)."""
    opts = solver_options(**dict(options or {}))
    h = hashlib.sha256()
    for l in graph.layers:
        dims = dict(l.dims)
        dims["N"] = 1
        nobatch = dataclasses.replace(l, dims=dims)
        h.update(repr(layer_signature(nobatch)).encode())
    h.update(json.dumps(_edge_indices(graph)).encode())
    h.update(_hw_blob(hw))
    h.update(json.dumps(opts, sort_keys=True).encode())
    return h.hexdigest()


__all__ = ["DEFAULT_OPTIONS", "solver_options", "schedule_signature",
           "family_signature"]
