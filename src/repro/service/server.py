"""Async batched solve server — hardened.

Clients ``submit`` ``SolveRequest``s; a single coalescing loop
(``serve_forever``) drains the queue in windows and answers each batch:

  1. identical in-flight signatures are **deduped** — the second submit of
     a signature awaits the first's future, never enqueues a second solve;
  2. fresh signatures are answered **from the store** (through the
     ``StoreGuard`` circuit breaker: a broken store degrades the server to
     solve-without-caching instead of failing requests);
  3. the remaining misses are solved **together**: each request's DP runs
     (vectorized, cheap), then the distinct detail-solve segments of all
     requests in the batch are pooled into one ThreadPoolExecutor pass
     (``kapla.solve_many``), run off the event loop in an executor so the
     loop keeps accepting submissions;
  4. winners are written back to the store; family near-misses seed
     warm-start chains exactly like ``LocalClient``.

Resilience contract (the chaos suite's invariants):

* **liveness** — every submitted request resolves to a ``ServiceResult``
  or raises the typed ``ServiceError``; a fault never strands a future;
* **failure isolation** — an exception inside a coalesced batch solve
  re-resolves each member independently (``resolve_request``), so a
  poisoned request fails alone;
* **deadlines** — a request past its ``deadline_s`` (measured from
  submission, queue time included) degrades down the ladder
  cached -> warm -> cold -> greedy first-valid, flagged ``degraded``;
* **bounded retries** — transient solve errors retry with bounded
  backoff (``runtime.fault.RecoveryPolicy``).

The server is in-process (asyncio futures, no sockets): the unit the CLI
and tests drive, and the piece a transport layer would wrap.
"""
from __future__ import annotations

import asyncio
import time
from typing import Dict, List, Optional, Tuple

from ..core.solver.kapla import solve_many
from ..obs import metrics, trace
from ..runtime.fault import CircuitBreaker, RecoveryPolicy
from .client import (ServiceError, ServiceResult, SolveRequest, StoreGuard,
                     attach_mesh_plan, record_degrade, record_resolution,
                     resolve_request)
from .store import ScheduleStore

_STOP = object()

_m_batch_width = metrics.histogram(
    "server_batch_width", "requests coalesced into one batch window",
    buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256))
_m_queue_wait = metrics.histogram(
    "server_queue_wait_seconds",
    "submit-to-batch-processing wait per request")


class SolveServer:
    """Coalescing schedule server over one ``ScheduleStore``."""

    def __init__(self, store: Optional[ScheduleStore] = None,
                 max_workers: Optional[int] = None,
                 batch_window_s: float = 0.005,
                 warm_start: bool = True,
                 breaker: Optional[CircuitBreaker] = None,
                 retry_policy: Optional[RecoveryPolicy] = None):
        self.store = store if store is not None else ScheduleStore()
        self.guard = StoreGuard(self.store, breaker)
        self.max_workers = max_workers
        self.batch_window_s = batch_window_s
        self.warm_start = warm_start
        self.retry_policy = retry_policy
        self._queue: Optional[asyncio.Queue] = None
        self._queue_loop = None
        self._stopped_loop = None
        self._inflight: Dict[str, asyncio.Future] = {}
        # mirrored into server_events_total{event=...} (repro.obs)
        self._events = metrics.CounterGroup("server", (
            "requests", "coalesced", "batches", "solved", "degraded",
            "errors", "batch_faults", "isolated"))

    @property
    def requests(self) -> int:
        return self._events["requests"]

    @property
    def coalesced(self) -> int:
        return self._events["coalesced"]

    @property
    def batches(self) -> int:
        return self._events["batches"]

    @property
    def solved(self) -> int:
        return self._events["solved"]

    @property
    def degraded(self) -> int:
        return self._events["degraded"]

    @property
    def errors(self) -> int:
        return self._events["errors"]

    @property
    def batch_faults(self) -> int:
        return self._events["batch_faults"]

    @property
    def isolated(self) -> int:
        return self._events["isolated"]

    def _q(self) -> asyncio.Queue:
        # asyncio.Queue binds to the loop it is first awaited on; a server
        # reused across asyncio.run() calls (tests, CLI) needs a fresh
        # queue — and fresh in-flight futures — per event loop
        loop = asyncio.get_running_loop()
        if self._queue is None or self._queue_loop is not loop:
            self._queue = asyncio.Queue()
            self._queue_loop = loop
            self._inflight = {}
        return self._queue

    # -- client side ---------------------------------------------------------
    async def submit(self, req: SolveRequest) -> ServiceResult:
        """Enqueue one request and await its result.  Duplicate in-flight
        signatures share one future (and one solve).  Raises the typed
        ``ServiceError`` if the request fails terminally, or
        ``RuntimeError`` if the server's loop on this event loop has
        already stopped — the request would otherwise never be drained."""
        self._events.inc("requests")
        q = self._q()              # also rebinds in-flight map on new loops
        if self._stopped_loop is asyncio.get_running_loop():
            raise RuntimeError("SolveServer is stopped on this event loop")
        sig = req.signature()
        fut = self._inflight.get(sig)
        if fut is not None:
            self._events.inc("coalesced")
            return await self._decorated(fut, req)
        fut = asyncio.get_running_loop().create_future()
        self._inflight[sig] = fut
        await q.put((sig, req, fut, time.perf_counter()))
        try:
            return await self._decorated(fut, req)
        finally:
            if self._inflight.get(sig) is fut and fut.done():
                self._inflight.pop(sig, None)

    async def _decorated(self, fut: asyncio.Future,
                         req: SolveRequest) -> ServiceResult:
        """Await the (possibly shared) in-flight future and apply the
        per-request multi-node rung.  Coalesced requests share one
        *undecorated* result — ``nodes`` is outside the signature — so
        each awaiter attaches (or strips) its own placement on a copy;
        the plan solve is CPU work and stays off the event loop."""
        res = await asyncio.shield(fut)
        if req.nodes > 1:
            res = await asyncio.get_running_loop().run_in_executor(
                None, attach_mesh_plan, res, req)
        return res

    async def stop(self) -> None:
        await self._q().put(_STOP)

    # -- server side ---------------------------------------------------------
    async def serve_forever(self) -> None:
        """Drain-and-batch loop; returns after ``stop()``."""
        q = self._q()
        self._stopped_loop = None
        running = True
        while running:
            item = await q.get()
            if item is _STOP:
                break
            batch = [item]
            if self.batch_window_s > 0:
                await asyncio.sleep(self.batch_window_s)  # coalesce window
            while not q.empty():
                nxt = q.get_nowait()
                if nxt is _STOP:
                    running = False
                    break
                batch.append(nxt)
            await self._process(batch)
        # fail anything still queued after stop; later submits on this
        # loop raise instead of enqueueing into a drained queue
        self._stopped_loop = asyncio.get_running_loop()
        while not q.empty():
            item = q.get_nowait()
            if item is not _STOP:
                fut = item[2]
                if not fut.done():
                    fut.set_exception(RuntimeError("server stopped"))

    def _expired(self, req: SolveRequest, ts: float) -> bool:
        return req.deadline_s is not None and \
            time.perf_counter() - ts > req.deadline_s

    async def _isolate(self, sig: str, req: SolveRequest,
                       fut: asyncio.Future, ts: float) -> None:
        """Resolve one request independently (the failure-isolation /
        deadline path): full ladder, typed terminal error."""
        self._events.inc("isolated")
        loop = asyncio.get_running_loop()
        try:
            res = await loop.run_in_executor(
                None, lambda: resolve_request(
                    self.guard, req, sig=sig, policy=self.retry_policy,
                    max_workers=self.max_workers,
                    warm_start=self.warm_start, t0=ts,
                    attach_mesh=False))   # shared future: per-awaiter
        except ServiceError as e:
            self._events.inc("errors")
            if not fut.done():
                fut.set_exception(e)
        except Exception as e:          # defensive: always a typed error
            self._events.inc("errors")
            if not fut.done():
                fut.set_exception(ServiceError(
                    f"request {sig[:12]} failed: {e!r}", signature=sig,
                    reason=repr(e)))
        else:
            self._events.inc("solved")
            if res.degraded:
                self._events.inc("degraded")
            if not fut.done():
                fut.set_result(res)
        finally:
            self._inflight.pop(sig, None)

    async def _process(self, batch: List[Tuple]) -> None:
        self._events.inc("batches")
        t0 = time.perf_counter()
        _m_batch_width.observe(len(batch))
        with trace.span("service.batch", width=len(batch)):
            await self._process_batch(batch, t0)

    async def _process_batch(self, batch: List[Tuple], t0: float) -> None:
        loop = asyncio.get_running_loop()
        misses: List[Tuple[str, SolveRequest, asyncio.Future, float]] = []
        for sig, req, fut, ts in batch:
            _m_queue_wait.observe(t0 - ts)
            if fut.done():
                continue
            # store reads parse whole schedule records: keep the disk +
            # JSON work off the event loop, like the solves below.  The
            # guard swallows store faults (breaker) — a read error is a
            # miss, not a failed request.
            cached = await loop.run_in_executor(None, self.guard.get,
                                                sig, req.graph)
            if cached is not None:
                # undecorated: the future may be shared by coalesced
                # requests with different node counts — each awaiter
                # attaches its own placement (``submit``)
                seconds = time.perf_counter() - ts
                record_resolution(sig, "cached", seconds,
                                  deadline_s=req.deadline_s)
                fut.set_result(ServiceResult(
                    cached, sig, "cached", seconds))
            else:
                misses.append((sig, req, fut, ts))
        if not misses:
            return
        by_opts: Dict[Tuple, List[Tuple[str, SolveRequest,
                                        asyncio.Future, float]]] = {}
        for m in misses:
            by_opts.setdefault(m[1].options, []).append(m)
        for opt_key, group in by_opts.items():
            # requests already past their deadline skip the pooled solve
            # and go straight down the ladder (-> greedy floor)
            pooled = [m for m in group if not self._expired(m[1], m[3])]
            expired = [m for m in group if self._expired(m[1], m[3])]
            for sig, req, fut, ts in expired:
                await self._isolate(sig, req, fut, ts)
            if not pooled:
                continue
            ctxs = [await loop.run_in_executor(
                None, self.guard.warm_context, req, sig)
                if self.warm_start else None for sig, req, _, _ in pooled]
            seeds = [c[0] if c else None for c in ctxs]
            solvers = [c[1] if c else None for c in ctxs]
            sources = ["warm" if s else "cold" for s in seeds]
            items = [(req.graph, req.hw) for _, req, _, _ in pooled]
            try:
                schedules = await loop.run_in_executor(
                    None, lambda: solve_many(
                        items, max_workers=self.max_workers,
                        seed_chains=seeds, layer_solvers=solvers,
                        **dict(opt_key)))
            except Exception:
                # per-request failure isolation: one poisoned or faulted
                # request must not fail the whole coalesced batch — each
                # member re-resolves independently and only the failing
                # request's future carries its (typed) error
                self._events.inc("batch_faults")
                trace.instant("service.batch_fault", width=len(pooled))
                await asyncio.gather(*(
                    self._isolate(sig, req, fut, ts)
                    for sig, req, fut, ts in pooled))
                continue
            for (sig, req, fut, ts), sched, src in zip(pooled, schedules,
                                                       sources):
                self._events.inc("solved")
                if src == "warm" and not sched.valid:
                    # seed did not transfer: fall back to a cold solve
                    record_degrade(sig, "warm->cold",
                                   "warm seed did not transfer")
                    try:
                        sched = await loop.run_in_executor(
                            None, lambda: solve_many(
                                [(req.graph, req.hw)],
                                max_workers=self.max_workers,
                                **dict(opt_key))[0])
                    except Exception:
                        self._events.inc("solved", -1)
                        await self._isolate(sig, req, fut, ts)
                        continue
                    src = "cold"
                rec = None
                if sched.valid:
                    # record serialization + the eviction scan stay off
                    # the loop too; the guard drops the write if the
                    # store is broken (solve-without-caching)
                    rec = await loop.run_in_executor(
                        None, lambda s=sched, r=req, g=sig:
                        self.guard.put(s, r.graph, r.hw, r.opts, sig=g))
                if not fut.done():
                    seconds = time.perf_counter() - ts
                    record_resolution(sig, src, seconds,
                                      deadline_s=req.deadline_s)
                    fut.set_result(ServiceResult(
                        sched, sig, src, seconds, rec))
                self._inflight.pop(sig, None)

    def stats(self) -> Dict:
        return {**self.guard.stats(), "requests": self.requests,
                "coalesced": self.coalesced, "batches": self.batches,
                "solved": self.solved, "degraded": self.degraded,
                "errors": self.errors, "batch_faults": self.batch_faults,
                "isolated": self.isolated,
                "inflight": len(self._inflight)}


async def serve_batch(server: SolveServer,
                      reqs: List[SolveRequest]) -> List[ServiceResult]:
    """Convenience: run the server loop just long enough to answer one
    burst of concurrent requests (tests, CLI).  Raises the first
    ``ServiceError`` if any request failed terminally — use
    ``serve_batch_settled`` to collect per-request outcomes instead."""
    loop_task = asyncio.ensure_future(server.serve_forever())
    try:
        results = await asyncio.gather(*(server.submit(r) for r in reqs))
    finally:
        await server.stop()
        await loop_task
    return list(results)


async def serve_batch_settled(server: SolveServer,
                              reqs: List[SolveRequest]) -> List[object]:
    """Like ``serve_batch`` but never raises for individual requests:
    each slot is a ``ServiceResult`` or the exception that answered it
    (liveness: every request gets exactly one of the two)."""
    loop_task = asyncio.ensure_future(server.serve_forever())
    try:
        results = await asyncio.gather(
            *(server.submit(r) for r in reqs), return_exceptions=True)
    finally:
        await server.stop()
        await loop_task
    return list(results)


__all__ = ["SolveServer", "serve_batch", "serve_batch_settled"]
