"""Async batched solve server.

Clients ``submit`` ``SolveRequest``s; a single coalescing loop
(``serve_forever``) drains the queue in windows and answers each batch:

  1. identical in-flight signatures are **deduped** — the second submit of
     a signature awaits the first's future, never enqueues a second solve;
  2. fresh signatures are answered **from the store**;
  3. the remaining misses are solved **together**: each request's DP runs
     (vectorized, cheap), then the distinct detail-solve segments of all
     requests in the batch are pooled into one ThreadPoolExecutor pass
     (``kapla.solve_many``), run off the event loop in an executor so the
     loop keeps accepting submissions;
  4. winners are written back to the store; family near-misses seed
     warm-start chains exactly like ``LocalClient``.

The server is in-process (asyncio futures, no sockets): the unit the CLI
and tests drive, and the piece a transport layer would wrap.
"""
from __future__ import annotations

import asyncio
import time
from typing import Dict, List, Optional, Tuple

from ..core.solver.kapla import solve_many
from .client import ServiceResult, SolveRequest, warm_context
from .store import ScheduleStore

_STOP = object()


class SolveServer:
    """Coalescing schedule server over one ``ScheduleStore``."""

    def __init__(self, store: Optional[ScheduleStore] = None,
                 max_workers: Optional[int] = None,
                 batch_window_s: float = 0.005,
                 warm_start: bool = True):
        self.store = store if store is not None else ScheduleStore()
        self.max_workers = max_workers
        self.batch_window_s = batch_window_s
        self.warm_start = warm_start
        self._queue: Optional[asyncio.Queue] = None
        self._queue_loop = None
        self._stopped_loop = None
        self._inflight: Dict[str, asyncio.Future] = {}
        self.requests = 0
        self.coalesced = 0
        self.batches = 0
        self.solved = 0

    def _q(self) -> asyncio.Queue:
        # asyncio.Queue binds to the loop it is first awaited on; a server
        # reused across asyncio.run() calls (tests, CLI) needs a fresh
        # queue — and fresh in-flight futures — per event loop
        loop = asyncio.get_running_loop()
        if self._queue is None or self._queue_loop is not loop:
            self._queue = asyncio.Queue()
            self._queue_loop = loop
            self._inflight = {}
        return self._queue

    # -- client side ---------------------------------------------------------
    async def submit(self, req: SolveRequest) -> ServiceResult:
        """Enqueue one request and await its result.  Duplicate in-flight
        signatures share one future (and one solve).  Raises if the
        server's loop on this event loop has already stopped — the
        request would otherwise never be drained."""
        self.requests += 1
        q = self._q()              # also rebinds in-flight map on new loops
        if self._stopped_loop is asyncio.get_running_loop():
            raise RuntimeError("SolveServer is stopped on this event loop")
        sig = req.signature()
        fut = self._inflight.get(sig)
        if fut is not None:
            self.coalesced += 1
            return await asyncio.shield(fut)
        fut = asyncio.get_running_loop().create_future()
        self._inflight[sig] = fut
        await q.put((sig, req, fut))
        try:
            return await asyncio.shield(fut)
        finally:
            if self._inflight.get(sig) is fut and fut.done():
                self._inflight.pop(sig, None)

    async def stop(self) -> None:
        await self._q().put(_STOP)

    # -- server side ---------------------------------------------------------
    async def serve_forever(self) -> None:
        """Drain-and-batch loop; returns after ``stop()``."""
        q = self._q()
        self._stopped_loop = None
        running = True
        while running:
            item = await q.get()
            if item is _STOP:
                break
            batch = [item]
            if self.batch_window_s > 0:
                await asyncio.sleep(self.batch_window_s)  # coalesce window
            while not q.empty():
                nxt = q.get_nowait()
                if nxt is _STOP:
                    running = False
                    break
                batch.append(nxt)
            await self._process(batch)
        # fail anything still queued after stop; later submits on this
        # loop raise instead of enqueueing into a drained queue
        self._stopped_loop = asyncio.get_running_loop()
        while not q.empty():
            item = q.get_nowait()
            if item is not _STOP:
                _, _, fut = item
                if not fut.done():
                    fut.set_exception(RuntimeError("server stopped"))

    async def _process(self, batch: List[Tuple]) -> None:
        self.batches += 1
        t0 = time.perf_counter()
        loop = asyncio.get_running_loop()
        misses: List[Tuple[str, SolveRequest, asyncio.Future]] = []
        for sig, req, fut in batch:
            if fut.done():
                continue
            # store reads parse whole schedule records: keep the disk +
            # JSON work off the event loop, like the solves below
            cached = await loop.run_in_executor(None, self.store.get,
                                                sig, req.graph)
            if cached is not None:
                fut.set_result(ServiceResult(
                    cached, sig, "cached", time.perf_counter() - t0))
            else:
                misses.append((sig, req, fut))
        if not misses:
            return
        by_opts: Dict[Tuple, List[Tuple[str, SolveRequest,
                                        asyncio.Future]]] = {}
        for m in misses:
            by_opts.setdefault(m[1].options, []).append(m)
        for opt_key, group in by_opts.items():
            ctxs = [await loop.run_in_executor(
                None, warm_context, self.store, req, sig)
                if self.warm_start else None for sig, req, _ in group]
            seeds = [c[0] if c else None for c in ctxs]
            solvers = [c[1] if c else None for c in ctxs]
            sources = ["warm" if s else "cold" for s in seeds]
            items = [(req.graph, req.hw) for _, req, _ in group]
            try:
                schedules = await loop.run_in_executor(
                    None, lambda: solve_many(
                        items, max_workers=self.max_workers,
                        seed_chains=seeds, layer_solvers=solvers,
                        **dict(opt_key)))
            except Exception as e:                # pragma: no cover
                for _, _, fut in group:
                    if not fut.done():
                        fut.set_exception(e)
                continue
            for (sig, req, fut), sched, src in zip(group, schedules,
                                                   sources):
                self.solved += 1
                if src == "warm" and not sched.valid:
                    # seed did not transfer: fall back to a cold solve
                    sched = await loop.run_in_executor(
                        None, lambda: solve_many(
                            [(req.graph, req.hw)],
                            max_workers=self.max_workers,
                            **dict(opt_key))[0])
                    src = "cold"
                rec = None
                if sched.valid:
                    # record serialization + the eviction scan stay off
                    # the loop too
                    rec = await loop.run_in_executor(
                        None, lambda s=sched, r=req, g=sig:
                        self.store.put(s, r.graph, r.hw, r.opts, sig=g))
                if not fut.done():
                    fut.set_result(ServiceResult(
                        sched, sig, src, time.perf_counter() - t0, rec))
                self._inflight.pop(sig, None)

    def stats(self) -> Dict:
        return {**self.store.stats(), "requests": self.requests,
                "coalesced": self.coalesced, "batches": self.batches,
                "solved": self.solved,
                "inflight": len(self._inflight)}


async def serve_batch(server: SolveServer,
                      reqs: List[SolveRequest]) -> List[ServiceResult]:
    """Convenience: run the server loop just long enough to answer one
    burst of concurrent requests (tests, CLI)."""
    loop_task = asyncio.ensure_future(server.serve_forever())
    try:
        results = await asyncio.gather(*(server.submit(r) for r in reqs))
    finally:
        await server.stop()
        await loop_task
    return list(results)


__all__ = ["SolveServer", "serve_batch"]
