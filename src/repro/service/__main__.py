"""Schedule-service CLI.

    python -m repro.service solve    --net resnet --batch 64 [--deadline S]
    python -m repro.service get      --net resnet --batch 64 [--json]
    python -m repro.service stats    [--json | --prom]
    python -m repro.service warm     --net resnet --batch 32
    python -m repro.service autotune --net mlp --batch 4 -k 3
    python -m repro.service repair

``solve`` answers through ``LocalClient`` down the degradation ladder
(store hit -> warm near-miss -> cold solve -> greedy first-valid when a
``--deadline`` expires) and reports the source + wall clock, so running
it twice demonstrates the cached path.  ``warm`` forces a warm-start
solve seeded from the nearest family record (same net, different batch).
``autotune`` lowers + executes the top-k candidates and promotes the
measured winner.  ``stats`` includes the resilience counters (corrupt /
quarantined / io_errors / rebuilds); ``stats --json`` adds the full
``repro.obs`` metrics-registry snapshot and ``--prom`` emits Prometheus
text exposition.  ``repair`` rebuilds the store
index from the records dir, quarantining corrupt records.  The store dir
defaults to ``$REPRO_STORE_DIR`` or ``.repro_store``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List, Optional

from ..core.solver.kapla import solve
from ..hw.presets import eyeriss_multinode
from ..workloads.nets import NETS, get_net
from .autotune import autotune_network
from .client import LocalClient, SolveRequest, warm_context
from .store import DEFAULT_ROOT, ScheduleStore


def _add_common(p: argparse.ArgumentParser, net: bool = True) -> None:
    p.add_argument("--store-dir", default=DEFAULT_ROOT,
                   help="schedule store root (default: %(default)s)")
    if net:
        p.add_argument("--net", required=True, choices=sorted(NETS),
                       help="registered network")
        p.add_argument("--batch", type=int, default=64)
        p.add_argument("--training", action="store_true",
                       help="use the training graph (fwd+bwd layers)")
        p.add_argument("--objective", default="energy",
                       choices=("energy", "edp", "latency"))
        p.add_argument("--k-s", type=int, default=4, dest="k_s")
        p.add_argument("--max-seg-len", type=int, default=4)


def _request(args) -> SolveRequest:
    graph = get_net(args.net, batch=args.batch, training=args.training)
    hw = eyeriss_multinode()
    return SolveRequest.make(graph, hw,
                             deadline_s=getattr(args, "deadline", None),
                             objective=args.objective,
                             k_s=args.k_s, max_seg_len=args.max_seg_len)


def _print_result(res, hw_freq: float) -> None:
    s = res.schedule
    flags = " DEGRADED" if res.degraded else ""
    print(f"{s.graph_name}: source={res.source}{flags} "
          f"sig={res.signature[:12]} in {res.seconds * 1e3:.1f} ms")
    if res.error:
        print(f"  degraded by: {res.error}")
    if s.valid:
        print(f"  energy {s.total_energy_pj / 1e9:.2f} mJ | latency "
              f"{s.total_latency_cycles / hw_freq * 1e3:.2f} ms "
              f"({s.total_latency_cycles:.3e} cycles) | "
              f"{0 if s.chain is None else len(s.chain.segments)} segments")
    else:
        print("  INVALID (no feasible schedule)")


def cmd_solve(args) -> int:
    from .client import ServiceError
    store = ScheduleStore(args.store_dir)
    client = LocalClient(store)
    req = _request(args)
    try:
        res = client.solve_request(req)
    except ServiceError as e:
        print(f"ERROR {e.signature[:12]}: {e}")
        return 2
    _print_result(res, req.hw.freq_hz)
    print("  store:", json.dumps(store.stats()))
    return 0 if res.schedule.valid else 1


def cmd_get(args) -> int:
    store = ScheduleStore(args.store_dir)
    req = _request(args)
    rec = store.get_record(req.signature())
    if rec is None:
        print(f"MISS {req.signature()[:12]} ({args.net}/b{args.batch})")
        return 1
    if args.json:
        json.dump(rec.to_json(), sys.stdout, indent=1)
        print()
        return 0
    print(f"HIT {rec.signature[:12]}: {rec.graph_name}/b{rec.batch} on "
          f"{rec.hw_name}, energy {rec.predicted_energy_pj / 1e9:.2f} mJ, "
          f"{rec.predicted_latency_cycles:.3e} cycles")
    if rec.measured:
        print(f"  measured: {json.dumps(rec.measured)}")
    return 0


def cmd_stats(args) -> int:
    store = ScheduleStore(args.store_dir)
    if getattr(args, "prom", False):
        from ..obs.metrics import REGISTRY
        sys.stdout.write(REGISTRY.exposition())
        return 0
    if getattr(args, "json", False):
        from ..obs.metrics import REGISTRY
        json.dump({"store": store.stats(),
                   "metrics": REGISTRY.snapshot()},
                  sys.stdout, indent=1)
        print()
        return 0
    print(json.dumps(store.stats(), indent=1))
    return 0


def cmd_warm(args) -> int:
    """Warm-start solve: seed from the nearest family record (same net,
    different batch) and write the result for this batch's signature."""
    store = ScheduleStore(args.store_dir)
    req = _request(args)
    sig = req.signature()
    ctx = warm_context(store, req, sig)
    seeds = solver = None
    if ctx is not None:
        seeds, solver, rec = ctx
        print(f"seeding from {rec.graph_name}/b{rec.batch} "
              f"({rec.signature[:12]})")
    t0 = time.perf_counter()
    sched = solve(req.graph, req.hw, seed_chains=seeds,
                  use_dp=not seeds,
                  **(dict(layer_solver=solver) if solver else {}),
                  **req.opts)
    dt = time.perf_counter() - t0
    if not sched.valid:
        print("warm solve produced no valid schedule")
        return 1
    store.put(sched, req.graph, req.hw, req.opts, sig=sig)
    print(f"{'warm' if seeds else 'cold'} solve in {dt:.3f} s -> stored "
          f"{sig[:12]}")
    return 0


def cmd_repair(args) -> int:
    """Rebuild the index from the records dir, quarantining corrupt
    records on the way — the manual entry point to the same self-healing
    the store runs automatically when it detects index damage."""
    store = ScheduleStore(args.store_dir)
    n = store.rebuild_index()
    print(f"rebuilt index: {n} records, "
          f"{store.quarantined} quarantined, "
          f"{sum(1 for v in store._family.values() if v)} families")
    print(json.dumps(store.stats(), indent=1))
    return 0


def cmd_autotune(args) -> int:
    store = ScheduleStore(args.store_dir)
    req = _request(args)
    report = autotune_network(req.graph, req.hw, store=store, k=args.k,
                              iters=args.iters,
                              candidate_timeout_s=args.candidate_timeout,
                              **req.opts)
    print(json.dumps(report, indent=1))
    return 0 if report.get("n_executed") else 1


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.service",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="verb", required=True)

    p = sub.add_parser("solve", help="serve one schedule "
                       "(cache -> warm -> cold -> greedy)")
    _add_common(p)
    p.add_argument("--deadline", type=float, default=None,
                   help="per-request deadline in seconds; past it the "
                   "answer degrades to the greedy floor")
    p.set_defaults(fn=cmd_solve)

    p = sub.add_parser("get", help="look up the stored record")
    _add_common(p)
    p.add_argument("--json", action="store_true",
                   help="dump the full record JSON")
    p.set_defaults(fn=cmd_get)

    p = sub.add_parser("stats", help="store statistics")
    p.add_argument("--json", action="store_true",
                   help="store stats + repro.obs metrics snapshot")
    p.add_argument("--prom", action="store_true",
                   help="Prometheus text exposition of the registry")
    _add_common(p, net=False)
    p.set_defaults(fn=cmd_stats)

    p = sub.add_parser("warm", help="warm-start solve from a family "
                       "near-miss and store it")
    _add_common(p)
    p.set_defaults(fn=cmd_warm)

    p = sub.add_parser("repair", help="rebuild the store index, "
                       "quarantining corrupt records")
    _add_common(p, net=False)
    p.set_defaults(fn=cmd_repair)

    p = sub.add_parser("autotune", help="measure top-k candidates and "
                       "promote the fastest")
    _add_common(p)
    p.add_argument("-k", type=int, default=3,
                   help="candidate schedules to execute")
    p.add_argument("--iters", type=int, default=2,
                   help="timing iterations per candidate")
    p.add_argument("--candidate-timeout", type=float, default=None,
                   help="disqualify a candidate whose lower+verify+"
                   "measure exceeds this many seconds")
    p.set_defaults(fn=cmd_autotune)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
