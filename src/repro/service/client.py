"""Client-side types + the synchronous in-process client.

``SolveRequest`` names one solve: a graph, a hardware template and the
normalized solver options.  ``LocalClient`` serves requests directly —
store lookup, warm-start near-miss, cold solve — without an event loop,
sharing the exact answer path of the async ``SolveServer`` (both resolve
cached → warm → cold in that order and write winners back to the store),
so tests and scripts exercise the same semantics synchronously.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.solver.kapla import (NetworkSchedule, seed_chains_from, solve,
                                 solve_many, warm_layer_solver)
from ..hw.template import HWTemplate
from ..workloads.layers import LayerGraph
from .signature import family_signature, schedule_signature, solver_options
from .store import ScheduleStore, StoreRecord


@dataclasses.dataclass(frozen=True)
class SolveRequest:
    """One schedule request; ``options`` are ``signature.solver_options``
    overrides (k_s, max_seg_len, objective)."""

    graph: LayerGraph
    hw: HWTemplate
    options: Tuple[Tuple[str, object], ...] = ()

    @staticmethod
    def make(graph: LayerGraph, hw: HWTemplate,
             **options) -> "SolveRequest":
        opts = solver_options(**options)
        return SolveRequest(graph, hw, tuple(sorted(opts.items())))

    @property
    def opts(self) -> Dict:
        return dict(self.options)

    def signature(self) -> str:
        return schedule_signature(self.graph, self.hw, self.opts)

    def family(self) -> str:
        return family_signature(self.graph, self.hw, self.opts)


@dataclasses.dataclass
class ServiceResult:
    """A served schedule plus provenance: ``source`` is ``"cached"`` (store
    hit), ``"warm"`` (near-miss-seeded solve) or ``"cold"`` (full solve);
    ``seconds`` is the service-side wall clock for this answer."""

    schedule: NetworkSchedule
    signature: str
    source: str
    seconds: float
    record: Optional[StoreRecord] = None


def warm_context(store: ScheduleStore, req: SolveRequest, sig: str):
    """(seed chains, transferring layer solver, source record) from the
    nearest family record in ``store``, or None.  The solver re-batches
    the record's stored intra-layer schemes to this graph's batch
    (positional name map — signatures never see names) so warm solves
    *evaluate* instead of re-solving each layer.  The single warm-start
    derivation shared by ``LocalClient``, ``SolveServer`` and the CLI."""
    for rec in store.warm_records(req.family(), exclude=(sig,)):
        sched = NetworkSchedule.from_json(rec.schedule)
        seeds = seed_chains_from(sched, req.graph)
        if not seeds:
            continue
        order = rec.layer_order or list(sched.layer_schemes)
        stored = {l.name: sched.layer_schemes[old]
                  for old, l in zip(order, req.graph.layers)
                  if old in sched.layer_schemes}
        return seeds, warm_layer_solver(stored), rec
    return None


class LocalClient:
    """Synchronous in-process schedule client over one ``ScheduleStore``.

    ``solve`` answers one request; ``solve_batch`` coalesces a list —
    identical signatures are deduped and the distinct misses' segments are
    pooled into one ThreadPoolExecutor pass (``kapla.solve_many``)."""

    def __init__(self, store: Optional[ScheduleStore] = None,
                 max_workers: Optional[int] = None,
                 warm_start: bool = True):
        self.store = store if store is not None else ScheduleStore()
        self.max_workers = max_workers
        self.warm_start = warm_start

    # -- single request ------------------------------------------------------
    def solve(self, graph: LayerGraph, hw: HWTemplate,
              **options) -> ServiceResult:
        req = SolveRequest.make(graph, hw, **options)
        return self.solve_request(req)

    def solve_request(self, req: SolveRequest) -> ServiceResult:
        t0 = time.perf_counter()
        sig = req.signature()
        cached = self.store.get(sig, req.graph)
        if cached is not None:
            return ServiceResult(cached, sig, "cached",
                                 time.perf_counter() - t0)
        ctx = self._warm_context(req, sig)
        if ctx is not None:
            seeds, solver, _ = ctx
            sched = solve(req.graph, req.hw, max_workers=self.max_workers,
                          seed_chains=seeds, use_dp=False,
                          layer_solver=solver, **req.opts)
            if sched.valid:
                rec = self.store.put(sched, req.graph, req.hw, req.opts,
                                     sig=sig)
                return ServiceResult(sched, sig, "warm",
                                     time.perf_counter() - t0, rec)
        sched = solve(req.graph, req.hw, max_workers=self.max_workers,
                      **req.opts)
        rec = None
        if sched.valid:
            rec = self.store.put(sched, req.graph, req.hw, req.opts,
                                 sig=sig)
        return ServiceResult(sched, sig, "cold",
                             time.perf_counter() - t0, rec)

    # -- batched requests ----------------------------------------------------
    def solve_batch(self, reqs: Sequence[SolveRequest]
                    ) -> List[ServiceResult]:
        """Answer a batch: dedupe identical signatures, answer fresh ones
        from the store, and solve the distinct misses *together* so their
        segments share one thread pool (the server's coalescing path,
        minus the event loop)."""
        t0 = time.perf_counter()
        sigs = [r.signature() for r in reqs]
        results: Dict[str, ServiceResult] = {}
        miss_sigs: List[str] = []
        miss_reqs: List[SolveRequest] = []
        miss_set: set = set()
        for sig, req in zip(sigs, reqs):
            if sig in results or sig in miss_set:
                continue
            cached = self.store.get(sig, req.graph)
            if cached is not None:
                results[sig] = ServiceResult(cached, sig, "cached",
                                             time.perf_counter() - t0)
            else:
                miss_set.add(sig)
                miss_sigs.append(sig)
                miss_reqs.append(req)
        if miss_reqs:
            by_opts: Dict[Tuple, List[int]] = {}
            for i, req in enumerate(miss_reqs):
                by_opts.setdefault(req.options, []).append(i)
            solved: Dict[int, NetworkSchedule] = {}
            sources: Dict[int, str] = {}
            for opt_key, idxs in by_opts.items():
                group = [miss_reqs[i] for i in idxs]
                ctxs = [self._warm_context(r, s)
                        for r, s in zip(group,
                                        (miss_sigs[i] for i in idxs))]
                seeds = [c[0] if c else None for c in ctxs]
                solvers = [c[1] if c else None for c in ctxs]
                res = solve_many([(r.graph, r.hw) for r in group],
                                 max_workers=self.max_workers,
                                 seed_chains=seeds, layer_solvers=solvers,
                                 **dict(opt_key))
                for i, sched, seed in zip(idxs, res, seeds):
                    if seed and not sched.valid:
                        # a warm seed that does not transfer falls back
                        # to a full cold solve
                        sched = solve(miss_reqs[i].graph, miss_reqs[i].hw,
                                      max_workers=self.max_workers,
                                      **miss_reqs[i].opts)
                        seed = None
                    solved[i] = sched
                    sources[i] = "warm" if seed else "cold"
            for i, (sig, req) in enumerate(zip(miss_sigs, miss_reqs)):
                sched = solved[i]
                rec = None
                if sched.valid:
                    rec = self.store.put(sched, req.graph, req.hw,
                                         req.opts, sig=sig)
                results[sig] = ServiceResult(
                    sched, sig, sources[i], time.perf_counter() - t0, rec)
        return [results[sig] for sig in sigs]

    # -- helpers -------------------------------------------------------------
    def _warm_context(self, req: SolveRequest, sig: str):
        if not self.warm_start:
            return None
        return warm_context(self.store, req, sig)

    def stats(self) -> Dict:
        return self.store.stats()


__all__ = ["SolveRequest", "ServiceResult", "LocalClient", "warm_context"]
