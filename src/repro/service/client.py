"""Client-side types + the synchronous in-process client.

``SolveRequest`` names one solve: a graph, a hardware template, the
normalized solver options and an optional per-request deadline.
``LocalClient`` serves requests directly — store lookup, warm-start
near-miss, cold solve — without an event loop, sharing the exact answer
path of the async ``SolveServer``: both walk the same **degradation
ladder** through ``resolve_request``:

    cached  ->  warm  ->  cold  ->  greedy (first-valid, ``degraded``)

with bounded-backoff retries on transient solve errors
(``runtime.fault.RecoveryPolicy``) and circuit-broken store access
(``StoreGuard``): a broken store degrades the service to
solve-without-caching instead of failing requests.  A request that
exhausts the whole ladder raises the typed ``ServiceError`` — the
service's liveness contract is *result or typed error*, never a hang or
an anonymous crash.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.solver.kapla import (NetworkSchedule, seed_chains_from, solve,
                                 solve_greedy, solve_many,
                                 warm_layer_solver)
from ..hw.template import HWTemplate
from ..obs import metrics, trace
from ..runtime.fault import CircuitBreaker, NodeFailure, RecoveryPolicy
from ..runtime.inject import InjectedFault
from ..workloads.layers import LayerGraph
from .signature import family_signature, schedule_signature, solver_options
from .store import ScheduleStore, StoreError, StoreRecord

#: solve errors worth retrying (fresh attempt may succeed); anything else
#: is treated as a poisoned request and drops straight to the greedy floor
TRANSIENT_ERRORS = (InjectedFault, NodeFailure, OSError, TimeoutError)

#: default retry policy for service solves: cheap, bounded, fast backoff —
#: KAPLA solves are ~sub-second, so retrying beats queueing behind a hang
DEFAULT_RETRY_POLICY = RecoveryPolicy(max_retries=2, backoff_seconds=0.02,
                                      backoff_factor=2.0, max_backoff=0.5)


# -- telemetry (repro.obs): every answer path reports through these ----------
_m_requests = metrics.counter(
    "service_requests_total",
    "requests answered, by resolved ladder rung", ("source",))
_m_request_seconds = metrics.histogram(
    "service_request_seconds",
    "service-side wall clock per answer, by resolved rung", ("source",))
_m_degrade = metrics.counter(
    "service_degrade_total",
    "degradation-ladder drops, by rung transition", ("rung",))
_m_slack = metrics.histogram(
    "service_deadline_slack_seconds",
    "deadline minus service time for deadline-carrying requests")

#: generic per-rung reasons for ``service.resolved`` events when no
#: specific fault forced the rung
_RUNG_REASONS = {"cached": "store hit", "warm": "family near-miss seed",
                 "cold": "full solve", "greedy": "ladder floor",
                 "error": "ladder exhausted"}


def record_resolution(sig: str, source: str, seconds: float,
                      degraded: bool = False,
                      reason: Optional[str] = None,
                      deadline_s: Optional[float] = None) -> None:
    """Publish one answered request: rung counter, latency histogram,
    deadline slack, and a ``service.resolved`` instant in the trace.
    The single funnel for every answer path — the ladder, the server's
    cached/batched paths and ``LocalClient.solve_batch``."""
    _m_requests.inc(source=source)
    _m_request_seconds.observe(seconds, source=source)
    if deadline_s is not None:
        _m_slack.observe(deadline_s - seconds)
    trace.instant("service.resolved", sig=sig[:12], source=source,
                  degraded=bool(degraded),
                  reason=reason or _RUNG_REASONS.get(source, ""))


def record_degrade(sig: str, rung: str, reason: str) -> None:
    """Publish one ladder drop (warm seed failed, transient retry,
    greedy floor, mesh fallback) with its reason."""
    _m_degrade.inc(rung=rung)
    trace.instant("service.degrade", sig=sig[:12], rung=rung,
                  reason=reason)


class ServiceError(RuntimeError):
    """Typed terminal failure for one request: the ladder was exhausted
    (or the request was poisoned beyond even the greedy floor)."""

    def __init__(self, msg: str, signature: str = "", reason: str = "",
                 attempts: int = 0):
        super().__init__(msg)
        self.signature = signature
        self.reason = reason
        self.attempts = attempts


@dataclasses.dataclass(frozen=True)
class SolveRequest:
    """One schedule request; ``options`` are ``signature.solver_options``
    overrides (k_s, max_seg_len, objective).  ``deadline_s`` (never part
    of the signature) bounds the service time budget: a request past its
    deadline degrades to the greedy floor instead of queueing a full
    solve.  ``nodes`` (also outside the signature — the single-node
    schedule is the shared, cacheable artifact) asks for a multi-node
    placement of the answer: the result carries a ``MultiNodePlan`` or,
    if partitioning fails, falls back one ladder rung to single-node,
    flagged degraded."""

    graph: LayerGraph
    hw: HWTemplate
    options: Tuple[Tuple[str, object], ...] = ()
    deadline_s: Optional[float] = None
    nodes: int = 1

    @staticmethod
    def make(graph: LayerGraph, hw: HWTemplate,
             deadline_s: Optional[float] = None, nodes: int = 1,
             **options) -> "SolveRequest":
        opts = solver_options(**options)
        return SolveRequest(graph, hw, tuple(sorted(opts.items())),
                            deadline_s, nodes)

    @property
    def opts(self) -> Dict:
        return dict(self.options)

    def signature(self) -> str:
        return schedule_signature(self.graph, self.hw, self.opts)

    def family(self) -> str:
        return family_signature(self.graph, self.hw, self.opts)


@dataclasses.dataclass
class ServiceResult:
    """A served schedule plus provenance: ``source`` is ``"cached"``
    (store hit), ``"warm"`` (near-miss-seeded solve), ``"cold"`` (full
    solve) or ``"greedy"`` (first-valid floor); ``degraded`` marks
    answers below the request's normal quality (greedy floor);
    ``error`` carries the fault that forced the degradation, if any;
    ``seconds`` is the service-side wall clock for this answer."""

    schedule: NetworkSchedule
    signature: str
    source: str
    seconds: float
    record: Optional[StoreRecord] = None
    degraded: bool = False
    error: Optional[str] = None
    #: multi-node placement (``multinode.MultiNodePlan``) when the
    #: request asked for ``nodes > 1`` and partitioning succeeded
    mesh_plan: Optional[object] = None
    nodes: int = 1


def attach_mesh_plan(res: ServiceResult,
                     req: SolveRequest) -> ServiceResult:
    """The service's multi-node rung: a request with ``nodes > 1`` gets
    a ``MultiNodePlan`` attached to its result (the cached/solved
    single-node schedule is reused — only the placement is computed).
    A failed partition falls back one rung to single-node, flagged
    ``degraded`` with the fault recorded — never a failed request.

    Never mutates ``res``: decoration happens on a copy.  Coalesced
    requests *share* one undecorated result (``nodes`` is outside the
    signature), so each awaiter decorates its own view — a ``nodes=1``
    request coalesced onto a ``nodes=4`` solve must not see the other
    request's placement, and vice versa."""
    if res.schedule is None or not res.schedule.valid:
        return res
    if req.nodes <= 1:
        if res.mesh_plan is None and res.nodes == 1:
            return res
        return dataclasses.replace(res, mesh_plan=None, nodes=1)
    from ..core.solver import multinode
    try:
        plan = multinode.plan_multinode(
            res.schedule, req.graph, req.hw,
            multinode.NodeMesh(nodes=req.nodes))
        return dataclasses.replace(res, mesh_plan=plan, nodes=req.nodes)
    except Exception as e:
        err = res.error if res.error is not None else \
            f"multi-node partition failed ({e!r}); single-node fallback"
        record_degrade(res.signature, "mesh->single", repr(e))
        return dataclasses.replace(res, mesh_plan=None, nodes=1,
                                   degraded=True, error=err)


class StoreGuard:
    """Circuit-broken store access.  ``StoreError``s trip the breaker;
    while it is open the store is skipped entirely (reads miss, writes
    drop) so a broken store degrades the service to solve-without-caching
    instead of failing every request."""

    def __init__(self, store: ScheduleStore,
                 breaker: Optional[CircuitBreaker] = None):
        self.store = store
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        self._events = metrics.CounterGroup("store_guard",
                                            ("errors", "skipped"))

    @property
    def errors(self) -> int:
        return self._events["errors"]

    @property
    def skipped(self) -> int:
        return self._events["skipped"]

    def _guard(self, fn, *args, default=None, **kwargs):
        if not self.breaker.allow():
            self._events.inc("skipped")
            return default
        try:
            out = fn(*args, **kwargs)
        except StoreError:
            self._events.inc("errors")
            self.breaker.record_failure()
            return default
        self.breaker.record_success()
        return out

    def get(self, sig: str, graph: Optional[LayerGraph] = None
            ) -> Optional[NetworkSchedule]:
        return self._guard(self.store.get, sig, graph)

    def put(self, schedule: NetworkSchedule, graph: LayerGraph,
            hw: HWTemplate, options=None, sig: Optional[str] = None
            ) -> Optional[StoreRecord]:
        return self._guard(self.store.put, schedule, graph, hw, options,
                           sig=sig)

    def warm_context(self, req: "SolveRequest", sig: str):
        return self._guard(warm_context, self.store, req, sig)

    def stats(self) -> Dict:
        return {**self.store.stats(), "store_errors": self.errors,
                "store_skipped": self.skipped,
                "breaker": self.breaker.stats()}


def warm_context(store: ScheduleStore, req: SolveRequest, sig: str):
    """(seed chains, transferring layer solver, source record) from the
    nearest family record in ``store``, or None.  The solver re-batches
    the record's stored intra-layer schemes to this graph's batch
    (positional name map — signatures never see names) so warm solves
    *evaluate* instead of re-solving each layer.  The single warm-start
    derivation shared by ``LocalClient``, ``SolveServer`` and the CLI."""
    for rec in store.warm_records(req.family(), exclude=(sig,)):
        sched = NetworkSchedule.from_json(rec.schedule)
        seeds = seed_chains_from(sched, req.graph)
        if not seeds:
            continue
        order = rec.layer_order or list(sched.layer_schemes)
        stored = {l.name: sched.layer_schemes[old]
                  for old, l in zip(order, req.graph.layers)
                  if old in sched.layer_schemes}
        return seeds, warm_layer_solver(stored), rec
    return None


def resolve_request(guard: StoreGuard, req: SolveRequest,
                    sig: Optional[str] = None,
                    policy: Optional[RecoveryPolicy] = None,
                    max_workers: Optional[int] = None,
                    warm_start: bool = True,
                    t0: Optional[float] = None,
                    sleep=time.sleep,
                    attach_mesh: bool = True) -> ServiceResult:
    """Answer one request down the degradation ladder.

    cached -> warm -> cold (with bounded-backoff retries on transient
    errors) -> greedy first-valid (flagged ``degraded``).  ``t0`` is the
    request's submit time (``time.perf_counter`` clock) — deadlines are
    measured from submission, so queue time counts against the budget.
    Raises ``ServiceError`` when even the greedy floor fails.

    ``attach_mesh=False`` skips the multi-node rung — callers whose
    result may be *shared* across coalesced requests (the async server)
    keep it undecorated and attach per awaiter instead.
    """
    t0 = time.perf_counter() if t0 is None else t0
    sig = sig if sig is not None else req.signature()
    with trace.span("service.request", sig=sig[:12],
                    graph=req.graph.name) as sp:
        try:
            res = _resolve_ladder(guard, req, sig, policy, max_workers,
                                  warm_start, t0, sleep, attach_mesh)
        except ServiceError as e:
            sp.set(source="error")
            record_resolution(sig, "error", time.perf_counter() - t0,
                              degraded=True, reason=e.reason,
                              deadline_s=req.deadline_s)
            raise
        sp.set(source=res.source, degraded=res.degraded)
        record_resolution(sig, res.source, res.seconds,
                          degraded=res.degraded, reason=res.error,
                          deadline_s=req.deadline_s)
        return res


def _resolve_ladder(guard: StoreGuard, req: SolveRequest, sig: str,
                    policy: Optional[RecoveryPolicy],
                    max_workers: Optional[int], warm_start: bool,
                    t0: float, sleep, attach_mesh: bool) -> ServiceResult:
    policy = policy if policy is not None else DEFAULT_RETRY_POLICY
    deadline_at = None if req.deadline_s is None else t0 + req.deadline_s
    decorate = attach_mesh_plan if attach_mesh else (lambda r, _: r)

    def expired() -> bool:
        return deadline_at is not None and time.perf_counter() > deadline_at

    cached = guard.get(sig, req.graph)
    if cached is not None:
        return decorate(
            ServiceResult(cached, sig, "cached",
                          time.perf_counter() - t0), req)

    attempts = 0
    backoff = policy.backoff_seconds
    last_err: Optional[BaseException] = None
    while not expired() and attempts <= policy.max_retries:
        attempts += 1
        try:
            ctx = guard.warm_context(req, sig) if warm_start else None
            src = "cold"
            sched = None
            if ctx is not None:
                seeds, solver, _ = ctx
                sched = solve(req.graph, req.hw, max_workers=max_workers,
                              seed_chains=seeds, use_dp=False,
                              layer_solver=solver, **req.opts)
                src = "warm"
                if not sched.valid:
                    sched = None        # seed did not transfer: cold
                    record_degrade(sig, "warm->cold",
                                   "warm seed did not transfer")
            if sched is None:
                src = "cold"
                sched = solve(req.graph, req.hw, max_workers=max_workers,
                              **req.opts)
            rec = guard.put(sched, req.graph, req.hw, req.opts, sig=sig) \
                if sched.valid else None
            return decorate(
                ServiceResult(sched, sig, src,
                              time.perf_counter() - t0, rec), req)
        except TRANSIENT_ERRORS as e:
            last_err = e
            if attempts > policy.max_retries or expired():
                break
            record_degrade(sig, "retry", repr(e))
            sleep(min(backoff, policy.max_backoff))
            backoff *= policy.backoff_factor
        except Exception as e:          # poisoned request: no retry value
            last_err = e
            break

    # ladder floor: first-valid greedy, flagged degraded
    record_degrade(sig, "greedy",
                   repr(last_err) if last_err is not None
                   else "deadline expired")
    try:
        sched = solve_greedy(req.graph, req.hw, max_workers=max_workers,
                             **req.opts)
        if sched.valid:
            return decorate(ServiceResult(
                sched, sig, "greedy", time.perf_counter() - t0,
                degraded=True,
                error=None if last_err is None else repr(last_err)), req)
        if last_err is None:
            # nothing faulted — the request has no feasible schedule at
            # all; answer with the invalid schedule like a plain solve
            return ServiceResult(sched, sig, "cold",
                                 time.perf_counter() - t0)
    except Exception as e:
        last_err = last_err if last_err is not None else e
    raise ServiceError(
        f"request {sig[:12]} failed after {attempts} attempt(s): "
        f"{last_err!r}", signature=sig, reason=repr(last_err),
        attempts=attempts)


class LocalClient:
    """Synchronous in-process schedule client over one ``ScheduleStore``.

    ``solve`` answers one request down the full degradation ladder;
    ``solve_batch`` coalesces a list — identical signatures are deduped
    and the distinct misses' segments are pooled into one
    ThreadPoolExecutor pass (``kapla.solve_many``); a fault inside the
    pooled solve isolates to per-request resolution so one poisoned
    request cannot fail its batch."""

    def __init__(self, store: Optional[ScheduleStore] = None,
                 max_workers: Optional[int] = None,
                 warm_start: bool = True,
                 breaker: Optional[CircuitBreaker] = None,
                 retry_policy: Optional[RecoveryPolicy] = None):
        self.store = store if store is not None else ScheduleStore()
        self.guard = StoreGuard(self.store, breaker)
        self.max_workers = max_workers
        self.warm_start = warm_start
        self.retry_policy = retry_policy
        self._events = metrics.CounterGroup("client",
                                            ("degraded", "errors"))

    @property
    def degraded(self) -> int:
        return self._events["degraded"]

    @property
    def errors(self) -> int:
        return self._events["errors"]

    # -- single request ------------------------------------------------------
    def solve(self, graph: LayerGraph, hw: HWTemplate,
              deadline_s: Optional[float] = None, nodes: int = 1,
              **options) -> ServiceResult:
        req = SolveRequest.make(graph, hw, deadline_s=deadline_s,
                                nodes=nodes, **options)
        return self.solve_request(req)

    def solve_request(self, req: SolveRequest) -> ServiceResult:
        try:
            res = resolve_request(self.guard, req,
                                  policy=self.retry_policy,
                                  max_workers=self.max_workers,
                                  warm_start=self.warm_start)
        except ServiceError:
            self._events.inc("errors")
            raise
        if res.degraded:
            self._events.inc("degraded")
        return res

    # -- batched requests ----------------------------------------------------
    def solve_batch(self, reqs: Sequence[SolveRequest]
                    ) -> List[ServiceResult]:
        """Answer a batch: dedupe identical signatures, answer fresh ones
        from the store, and solve the distinct misses *together* so their
        segments share one thread pool (the server's coalescing path,
        minus the event loop).  A fault inside the pooled solve falls
        back to per-request isolated resolution; a request that fails
        even isolated resolution gets a ``ServiceResult`` carrying the
        typed error string rather than poisoning its neighbours."""
        t0 = time.perf_counter()
        sigs = [r.signature() for r in reqs]
        results: Dict[str, ServiceResult] = {}
        miss_sigs: List[str] = []
        miss_reqs: List[SolveRequest] = []
        miss_set: set = set()
        for sig, req in zip(sigs, reqs):
            if sig in results or sig in miss_set:
                continue
            cached = self.guard.get(sig, req.graph)
            if cached is not None:
                results[sig] = ServiceResult(
                    cached, sig, "cached", time.perf_counter() - t0)
            else:
                miss_set.add(sig)
                miss_sigs.append(sig)
                miss_reqs.append(req)
        if miss_reqs:
            by_opts: Dict[Tuple, List[int]] = {}
            for i, req in enumerate(miss_reqs):
                by_opts.setdefault(req.options, []).append(i)
            for opt_key, idxs in by_opts.items():
                group = [miss_reqs[i] for i in idxs]
                ctxs = [self._warm_context(r, s)
                        for r, s in zip(group,
                                        (miss_sigs[i] for i in idxs))]
                seeds = [c[0] if c else None for c in ctxs]
                solvers = [c[1] if c else None for c in ctxs]
                try:
                    res = solve_many([(r.graph, r.hw) for r in group],
                                     max_workers=self.max_workers,
                                     seed_chains=seeds,
                                     layer_solvers=solvers,
                                     **dict(opt_key))
                except Exception:
                    # pooled solve faulted: isolate per request so one
                    # poisoned request fails alone
                    for i in idxs:
                        results[miss_sigs[i]] = self._isolated(
                            miss_reqs[i], miss_sigs[i], t0)
                    continue
                for i, sched, seed in zip(idxs, res, seeds):
                    req, sig = miss_reqs[i], miss_sigs[i]
                    src = "warm" if seed else "cold"
                    if seed and not sched.valid:
                        # a warm seed that does not transfer falls back
                        # to a full cold solve
                        try:
                            sched = solve(req.graph, req.hw,
                                          max_workers=self.max_workers,
                                          **req.opts)
                        except Exception:
                            results[sig] = self._isolated(req, sig, t0)
                            continue
                        src = "cold"
                    rec = self.guard.put(sched, req.graph, req.hw,
                                         req.opts, sig=sig) \
                        if sched.valid else None
                    results[sig] = ServiceResult(
                        sched, sig, src, time.perf_counter() - t0, rec)
        # deduped signatures share one undecorated result; the mesh rung
        # is per *request* (nodes is outside the signature), so each
        # request decorates its own view here
        return [attach_mesh_plan(results[sig], req)
                for sig, req in zip(sigs, reqs)]

    # -- helpers -------------------------------------------------------------
    def _isolated(self, req: SolveRequest, sig: str,
                  t0: float) -> ServiceResult:
        try:
            # shared by signature in the batch results: keep undecorated
            # (the mesh rung runs per request at the end of solve_batch)
            res = resolve_request(self.guard, req, sig=sig,
                                  policy=self.retry_policy,
                                  max_workers=self.max_workers,
                                  warm_start=self.warm_start, t0=t0,
                                  attach_mesh=False)
        except ServiceError as e:
            self._events.inc("errors")
            from ..core.solver.kapla import _invalid_schedule
            return ServiceResult(
                _invalid_schedule(req.graph, None), sig, "error",
                time.perf_counter() - t0, degraded=True, error=str(e))
        if res.degraded:
            self._events.inc("degraded")
        return res

    def _warm_context(self, req: SolveRequest, sig: str):
        if not self.warm_start:
            return None
        return self.guard.warm_context(req, sig)

    def stats(self) -> Dict:
        return {**self.guard.stats(), "degraded": self.degraded,
                "errors": self.errors}


__all__ = ["SolveRequest", "ServiceResult", "ServiceError", "StoreGuard",
           "LocalClient", "warm_context", "resolve_request",
           "attach_mesh_plan", "TRANSIENT_ERRORS", "DEFAULT_RETRY_POLICY"]
