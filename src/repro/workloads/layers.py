"""Layer and network specifications for the KAPLA dataflow solver.

The paper (§II-A) targets CONV and FC layers plus depthwise CONV, pooling and
element-wise layers, for both inference and training (backward layers "modeled
similarly to the forward layers with different data layouts").

We use a *generic* layer description: a set of named loop dimensions, a set of
named tensors each relevant to a subset of those dimensions, and per-tensor
"unit" multipliers that absorb the within-unit footprint (e.g. the R*S filter
window, the input halo).  This lets one analytic model cover forward CONV/FC,
depthwise CONV, pooling, element-wise ops, and all backward layer types.

Cross-level blocking dimensions are N, C, K, X, Y (filter dims R, S are kept at
the PE/unit level, which matches row-stationary and systolic PE mappings).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, FrozenSet, List, Mapping, Optional, Sequence, Tuple

DIMS = ("N", "C", "K", "X", "Y")


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """A single NN layer, in solver-generic form.

    dims:    loop dimension name -> total size (absent dims have size 1).
    tensors: tensor name -> frozenset of relevant dims (dims that index it).
    unit:    tensor name -> per-point element multiplier (R*S for weights,
             input halo ratio for inputs, 1 otherwise).
    macs_per_point: MAC (or op) count per point of the full dim iteration
             space (R*S for conv, 1 for fc).
    reduction_dims: dims accumulated into the output tensor 'O' (partial-sum
             traffic doubles when these loops sit outside O's residency).
    """

    name: str
    kind: str
    dims: Mapping[str, int]
    tensors: Mapping[str, FrozenSet[str]]
    unit: Mapping[str, float]
    macs_per_point: float
    reduction_dims: FrozenSet[str]
    src: Tuple[str, ...] = ()
    bytes_per_elem: int = 2
    has_weights: bool = True
    # per-tensor unit multipliers at the innermost (PE/REGF) level: a PE's
    # working set is one 1-D conv row (one filter row, one input row span,
    # one psum), not the full R*S window — matching row-stationary /
    # systolic PE mappings.  Defaults to ``unit`` when None.
    unit_inner: Optional[Mapping[str, float]] = None
    # kind-specific execution parameters needed to *run* the layer (the
    # analytic model folds them into ``unit``/``macs_per_point``): R, S and
    # stride for conv-family layers, causal for attention.  Excluded from
    # the solver memo signature — it only affects lowering/execution.
    meta: Mapping[str, float] = dataclasses.field(default_factory=dict)

    def inner_unit(self, t: str) -> float:
        u = self.unit_inner if self.unit_inner is not None else self.unit
        return u.get(t, 1.0)

    # ---- derived quantities -------------------------------------------------
    def dim(self, d: str) -> int:
        return int(self.dims.get(d, 1))

    def tensor_size(self, t: str) -> float:
        """Total element count of tensor ``t``."""
        sz = self.unit.get(t, 1.0)
        for d in self.tensors[t]:
            sz *= self.dim(d)
        return sz

    def total_macs(self) -> float:
        macs = self.macs_per_point
        for d in DIMS:
            macs *= self.dim(d)
        return macs

    def total_points(self) -> float:
        p = 1.0
        for d in DIMS:
            p *= self.dim(d)
        return p

    @property
    def weight_tensor(self) -> Optional[str]:
        return "W" if "W" in self.tensors else None

    def footprint_bytes(self) -> float:
        return sum(self.tensor_size(t) for t in self.tensors) * self.bytes_per_elem

    def ofmap_size(self) -> float:
        return self.tensor_size("O")

    # ---- JSON (de)serialization --------------------------------------------
    def to_json_dict(self) -> Dict:
        """Stable JSON-safe form (frozensets become sorted lists)."""
        return {
            "name": self.name, "kind": self.kind,
            "dims": dict(self.dims),
            "tensors": {t: sorted(rel) for t, rel in self.tensors.items()},
            "unit": dict(self.unit),
            "macs_per_point": self.macs_per_point,
            "reduction_dims": sorted(self.reduction_dims),
            "src": list(self.src),
            "bytes_per_elem": self.bytes_per_elem,
            "has_weights": self.has_weights,
            "unit_inner": None if self.unit_inner is None
            else dict(self.unit_inner),
            "meta": dict(self.meta),
        }

    @staticmethod
    def from_json_dict(d: Mapping) -> "LayerSpec":
        return LayerSpec(
            name=d["name"], kind=d["kind"],
            dims={k: int(v) for k, v in d["dims"].items()},
            tensors={t: frozenset(rel) for t, rel in d["tensors"].items()},
            unit=dict(d["unit"]),
            macs_per_point=float(d["macs_per_point"]),
            reduction_dims=frozenset(d["reduction_dims"]),
            src=tuple(d.get("src", ())),
            bytes_per_elem=int(d.get("bytes_per_elem", 2)),
            has_weights=bool(d.get("has_weights", True)),
            unit_inner=None if d.get("unit_inner") is None
            else dict(d["unit_inner"]),
            meta=dict(d.get("meta", {})))

    def ifmap_size(self) -> float:
        return self.tensor_size("I") if "I" in self.tensors else 0.0


def conv(name: str, n: int, c: int, k: int, xo: int, yo: int, r: int, s: int,
         stride: int = 1, src: Sequence[str] = ()) -> LayerSpec:
    xi = xo * stride + max(r - stride, 0)
    yi = yo * stride + max(s - stride, 0)
    halo = (xi * yi) / float(xo * yo)
    return LayerSpec(
        name=name, kind="conv",
        dims={"N": n, "C": c, "K": k, "X": xo, "Y": yo},
        tensors={"I": frozenset({"N", "C", "X", "Y"}),
                 "W": frozenset({"C", "K"}),
                 "O": frozenset({"N", "K", "X", "Y"})},
        unit={"I": halo, "W": float(r * s), "O": 1.0},
        unit_inner={"I": xi / float(xo), "W": float(r), "O": 1.0},
        macs_per_point=float(r * s),
        reduction_dims=frozenset({"C"}),
        src=tuple(src),
        meta={"R": r, "S": s, "stride": stride})


def fc(name: str, n: int, c: int, k: int, src: Sequence[str] = ()) -> LayerSpec:
    return LayerSpec(
        name=name, kind="fc",
        dims={"N": n, "C": c, "K": k},
        tensors={"I": frozenset({"N", "C"}),
                 "W": frozenset({"C", "K"}),
                 "O": frozenset({"N", "K"})},
        unit={"I": 1.0, "W": 1.0, "O": 1.0},
        macs_per_point=1.0,
        reduction_dims=frozenset({"C"}),
        src=tuple(src))


def dwconv(name: str, n: int, c: int, xo: int, yo: int, r: int, s: int,
           stride: int = 1, src: Sequence[str] = ()) -> LayerSpec:
    xi = xo * stride + max(r - stride, 0)
    yi = yo * stride + max(s - stride, 0)
    halo = (xi * yi) / float(xo * yo)
    return LayerSpec(
        name=name, kind="dwconv",
        dims={"N": n, "C": c, "X": xo, "Y": yo},
        tensors={"I": frozenset({"N", "C", "X", "Y"}),
                 "W": frozenset({"C"}),
                 "O": frozenset({"N", "C", "X", "Y"})},
        unit={"I": halo, "W": float(r * s), "O": 1.0},
        unit_inner={"I": xi / float(xo), "W": float(r), "O": 1.0},
        macs_per_point=float(r * s),
        reduction_dims=frozenset(),
        src=tuple(src),
        meta={"R": r, "S": s, "stride": stride})


def pool(name: str, n: int, c: int, xo: int, yo: int, r: int, s: int,
         stride: int = 2, src: Sequence[str] = ()) -> LayerSpec:
    xi = xo * stride + max(r - stride, 0)
    yi = yo * stride + max(s - stride, 0)
    halo = (xi * yi) / float(xo * yo)
    return LayerSpec(
        name=name, kind="pool",
        dims={"N": n, "C": c, "X": xo, "Y": yo},
        tensors={"I": frozenset({"N", "C", "X", "Y"}),
                 "O": frozenset({"N", "C", "X", "Y"})},
        unit={"I": halo, "O": 1.0},
        unit_inner={"I": xi / float(xo), "O": 1.0},
        macs_per_point=float(r * s),
        reduction_dims=frozenset(),
        src=tuple(src), has_weights=False,
        meta={"R": r, "S": s, "stride": stride})


def attention(name: str, batch: int, heads: int, seq_q: int, d_head: int,
              seq_kv: Optional[int] = None,
              src: Sequence[str] = ()) -> LayerSpec:
    """Fused attention scores+context op (softmax(QK^T) V) for one head
    group, in solver-generic form.

    Dim mapping: N = batch*heads (independent rows), X = query positions,
    C = KV positions (the softmax/weighted-sum reduction), K = head dim.
    Tensors: I = Q [N, X, K]; W = the K/V pair [N, C, K] (unit 2.0 — both
    operands stream together); O [N, X, K].  Two MACs per point of the
    N x X x C x K space (QK^T and PV).  The scores/probs matrix never
    appears as a tensor — like flash attention, it lives within a block.
    """
    skv = seq_kv if seq_kv is not None else seq_q
    return LayerSpec(
        name=name, kind="attention",
        dims={"N": batch * heads, "X": seq_q, "C": skv, "K": d_head},
        tensors={"I": frozenset({"N", "X", "K"}),
                 "W": frozenset({"N", "C", "K"}),
                 "O": frozenset({"N", "X", "K"})},
        unit={"I": 1.0, "W": 2.0, "O": 1.0},
        macs_per_point=2.0,
        reduction_dims=frozenset({"C"}),
        src=tuple(src),
        meta={"batch": batch, "heads": heads})


def eltwise(name: str, n: int, c: int, xo: int, yo: int,
            src: Sequence[str] = ()) -> LayerSpec:
    return LayerSpec(
        name=name, kind="eltwise",
        dims={"N": n, "C": c, "X": xo, "Y": yo},
        tensors={"I": frozenset({"N", "C", "X", "Y"}),
                 "O": frozenset({"N", "C", "X", "Y"})},
        unit={"I": 2.0, "O": 1.0},   # two summands
        macs_per_point=1.0,
        reduction_dims=frozenset(),
        src=tuple(src), has_weights=False)


# ---------------------------------------------------------------------------
# Backward layers (training).  Modeled as CONV-like layers with transposed
# data layouts, per §II-A of the paper.
# ---------------------------------------------------------------------------

def backward_data(fwd: LayerSpec) -> LayerSpec:
    """dI = dO (*) W^T: same shape family as forward with C and K swapped."""
    d = dict(fwd.dims)
    c, k = d.get("C", 1), d.get("K", 1)
    d["C"], d["K"] = k, c
    return dataclasses.replace(
        fwd, name=fwd.name + ".bd", kind=fwd.kind + "_bd", dims=d,
        src=(fwd.name + ".grad_in",))


def backward_weight(fwd: LayerSpec) -> LayerSpec:
    """dW = I (*) dO: output is the weight tensor; N, X, Y are reduced."""
    return dataclasses.replace(
        fwd, name=fwd.name + ".bw", kind=fwd.kind + "_bw",
        tensors={"I": fwd.tensors["I"],
                 "W": fwd.tensors["O"],        # dO plays the streamed role
                 "O": fwd.tensors.get("W", frozenset({"C", "K"}))},
        unit={"I": fwd.unit.get("I", 1.0),
              "W": 1.0,
              "O": fwd.unit.get("W", 1.0)},
        reduction_dims=frozenset({"N", "X", "Y"} & set(fwd.dims)),
        src=(fwd.name,))


@dataclasses.dataclass
class LayerGraph:
    """An NN as a topologically-ordered list of layers with data deps."""

    name: str
    layers: List[LayerSpec]

    def __post_init__(self) -> None:
        self.by_name: Dict[str, LayerSpec] = {l.name: l for l in self.layers}
        if len(self.by_name) != len(self.layers):
            raise ValueError("duplicate layer names in " + self.name)

    def __len__(self) -> int:
        return len(self.layers)

    def total_macs(self) -> float:
        return sum(l.total_macs() for l in self.layers)

    def training_graph(self) -> "LayerGraph":
        """Extend with backward-data and backward-weight layers."""
        out = list(self.layers)
        for l in reversed(self.layers):
            if l.kind in ("conv", "fc", "dwconv"):
                out.append(backward_data(l))
                out.append(backward_weight(l))
        return LayerGraph(self.name + "+train", out)
