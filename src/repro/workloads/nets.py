"""The seven evaluated networks from KAPLA §V (Methodology).

AlexNet, MobileNet, VGGNet(-16), GoogLeNet, ResNet(-50), an MLP, and an LSTM.
Default batch 64 (paper), batch 1 for edge inference.
"""
from __future__ import annotations

from typing import List

from .layers import LayerGraph, LayerSpec, conv, dwconv, eltwise, fc, pool


def alexnet(batch: int = 64) -> LayerGraph:
    L: List[LayerSpec] = []
    L.append(conv("conv1", batch, 3, 96, 55, 55, 11, 11, stride=4))
    L.append(pool("pool1", batch, 96, 27, 27, 3, 3, src=["conv1"]))
    L.append(conv("conv2", batch, 96, 256, 27, 27, 5, 5, src=["pool1"]))
    L.append(pool("pool2", batch, 256, 13, 13, 3, 3, src=["conv2"]))
    L.append(conv("conv3", batch, 256, 384, 13, 13, 3, 3, src=["pool2"]))
    L.append(conv("conv4", batch, 384, 384, 13, 13, 3, 3, src=["conv3"]))
    L.append(conv("conv5", batch, 384, 256, 13, 13, 3, 3, src=["conv4"]))
    L.append(pool("pool5", batch, 256, 6, 6, 3, 3, src=["conv5"]))
    L.append(fc("fc6", batch, 256 * 6 * 6, 4096, src=["pool5"]))
    L.append(fc("fc7", batch, 4096, 4096, src=["fc6"]))
    L.append(fc("fc8", batch, 4096, 1000, src=["fc7"]))
    return LayerGraph("alexnet", L)


def mobilenet(batch: int = 64) -> LayerGraph:
    # MobileNet-v1: conv, then 13 (dw + pw) pairs.
    cfg = [  # (c_in, c_out, stride, x_out)
        (32, 64, 1, 112), (64, 128, 2, 56), (128, 128, 1, 56),
        (128, 256, 2, 28), (256, 256, 1, 28), (256, 512, 2, 14),
        (512, 512, 1, 14), (512, 512, 1, 14), (512, 512, 1, 14),
        (512, 512, 1, 14), (512, 512, 1, 14), (512, 1024, 2, 7),
        (1024, 1024, 1, 7),
    ]
    L: List[LayerSpec] = [conv("conv1", batch, 3, 32, 112, 112, 3, 3, stride=2)]
    prev = "conv1"
    for i, (ci, co, st, xo) in enumerate(cfg):
        dw = f"dw{i + 1}"
        pw = f"pw{i + 1}"
        L.append(dwconv(dw, batch, ci, xo, xo, 3, 3, stride=st, src=[prev]))
        L.append(conv(pw, batch, ci, co, xo, xo, 1, 1, src=[dw]))
        prev = pw
    L.append(pool("gap", batch, 1024, 1, 1, 7, 7, stride=7, src=[prev]))
    L.append(fc("fc", batch, 1024, 1000, src=["gap"]))
    return LayerGraph("mobilenet", L)


def vggnet(batch: int = 64) -> LayerGraph:
    cfg = [  # (n_convs, channels, x)
        (2, 64, 224), (2, 128, 112), (3, 256, 56), (3, 512, 28), (3, 512, 14)]
    L: List[LayerSpec] = []
    prev_name, prev_c = "", 3
    for b, (n_convs, ch, x) in enumerate(cfg):
        for i in range(n_convs):
            nm = f"conv{b + 1}_{i + 1}"
            L.append(conv(nm, batch, prev_c, ch, x, x, 3, 3,
                          src=[prev_name] if prev_name else []))
            prev_name, prev_c = nm, ch
        pn = f"pool{b + 1}"
        L.append(pool(pn, batch, ch, x // 2, x // 2, 2, 2, src=[prev_name]))
        prev_name = pn
    L.append(fc("fc6", batch, 512 * 7 * 7, 4096, src=[prev_name]))
    L.append(fc("fc7", batch, 4096, 4096, src=["fc6"]))
    L.append(fc("fc8", batch, 4096, 1000, src=["fc7"]))
    return LayerGraph("vggnet", L)


def _inception(L: List[LayerSpec], name: str, src: str, batch: int, c_in: int,
               x: int, b1: int, b3r: int, b3: int, b5r: int, b5: int,
               bp: int) -> str:
    """GoogLeNet inception module; returns the (concatenated) output name."""
    L.append(conv(f"{name}.1x1", batch, c_in, b1, x, x, 1, 1, src=[src]))
    L.append(conv(f"{name}.3r", batch, c_in, b3r, x, x, 1, 1, src=[src]))
    L.append(conv(f"{name}.3x3", batch, b3r, b3, x, x, 3, 3, src=[f"{name}.3r"]))
    L.append(conv(f"{name}.5r", batch, c_in, b5r, x, x, 1, 1, src=[src]))
    L.append(conv(f"{name}.5x5", batch, b5r, b5, x, x, 5, 5, src=[f"{name}.5r"]))
    L.append(conv(f"{name}.pp", batch, c_in, bp, x, x, 1, 1, src=[src]))
    # concat is free; downstream layers consume the 4 branches jointly — we
    # model it with an eltwise-free passthrough by naming convention: the
    # concatenated tensor is referenced as "<name>.out" via a cheap eltwise.
    L.append(eltwise(f"{name}.out", batch, b1 + b3 + b5 + bp, x, x,
                     src=[f"{name}.1x1", f"{name}.3x3", f"{name}.5x5",
                          f"{name}.pp"]))
    return f"{name}.out"


def googlenet(batch: int = 64) -> LayerGraph:
    L: List[LayerSpec] = []
    L.append(conv("conv1", batch, 3, 64, 112, 112, 7, 7, stride=2))
    L.append(pool("pool1", batch, 64, 56, 56, 3, 3, src=["conv1"]))
    L.append(conv("conv2r", batch, 64, 64, 56, 56, 1, 1, src=["pool1"]))
    L.append(conv("conv2", batch, 64, 192, 56, 56, 3, 3, src=["conv2r"]))
    L.append(pool("pool2", batch, 192, 28, 28, 3, 3, src=["conv2"]))
    o = _inception(L, "i3a", "pool2", batch, 192, 28, 64, 96, 128, 16, 32, 32)
    o = _inception(L, "i3b", o, batch, 256, 28, 128, 128, 192, 32, 96, 64)
    L.append(pool("pool3", batch, 480, 14, 14, 3, 3, src=[o]))
    o = _inception(L, "i4a", "pool3", batch, 480, 14, 192, 96, 208, 16, 48, 64)
    o = _inception(L, "i4b", o, batch, 512, 14, 160, 112, 224, 24, 64, 64)
    o = _inception(L, "i4c", o, batch, 512, 14, 128, 128, 256, 24, 64, 64)
    o = _inception(L, "i4d", o, batch, 512, 14, 112, 144, 288, 32, 64, 64)
    o = _inception(L, "i4e", o, batch, 528, 14, 256, 160, 320, 32, 128, 128)
    L.append(pool("pool4", batch, 832, 7, 7, 3, 3, src=[o]))
    o = _inception(L, "i5a", "pool4", batch, 832, 7, 256, 160, 320, 32, 128, 128)
    o = _inception(L, "i5b", o, batch, 832, 7, 384, 192, 384, 48, 128, 128)
    L.append(pool("gap", batch, 1024, 1, 1, 7, 7, stride=7, src=[o]))
    L.append(fc("fc", batch, 1024, 1000, src=["gap"]))
    return LayerGraph("googlenet", L)


def _res_bottleneck(L: List[LayerSpec], name: str, src: str, batch: int,
                    c_in: int, c_mid: int, c_out: int, x: int,
                    stride: int = 1, project: bool = False) -> str:
    L.append(conv(f"{name}.a", batch, c_in, c_mid, x, x, 1, 1, stride=stride,
                  src=[src]))
    L.append(conv(f"{name}.b", batch, c_mid, c_mid, x, x, 3, 3,
                  src=[f"{name}.a"]))
    L.append(conv(f"{name}.c", batch, c_mid, c_out, x, x, 1, 1,
                  src=[f"{name}.b"]))
    srcs = [f"{name}.c"]
    if project:
        L.append(conv(f"{name}.p", batch, c_in, c_out, x, x, 1, 1,
                      stride=stride, src=[src]))
        srcs.append(f"{name}.p")
    else:
        srcs.append(src)
    L.append(eltwise(f"{name}.add", batch, c_out, x, x, src=srcs))
    return f"{name}.add"


def resnet50(batch: int = 64) -> LayerGraph:
    L: List[LayerSpec] = []
    L.append(conv("conv1", batch, 3, 64, 112, 112, 7, 7, stride=2))
    L.append(pool("pool1", batch, 64, 56, 56, 3, 3, src=["conv1"]))
    o = "pool1"
    stages = [  # (n_blocks, c_mid, c_out, x)
        (3, 64, 256, 56), (4, 128, 512, 28), (6, 256, 1024, 14),
        (3, 512, 2048, 7)]
    c_in = 64
    for s, (nb, cm, co, x) in enumerate(stages):
        for b in range(nb):
            stride = 2 if (b == 0 and s > 0) else 1
            o = _res_bottleneck(L, f"r{s + 2}{chr(97 + b)}", o, batch, c_in,
                                cm, co, x, stride=stride, project=(b == 0))
            c_in = co
    L.append(pool("gap", batch, 2048, 1, 1, 7, 7, stride=7, src=[o]))
    L.append(fc("fc", batch, 2048, 1000, src=["gap"]))
    return LayerGraph("resnet50", L)


def mlp(batch: int = 64) -> LayerGraph:
    """MLP-L from PRIME [12]: 784-1500-1000-500-10."""
    L = [fc("fc1", batch, 784, 1500)]
    L.append(fc("fc2", batch, 1500, 1000, src=["fc1"]))
    L.append(fc("fc3", batch, 1000, 500, src=["fc2"]))
    L.append(fc("fc4", batch, 500, 10, src=["fc3"]))
    return LayerGraph("mlp", L)


def lstm(batch: int = 64, hidden: int = 512, steps: int = 8) -> LayerGraph:
    """seq2seq-style LSTM [49]: per step, gate GEMMs + element-wise."""
    L: List[LayerSpec] = []
    prev = ""
    for t in range(steps):
        gx = f"t{t}.gx"
        gh = f"t{t}.gh"
        L.append(fc(gx, batch, hidden, 4 * hidden,
                    src=[prev] if prev else []))
        L.append(fc(gh, batch, hidden, 4 * hidden,
                    src=[prev] if prev else []))
        ew = f"t{t}.cell"
        L.append(eltwise(ew, batch, hidden, 1, 1, src=[gx, gh]))
        prev = ew
    return LayerGraph("lstm", L)


def transformer(batch: int = 64, layers: int = 12, d_model: int = 512,
                d_ff: int = 2048) -> LayerGraph:
    """Deep transformer-style layer graph built from fc/eltwise blocks.

    Per block: a fused QKV projection, the attention output projection, a
    residual add, the two FFN GEMMs, and a second residual add — six layers
    per block, so the inter-layer DP (segment slicing across hundreds of
    layers) dominates the solve on deep configs.  Attention score/context
    matmuls are activation-activation products the generic layer model has
    no tensor class for; the GEMM chain above carries the inter-layer
    structure (long residual-linked pipelines) that the solver exercises.
    """
    L: List[LayerSpec] = []
    prev = ""
    for i in range(layers):
        qkv, proj = f"b{i}.qkv", f"b{i}.proj"
        add1, ff1, ff2, add2 = (f"b{i}.add1", f"b{i}.ff1", f"b{i}.ff2",
                                f"b{i}.add2")
        L.append(fc(qkv, batch, d_model, 3 * d_model,
                    src=[prev] if prev else []))
        L.append(fc(proj, batch, d_model, d_model, src=[qkv]))
        L.append(eltwise(add1, batch, d_model, 1, 1,
                         src=[proj, prev] if prev else [proj]))
        L.append(fc(ff1, batch, d_model, d_ff, src=[add1]))
        L.append(fc(ff2, batch, d_ff, d_model, src=[ff1]))
        L.append(eltwise(add2, batch, d_model, 1, 1, src=[ff2, add1]))
        prev = add2
    return LayerGraph(f"transformer{layers}", L)


NETS = {
    "alexnet": alexnet,
    "mobilenet": mobilenet,
    "vggnet": vggnet,
    "googlenet": googlenet,
    "resnet": resnet50,
    "mlp": mlp,
    "lstm": lstm,
    "transformer": transformer,
}


def get_net(name: str, batch: int = 64, training: bool = False) -> LayerGraph:
    g = NETS[name](batch)
    return g.training_graph() if training else g
