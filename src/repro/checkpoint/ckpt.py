"""Sharded checkpointing: per-host npz payloads + a JSON manifest,
written atomically (tmp + rename) so a mid-write failure never corrupts the
latest checkpoint.  Restore reshards to whatever mesh is current — the
elastic-rescale path (runtime/fault.py) relies on this.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub" or arr.dtype.itemsize == 0:
            arr = np.asarray(jax.numpy.asarray(leaf, jax.numpy.float32))
        elif str(arr.dtype) == "bfloat16":
            arr = arr.astype(np.float32)   # npz has no native bf16
        out[key] = arr
    return out


def _unflatten(template: PyTree, flat: Dict[str, np.ndarray]) -> PyTree:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        try:
            leaves.append(arr.astype(leaf.dtype))
        except (TypeError, ValueError):
            # bf16 & friends: cast through jax (ml_dtypes-aware)
            leaves.append(np.asarray(jax.numpy.asarray(arr)
                                     .astype(leaf.dtype)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(ckpt_dir: str, step: int, params: PyTree, opt_state: PyTree,
         extra: Optional[Dict[str, Any]] = None, host_index: int = 0,
         keep: int = 3) -> str:
    """Write checkpoint ``step`` atomically; returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=ckpt_dir)
    try:
        np.savez(os.path.join(tmp, f"params_h{host_index}.npz"),
                 **_flatten(params))
        np.savez(os.path.join(tmp, f"opt_h{host_index}.npz"),
                 **_flatten(opt_state))
        manifest = {"step": step, "time": time.time(),
                    "host_index": host_index,
                    "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and
             os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, params_template: PyTree,
            opt_template: PyTree, step: Optional[int] = None,
            host_index: int = 0) -> Tuple[PyTree, PyTree, Dict[str, Any]]:
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    p = dict(np.load(os.path.join(d, f"params_h{host_index}.npz"),
                     allow_pickle=False))
    o = dict(np.load(os.path.join(d, f"opt_h{host_index}.npz"),
                     allow_pickle=False))
    return (_unflatten(params_template, p), _unflatten(opt_template, o),
            manifest)
