"""Unified model API for all 10 assigned architectures.

``build_model(cfg, mesh)`` returns a ``ModelAPI`` with:
  init(key)                          -> params
  forward(params, inputs)            -> logits            (train path)
  prefill(params, inputs, max_len)   -> (logits, cache)   (inference prefill)
  init_cache(batch, max_len)         -> cache
  decode_step(params, cache, tok, n) -> (logits, cache)   (one new token)
  loss_fn(params, batch)             -> scalar loss

All layer stacks use ``jax.lax.scan`` over stacked parameters so the HLO
stays one-block-sized regardless of depth (essential for compiling 61-layer
1T-param graphs for 512 devices).  Activation checkpointing (`remat=block`)
wraps the scan body.  MoE layers run expert-parallel inside ``shard_map``
(see models/moe.py); everything else is pjit/GSPMD-sharded via autoshard.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .attention import attn_decode, attn_forward, init_attn, _project_qkv
from .common import (chunked_cross_entropy, cross_entropy_loss, dense_init,
                     rms_norm, split_keys)
from .moe import init_moe, moe_ffn, shared_expert_ffn
from .ssm import (init_mamba, mamba_decode, mamba_forward, mamba_init_state,
                  ssm_dims)

PyTree = Any


# ---------------------------------------------------------------------------
# layer initializers
# ---------------------------------------------------------------------------

def _init_mlp(key, d, f, dtype):
    ks = split_keys(key, ["wi", "wg", "wo"])
    return {"wi": dense_init(ks["wi"], (d, f), d, dtype),
            "wg": dense_init(ks["wg"], (d, f), d, dtype),
            "wo": dense_init(ks["wo"], (f, d), f, dtype)}


def _gated_mlp(p, x):
    h = (x @ p["wi"]) * jax.nn.silu(x @ p["wg"])
    return h @ p["wo"]


def _init_dense_block(key, cfg: ModelConfig, dtype):
    ks = split_keys(key, ["attn", "mlp"])
    return {"ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attn(ks["attn"], cfg, dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "mlp": _init_mlp(ks["mlp"], cfg.d_model, cfg.d_ff, dtype)}


def _init_moe_block(key, cfg: ModelConfig, model_axis_size: int, dtype):
    ks = split_keys(key, ["attn", "moe"])
    return {"ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attn(ks["attn"], cfg, dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "moe": init_moe(ks["moe"], cfg, model_axis_size, dtype)}


def _init_mamba_block(key, cfg: ModelConfig, dtype):
    return {"ln": jnp.zeros((cfg.d_model,), dtype),
            "mamba": init_mamba(key, cfg, dtype)}


# ---------------------------------------------------------------------------
# ModelAPI
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ModelAPI:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    prefill: Callable
    init_cache: Callable
    decode_step: Callable
    loss_fn: Callable


def build_model(cfg: ModelConfig, mesh=None, dtype=jnp.bfloat16) -> ModelAPI:
    V = cfg.padded_vocab
    d = cfg.d_model
    L = cfg.num_layers
    mesh_axes = tuple(mesh.axis_names) if mesh is not None else ()
    model_axis = "model" if "model" in mesh_axes else None
    model_axis_size = mesh.shape["model"] if model_axis else 1
    data_axes = tuple(a for a in ("pod", "data") if a in mesh_axes)
    use_shard_map = cfg.family == "moe" and mesh is not None \
        and model_axis is not None

    paired = cfg.local_window > 0          # gemma2: (local, global) pairs
    if paired:
        assert L % 2 == 0, "local/global alternation needs even depth"

    def _seq_shard(h):
        """Megatron-SP-style residual sharding: between blocks the hidden
        state lives sequence-sharded over 'model' (and batch over data), so
        remat-saved residuals shrink by the TP degree.  GSPMD inserts the
        gather/scatter around attention automatically."""
        if not cfg.seq_shard or mesh is None or model_axis is None:
            return h
        from jax.sharding import NamedSharding
        dp = data_axes if len(data_axes) > 1 else \
            (data_axes[0] if data_axes else None)
        if h.ndim == 3 and h.shape[1] % model_axis_size == 0:
            return jax.lax.with_sharding_constraint(
                h, NamedSharding(mesh, P(dp, "model", None)))
        return h

    # ---- init ---------------------------------------------------------------
    def init(key: jax.Array) -> PyTree:
        ks = split_keys(key, ["embed", "head", "blocks", "extra"])
        params: Dict[str, PyTree] = {
            "embed": dense_init(ks["embed"], (V, d), d, dtype),
            "final_norm": jnp.zeros((d,), dtype),
            "lm_head": dense_init(ks["head"], (d, V), d, dtype),
        }
        if cfg.family == "dense":
            keys = jax.random.split(ks["blocks"], L)
            params["blocks"] = jax.vmap(
                lambda k: _init_dense_block(k, cfg, dtype))(keys)
        elif cfg.family == "moe":
            fd = cfg.first_dense_layers
            if fd:
                dkeys = jax.random.split(ks["extra"], fd)
                params["dense_blocks"] = jax.vmap(
                    lambda k: _init_dense_block(k, cfg, dtype))(dkeys)
            keys = jax.random.split(ks["blocks"], L - fd)
            params["blocks"] = jax.vmap(
                lambda k: _init_moe_block(k, cfg, model_axis_size, dtype)
            )(keys)
        elif cfg.family == "ssm":
            keys = jax.random.split(ks["blocks"], L)
            params["blocks"] = jax.vmap(
                lambda k: _init_mamba_block(k, cfg, dtype))(keys)
        elif cfg.family == "hybrid":
            keys = jax.random.split(ks["blocks"], L)
            params["blocks"] = jax.vmap(
                lambda k: _init_mamba_block(k, cfg, dtype))(keys)
            params["shared"] = _init_dense_block(ks["extra"], cfg, dtype)
        else:
            raise ValueError(cfg.family)
        return params

    # ---- helpers --------------------------------------------------------
    def _embed(params, inputs):
        if inputs.dtype in (jnp.int32, jnp.int64):
            h = params["embed"][inputs]          # row gather, no collective
        else:
            h = inputs.astype(dtype)             # precomputed embeddings stub
        return h * jnp.asarray(d ** 0.5, dtype)

    def _logits(params, h):
        logits = h.astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
        if cfg.final_logit_softcap > 0:
            cap = cfg.final_logit_softcap
            logits = jnp.tanh(logits / cap) * cap
        return logits

    def _routed_moe(bp_moe, x):
        routed_p = {k: v for k, v in bp_moe.items() if k != "shared"}
        if use_shard_map:
            from jax.experimental.shard_map import shard_map
            pspecs = {"router": P(), "wi": P("model", None, None),
                      "wg": P("model", None, None),
                      "wo": P("model", None, None)}
            x_spec = P(data_axes if data_axes else None, None, None)
            fn = shard_map(
                functools.partial(moe_ffn, cfg=cfg, model_axis=model_axis),
                mesh=mesh, in_specs=(pspecs, x_spec), out_specs=x_spec,
                check_rep=False)
            return fn(routed_p, x)
        return moe_ffn(routed_p, x, cfg=cfg, model_axis=None)

    def _dense_block_fwd(bp, h, window, collect_kv=False):
        a_in = rms_norm(h, bp["ln1"])
        if collect_kv:
            B, S, _ = a_in.shape
            q, k, v = _project_qkv(bp["attn"], a_in, cfg, jnp.arange(S))
            from ..kernels import ops
            o = ops.attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                              jnp.swapaxes(v, 1, 2), causal=True,
                              window=window,
                              logit_softcap=cfg.attn_logit_softcap)
            o = jnp.swapaxes(o, 1, 2).reshape(B, S, -1)
            attn_out = o @ bp["attn"]["wo"]
            kv = (jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2))
        else:
            attn_out = attn_forward(bp["attn"], a_in, cfg, window=window)
            kv = None
        h = h + attn_out
        f_in = rms_norm(h, bp["ln2"])
        if "mlp" in bp:
            ffn = _gated_mlp(bp["mlp"], f_in)
        else:
            ffn = _routed_moe(bp["moe"], f_in)
            if "shared" in bp["moe"]:
                ffn = ffn + shared_expert_ffn(bp["moe"], f_in)
        return h + ffn, kv

    def _mamba_block_fwd(bp, h):
        return h + mamba_forward(bp["mamba"], rms_norm(h, bp["ln"]), cfg)

    def _maybe_remat(f):
        return jax.checkpoint(f, prevent_cse=False) \
            if cfg.remat == "block" else f

    # ---- forward (train / prefill) -------------------------------------
    def forward(params, inputs, collect_kv: bool = False,
                last_only: bool = False, return_hidden: bool = False):
        h = _embed(params, inputs)

        if cfg.family in ("dense", "moe"):
            kv_all = []

            def run_stack(h, blocks, windows):
                def body(hc, bp):
                    if paired:
                        bpl = jax.tree_util.tree_map(lambda a: a[0], bp)
                        bpg = jax.tree_util.tree_map(lambda a: a[1], bp)
                        hc, kv1 = _dense_block_fwd(bpl, hc, cfg.local_window,
                                                   collect_kv)
                        hc, kv2 = _dense_block_fwd(bpg, hc, 0, collect_kv)
                        if collect_kv:
                            kv = jax.tree_util.tree_map(
                                lambda a, b: jnp.stack([a, b]), kv1, kv2)
                        else:
                            kv = None
                    else:
                        hc, kv = _dense_block_fwd(bp, hc, windows,
                                                  collect_kv)
                    return _seq_shard(hc), kv
                body = _maybe_remat(body)
                return jax.lax.scan(body, h, blocks)

            if cfg.family == "moe" and cfg.first_dense_layers:
                def dbody(hc, bp):
                    hc, kv = _dense_block_fwd(bp, hc, 0, collect_kv)
                    return _seq_shard(hc), kv
                dbody = _maybe_remat(dbody)
                h, kv_d = jax.lax.scan(dbody, h, params["dense_blocks"])
                kv_all.append(kv_d)

            blocks = params["blocks"]
            if paired:
                blocks = jax.tree_util.tree_map(
                    lambda a: a.reshape((L // 2, 2) + a.shape[1:]), blocks)
            h, kv_m = run_stack(h, blocks, 0)
            kv_all.append(kv_m)

        elif cfg.family == "ssm":
            def body(hc, bp):
                return _mamba_block_fwd(bp, hc), None
            body = _maybe_remat(body)
            h, _ = jax.lax.scan(body, h, params["blocks"])
            kv_all = [None]

        elif cfg.family == "hybrid":
            kv_all = []
            n_sites, rem = divmod(L, cfg.attn_every)

            def mbody(hc, bp):
                return _mamba_block_fwd(bp, hc), None
            mbody = _maybe_remat(mbody)
            blocks = params["blocks"]
            for s in range(n_sites):
                grp = jax.tree_util.tree_map(
                    lambda a: jax.lax.dynamic_slice_in_dim(
                        a, s * cfg.attn_every, cfg.attn_every), blocks)
                h, _ = jax.lax.scan(mbody, h, grp)
                h, kv = _dense_block_fwd(params["shared"], h, 0, collect_kv)
                kv_all.append(kv)
            if rem:
                tail = jax.tree_util.tree_map(
                    lambda a: jax.lax.dynamic_slice_in_dim(
                        a, n_sites * cfg.attn_every, rem), blocks)
                h, _ = jax.lax.scan(mbody, h, tail)

        if last_only:
            h = h[:, -1:]          # slice before the vocab projection
        h = rms_norm(h, params["final_norm"])
        if return_hidden:
            return h
        return (_logits(params, h), kv_all) if collect_kv \
            else _logits(params, h)

    # ---- loss ------------------------------------------------------------
    def loss_fn(params, batch):
        h = forward(params, batch["inputs"], return_hidden=True)
        # chunked CE: the [tokens, vocab] f32 logits never materialize
        return chunked_cross_entropy(h, params["lm_head"],
                                     batch["targets"],
                                     softcap=cfg.final_logit_softcap)

    # ---- KV / state caches -------------------------------------------------
    def init_cache(batch: int, max_len: int):
        if cfg.family in ("dense", "moe"):
            KV, hd = cfg.num_kv_heads, cfg.head_dim
            if cfg.kv_cache_dtype == "int8":
                return {
                    "k": jnp.zeros((L, batch, KV, max_len, hd), jnp.int8),
                    "v": jnp.zeros((L, batch, KV, max_len, hd), jnp.int8),
                    "k_scale": jnp.zeros((L, batch, KV, max_len, 1),
                                         jnp.float32),
                    "v_scale": jnp.zeros((L, batch, KV, max_len, 1),
                                         jnp.float32),
                }
            return {
                "k": jnp.zeros((L, batch, KV, max_len, hd), dtype),
                "v": jnp.zeros((L, batch, KV, max_len, hd), dtype),
            }
        if cfg.family == "ssm":
            st = jax.vmap(lambda _: mamba_init_state(cfg, batch, dtype))(
                jnp.arange(L))
            return st
        if cfg.family == "hybrid":
            n_sites = L // cfg.attn_every
            KV, hd = cfg.num_kv_heads, cfg.head_dim
            st = jax.vmap(lambda _: mamba_init_state(cfg, batch, dtype))(
                jnp.arange(L))
            st["k"] = jnp.zeros((n_sites, batch, KV, max_len, hd), dtype)
            st["v"] = jnp.zeros((n_sites, batch, KV, max_len, hd), dtype)
            return st
        raise ValueError(cfg.family)

    # ---- prefill ------------------------------------------------------------
    def prefill(params, inputs, max_len: int):
        """Run the full prompt, return (last-token logits, filled cache)."""
        B = inputs.shape[0]
        S = inputs.shape[1]
        if cfg.family in ("dense", "moe"):
            logits, kv_all = forward(params, inputs, collect_kv=True,
                                     last_only=True)
            cache = init_cache(B, max_len)
            parts_k, parts_v = [], []
            for kv in kv_all:
                if kv is None:
                    continue
                kk, vv = kv
                if kk.ndim == 6:               # paired: [L/2, 2, B, ...]
                    kk = kk.reshape((-1,) + kk.shape[2:])
                    vv = vv.reshape((-1,) + vv.shape[2:])
                parts_k.append(kk)
                parts_v.append(vv)
            k_new = jnp.concatenate(parts_k, 0).astype(dtype)
            v_new = jnp.concatenate(parts_v, 0).astype(dtype)
            if cfg.kv_cache_dtype == "int8":
                from ..kernels import ops as kops
                k_new, ks = kops.quantize_kv(k_new)
                v_new, vs = kops.quantize_kv(v_new)
                cache["k_scale"] = jax.lax.dynamic_update_slice(
                    cache["k_scale"], ks.astype(jnp.float32),
                    (0, 0, 0, 0, 0))
                cache["v_scale"] = jax.lax.dynamic_update_slice(
                    cache["v_scale"], vs.astype(jnp.float32),
                    (0, 0, 0, 0, 0))
            cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"], k_new.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
            cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"], v_new.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
            return logits[:, -1:], cache
        # ssm / hybrid prefill: run forward and rebuild decode state by
        # replaying the final states (cheap path: token-by-token is O(S);
        # we use the chunked forward's final states instead)
        logits = forward(params, inputs, last_only=True)
        cache = init_cache(B, max_len)
        return logits, cache

    # ---- decode -------------------------------------------------------------
    def decode_step(params, cache, tokens, cache_len):
        """tokens: [B, 1] int32; cache_len: [] int32 (tokens already in
        cache).  Returns (logits [B,1,V], updated cache)."""
        h = _embed(params, tokens)

        if cfg.family in ("dense", "moe"):
            windows = None
            if paired:
                windows = jnp.tile(
                    jnp.array([cfg.local_window, 0], jnp.int32), L // 2)

            start = 0
            if cfg.family == "moe" and cfg.first_dense_layers:
                fd = cfg.first_dense_layers

                def dbody(carry, xs):
                    hc = carry
                    bp, kc, vc = xs
                    a_in = rms_norm(hc, bp["ln1"])
                    a, kc, vc = attn_decode(bp["attn"], a_in, cfg, kc, vc,
                                            cache_len, 0)
                    hc = hc + a
                    f_in = rms_norm(hc, bp["ln2"])
                    hc = hc + _gated_mlp(bp["mlp"], f_in)
                    return hc, (kc, vc)

                h, (kd, vd) = jax.lax.scan(
                    dbody, h, (params["dense_blocks"],
                               cache["k"][:fd], cache["v"][:fd]))
                cache["k"] = cache["k"].at[:fd].set(kd)
                cache["v"] = cache["v"].at[:fd].set(vd)
                start = fd

            quant = cfg.kv_cache_dtype == "int8"

            def body(carry, xs):
                hc = carry
                win = 0
                ks = vs = None
                if paired and quant:
                    bp, kc, vc, ks, vs, win = xs
                elif paired:
                    bp, kc, vc, win = xs
                elif quant:
                    bp, kc, vc, ks, vs = xs
                else:
                    bp, kc, vc = xs
                a_in = rms_norm(hc, bp["ln1"])
                res = attn_decode(bp["attn"], a_in, cfg, kc, vc,
                                  cache_len, window=win,
                                  k_scale=ks, v_scale=vs)
                a, kc, vc = res[0], res[1], res[2]
                hc = hc + a
                f_in = rms_norm(hc, bp["ln2"])
                if "mlp" in bp:
                    ffn = _gated_mlp(bp["mlp"], f_in)
                else:
                    ffn = _routed_moe(bp["moe"], f_in)
                    if "shared" in bp["moe"]:
                        ffn = ffn + shared_expert_ffn(bp["moe"], f_in)
                ys = (kc, vc) + ((res[3], res[4]) if quant else ())
                return hc + ffn, ys

            xs = (params["blocks"], cache["k"][start:], cache["v"][start:])
            if quant:
                xs = xs + (cache["k_scale"][start:],
                           cache["v_scale"][start:])
            if paired:
                xs = xs + (windows,)
            h, new_vals = jax.lax.scan(body, h, xs)
            cache["k"] = cache["k"].at[start:].set(new_vals[0])
            cache["v"] = cache["v"].at[start:].set(new_vals[1])
            if quant:
                cache["k_scale"] = cache["k_scale"].at[start:].set(
                    new_vals[2])
                cache["v_scale"] = cache["v_scale"].at[start:].set(
                    new_vals[3])

        elif cfg.family == "ssm":
            def body(hc, xs):
                bp, st = xs
                out, st = mamba_decode(bp["mamba"],
                                       rms_norm(hc, bp["ln"]), st, cfg)
                return hc + out, st
            h, cache = jax.lax.scan(body, h, (params["blocks"], cache))

        elif cfg.family == "hybrid":
            n_sites = L // cfg.attn_every
            rem = L - n_sites * cfg.attn_every
            blocks = params["blocks"]

            def mbody(hc, xs):
                bp, st = xs
                out, st = mamba_decode(bp["mamba"],
                                       rms_norm(hc, bp["ln"]), st, cfg)
                return hc + out, st

            mstate = {k: cache[k] for k in
                      ("conv_x", "conv_b", "conv_c", "ssm")}
            st_out = []
            k_out, v_out = [], []
            for s in range(n_sites):
                sl = slice(s * cfg.attn_every, (s + 1) * cfg.attn_every)
                grp = jax.tree_util.tree_map(lambda a: a[sl], blocks)
                st_sl = jax.tree_util.tree_map(lambda a: a[sl], mstate)
                h, st_new = jax.lax.scan(mbody, h, (grp, st_sl))
                st_out.append(st_new)
                sp = params["shared"]
                a_in = rms_norm(h, sp["ln1"])
                a, kc, vc = attn_decode(sp["attn"], a_in, cfg,
                                        cache["k"][s], cache["v"][s],
                                        cache_len, 0)
                h = h + a
                h = h + _gated_mlp(sp["mlp"], rms_norm(h, sp["ln2"]))
                k_out.append(kc)
                v_out.append(vc)
            if rem:
                sl = slice(n_sites * cfg.attn_every, L)
                grp = jax.tree_util.tree_map(lambda a: a[sl], blocks)
                st_sl = jax.tree_util.tree_map(lambda a: a[sl], mstate)
                h, st_new = jax.lax.scan(mbody, h, (grp, st_sl))
                st_out.append(st_new)
            cache = jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs, 0), *st_out)
            cache["k"] = jnp.stack(k_out, 0)
            cache["v"] = jnp.stack(v_out, 0)

        h = rms_norm(h, params["final_norm"])
        return _logits(params, h), cache

    return ModelAPI(cfg, init, forward, prefill, init_cache, decode_step,
                    loss_fn)
