"""Shared model components: norms, RoPE, init helpers."""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

PyTree = Any


def _rms_norm_impl(x: jnp.ndarray, scale: jnp.ndarray,
                   eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


@jax.custom_vjp
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """RMSNorm with a hand-written VJP: stats computed in f32, but the saved
    residuals and the outgoing cotangent stay in x.dtype (bf16) — autodiff of
    the f32-internals version otherwise drags f32 [B,S,d] intermediates
    through every layer's backward (§Perf iteration 6)."""
    return _rms_norm_impl(x, scale)


def _rms_fwd(x, scale):
    return _rms_norm_impl(x, scale), (x, scale)


def _rms_bwd(res, dy):
    x, scale = res
    eps = 1e-6
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    xhat = xf * rstd
    w = 1.0 + scale.astype(jnp.float32)
    u = dy.astype(jnp.float32) * w
    dx = rstd * (u - xhat * jnp.mean(u * xhat, axis=-1, keepdims=True))
    dscale = jnp.sum(dy.astype(jnp.float32) * xhat,
                     axis=tuple(range(dy.ndim - 1)))
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


rms_norm.defvjp(_rms_fwd, _rms_bwd)


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10000.0) -> jnp.ndarray:
    """Rotary embeddings.  x: [B, S, H, D]; positions: [B, S] or [S]."""
    D = x.shape[-1]
    half = D // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freq  # [B,S,half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), \
        x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def dense_init(key: jax.Array, shape, fan_in: int | None = None,
               dtype=jnp.bfloat16) -> jnp.ndarray:
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) > 1 \
        else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def split_keys(key: jax.Array, names) -> Dict[str, jax.Array]:
    ks = jax.random.split(key, len(names))
    return dict(zip(names, ks))


def cross_entropy_loss(logits: jnp.ndarray, targets: jnp.ndarray,
                       vocab_size: int, z_loss: float = 1e-4):
    """Token CE with optional z-loss; logits: [B,S,V], targets: [B,S]."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    loss = jnp.mean(nll)
    if z_loss > 0:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss


def chunked_cross_entropy(h: jnp.ndarray, lm_head: jnp.ndarray,
                          targets: jnp.ndarray, softcap: float = 0.0,
                          chunk: int = 512, z_loss: float = 1e-4):
    """CE computed per sequence chunk with rematerialization: the full
    [tokens, vocab] f32 logits tensor never materializes (fwd) and is
    recomputed per chunk (bwd).  Cuts the vocab-projection working set from
    O(S x V) to O(chunk x V) — a large memory-roofline term for 64k-256k
    vocabularies (§Perf iteration 5)."""
    B, S, d = h.shape
    if S % chunk != 0:
        return cross_entropy_loss(
            _apply_head(h, lm_head, softcap), targets, lm_head.shape[-1],
            z_loss)
    nc = S // chunk
    hc = jnp.moveaxis(h.reshape(B, nc, chunk, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(B, nc, chunk), 1, 0)

    @jax.checkpoint
    def one(hx, tx):
        logits = _apply_head(hx, lm_head, softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tx[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold) + z_loss * jnp.sum(jnp.square(lse))

    def body(acc, xs):
        hx, tx = xs
        return acc + one(hx, tx), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, tc))
    return total / (B * S)


def _apply_head(h, lm_head, softcap):
    logits = h.astype(jnp.float32) @ lm_head.astype(jnp.float32)
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits
