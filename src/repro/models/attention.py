"""GQA attention block (RoPE, optional QKV bias, local window, softcap)."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from .common import dense_init, rope, split_keys


def init_attn(key: jax.Array, cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict:
    d, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    ks = split_keys(key, ["wq", "wk", "wv", "wo"])
    p = {
        "wq": dense_init(ks["wq"], (d, H * hd), d, dtype),
        "wk": dense_init(ks["wk"], (d, KV * hd), d, dtype),
        "wv": dense_init(ks["wv"], (d, KV * hd), d, dtype),
        "wo": dense_init(ks["wo"], (H * hd, d), H * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _project_qkv(p: Dict, x: jnp.ndarray, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    q = rope(q, positions)
    k = rope(k, positions)
    return q, k, v


def attn_forward(p: Dict, x: jnp.ndarray, cfg: ModelConfig,
                 window: int = 0) -> jnp.ndarray:
    """Full-sequence (train / prefill) attention."""
    B, S, _ = x.shape
    positions = jnp.arange(S)
    q, k, v = _project_qkv(p, x, cfg, positions)
    o = ops.attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                      jnp.swapaxes(v, 1, 2), causal=True, window=window,
                      logit_softcap=cfg.attn_logit_softcap)
    o = jnp.swapaxes(o, 1, 2).reshape(B, S, cfg.num_heads * cfg.head_dim)
    return o @ p["wo"]


def attn_decode(p: Dict, x: jnp.ndarray, cfg: ModelConfig,
                k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                cache_len: jnp.ndarray, window: int = 0,
                k_scale=None, v_scale=None):
    """One-token decode.  x: [B, 1, d]; caches: [B, KV, Smax, hd].
    With int8 caches, k_scale/v_scale are per-position scale planes
    [B, KV, Smax, 1] and new entries are quantized on write.
    Returns (out [B,1,d], new caches...) — scales appended when present."""
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg, cache_len[None, None])
    k_entry = jnp.swapaxes(k, 1, 2)            # [B, KV, 1, hd]
    v_entry = jnp.swapaxes(v, 1, 2)
    quant = k_scale is not None
    if quant:
        k_entry, ks_new = ops.quantize_kv(k_entry)
        v_entry, vs_new = ops.quantize_kv(v_entry)
        k_scale = jax.lax.dynamic_update_slice(
            k_scale, ks_new.astype(k_scale.dtype), (0, 0, cache_len, 0))
        v_scale = jax.lax.dynamic_update_slice(
            v_scale, vs_new.astype(v_scale.dtype), (0, 0, cache_len, 0))
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_entry.astype(k_cache.dtype), (0, 0, cache_len, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_entry.astype(v_cache.dtype), (0, 0, cache_len, 0))
    o = ops.decode_attention(jnp.swapaxes(q, 1, 2), k_cache, v_cache,
                             cache_len + 1, window=window,
                             logit_softcap=cfg.attn_logit_softcap,
                             k_scale=k_scale, v_scale=v_scale)
    o = jnp.swapaxes(o, 1, 2).reshape(B, 1, cfg.num_heads * cfg.head_dim)
    out = o @ p["wo"]
    if quant:
        return out, k_cache, v_cache, k_scale, v_scale
    return out, k_cache, v_cache
