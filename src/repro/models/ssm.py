"""Mamba2 (SSD) block: in-proj -> causal conv -> selective SSM -> gated out.

Train/prefill uses the chunked SSD path (``kernels.ops.ssd`` — Pallas
intra-chunk kernel on TPU); decode maintains O(1) per-token state
(conv tail + SSM state), which is what makes long_500k runnable.

Projections are kept as separate weights (w_z, w_x, w_b, w_c, w_dt) rather
than one packed matrix so each shards cleanly: the inner dim ``di`` (and the
head dim H = di / head_dim) goes over the ``model`` mesh axis; the small
shared B/C projections stay replicated.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from .common import dense_init, rms_norm, split_keys


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    di = cfg.ssm_expand * cfg.d_model
    H = di // cfg.ssm_head_dim
    return di, H, cfg.ssm_state


def init_mamba(key: jax.Array, cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict:
    d = cfg.d_model
    di, H, N = ssm_dims(cfg)
    cw = cfg.conv_width
    ks = split_keys(key, ["w_z", "w_x", "w_b", "w_c", "w_dt", "w_out"])
    return {
        "w_z": dense_init(ks["w_z"], (d, di), d, dtype),
        "w_x": dense_init(ks["w_x"], (d, di), d, dtype),
        "w_b": dense_init(ks["w_b"], (d, N), d, dtype),
        "w_c": dense_init(ks["w_c"], (d, N), d, dtype),
        "w_dt": dense_init(ks["w_dt"], (d, H), d, dtype),
        "conv_x_w": dense_init(jax.random.fold_in(key, 1), (cw, di), cw,
                               dtype),
        "conv_b_w": dense_init(jax.random.fold_in(key, 2), (cw, N), cw,
                               dtype),
        "conv_c_w": dense_init(jax.random.fold_in(key, 3), (cw, N), cw,
                               dtype),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_b_b": jnp.zeros((N,), dtype),
        "conv_c_b": jnp.zeros((N,), dtype),
        "a_log": jnp.zeros((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm": jnp.zeros((di,), dtype),
        "w_out": dense_init(ks["w_out"], (di, d), di, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 ) -> jnp.ndarray:
    """Depthwise causal conv along seq.  x: [B,S,C]; w: [cw, C]."""
    B, S, C = x.shape
    cw = w.shape[0]
    pad = jnp.zeros((B, cw - 1, C), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i: i + S] * w[i] for i in range(cw))
    return jax.nn.silu(y + b)


def mamba_forward(p: Dict, x_in: jnp.ndarray, cfg: ModelConfig,
                  ) -> jnp.ndarray:
    """Full-sequence forward.  x_in: [B, S, d]."""
    B, S, _ = x_in.shape
    di, H, N = ssm_dims(cfg)
    z = x_in @ p["w_z"]
    xs = _causal_conv(x_in @ p["w_x"], p["conv_x_w"], p["conv_x_b"])
    b = _causal_conv(x_in @ p["w_b"], p["conv_b_w"], p["conv_b_b"])
    c = _causal_conv(x_in @ p["w_c"], p["conv_c_w"], p["conv_c_b"])
    dt = jax.nn.softplus((x_in @ p["w_dt"]).astype(jnp.float32) +
                         p["dt_bias"])
    xh = xs.reshape(B, S, H, cfg.ssm_head_dim)
    y, _ = ops.ssd(xh, dt, p["a_log"], b, c)
    y = y + xh * p["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["w_out"]


def mamba_init_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    di, H, N = ssm_dims(cfg)
    cw = cfg.conv_width
    return {
        "conv_x": jnp.zeros((batch, cw - 1, di), dtype),
        "conv_b": jnp.zeros((batch, cw - 1, N), dtype),
        "conv_c": jnp.zeros((batch, cw - 1, N), dtype),
        "ssm": jnp.zeros((batch, H, cfg.ssm_head_dim, N), jnp.float32),
    }


def _conv_step(tail: jnp.ndarray, xt: jnp.ndarray, w: jnp.ndarray,
               b: jnp.ndarray):
    """tail: [B, cw-1, C]; xt: [B, C] -> (y [B, C], new tail)."""
    window = jnp.concatenate([tail, xt[:, None]], axis=1)
    y = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                   w.astype(jnp.float32))
    return jax.nn.silu(y + b.astype(jnp.float32)).astype(xt.dtype), \
        window[:, 1:]


def mamba_decode(p: Dict, x_in: jnp.ndarray, state: Dict, cfg: ModelConfig,
                 ) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode.  x_in: [B, 1, d]."""
    B = x_in.shape[0]
    di, H, N = ssm_dims(cfg)
    xt = x_in[:, 0]
    z = xt @ p["w_z"]
    xs, conv_x = _conv_step(state["conv_x"], xt @ p["w_x"],
                            p["conv_x_w"], p["conv_x_b"])
    b, conv_b = _conv_step(state["conv_b"], xt @ p["w_b"],
                           p["conv_b_w"], p["conv_b_b"])
    c, conv_c = _conv_step(state["conv_c"], xt @ p["w_c"],
                           p["conv_c_w"], p["conv_c_b"])
    dt = jax.nn.softplus((xt @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    xh = xs.reshape(B, H, cfg.ssm_head_dim)
    h, y = ops.ssd_decode(state["ssm"], xh, dt, p["a_log"], b, c)
    y = y + xh * p["d_skip"][None, :, None].astype(xh.dtype)
    y = y.reshape(B, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = (y @ p["w_out"])[:, None]
    return out, {"conv_x": conv_x, "conv_b": conv_b, "conv_c": conv_c,
                 "ssm": h}
