"""Mixture-of-Experts FFN with expert parallelism.

Design (TPU-native adaptation — see DESIGN.md §2):
  * experts are sharded over the ``model`` mesh axis (the paper's ``stack``
    over a tensor-exclusive dim: expert weights are never replicated);
  * routing is computed redundantly on every model-shard (tokens are
    replicated across ``model`` after the attention all-reduce anyway);
  * each shard gathers capacity-bounded buffers for its *local* experts only
    (sort-free capacity assignment via ranked positions), runs the batched
    expert FFN (dense, MXU-aligned), scatter-adds gated outputs, and a
    single ``psum`` over ``model`` combines partial outputs — the same
    collective a tensor-parallel FFN would need, so no extra latency class.

FLOPs are honest: only local-expert capacity buffers are computed (top-k x
capacity-factor tokens per expert), never a dense all-experts pass and never
a quadratic one-hot dispatch einsum.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, pad_to
from .common import dense_init, split_keys


def padded_experts(cfg: ModelConfig, model_axis_size: int) -> int:
    """Experts padded up so the model axis divides them evenly (padding
    experts receive -inf router logits and are never selected)."""
    return pad_to(cfg.num_experts, max(1, model_axis_size))


def init_moe(key: jax.Array, cfg: ModelConfig, model_axis_size: int,
             dtype=jnp.bfloat16) -> Dict:
    d, f = cfg.d_model, cfg.moe_d_ff
    E = padded_experts(cfg, model_axis_size)
    ks = split_keys(key, ["router", "wi", "wg", "wo", "swi", "swg", "swo"])
    p = {
        "router": dense_init(ks["router"], (d, E), d, jnp.float32),
        "wi": dense_init(ks["wi"], (E, d, f), d, dtype),
        "wg": dense_init(ks["wg"], (E, d, f), d, dtype),
        "wo": dense_init(ks["wo"], (E, f, d), f, dtype),
    }
    if cfg.num_shared_experts > 0:
        fs = f * cfg.num_shared_experts
        p["shared"] = {
            "wi": dense_init(ks["swi"], (d, fs), d, dtype),
            "wg": dense_init(ks["swg"], (d, fs), d, dtype),
            "wo": dense_init(ks["swo"], (fs, d), fs, dtype),
        }
    return p


def _capacity(tokens: int, num_experts: int, top_k: int,
              capacity_factor: float) -> int:
    c = int(tokens * top_k * capacity_factor / num_experts) + 1
    return max(4, pad_to(c, 4))


def moe_ffn(p: Dict, x: jnp.ndarray, cfg: ModelConfig,
            model_axis: Optional[str] = None) -> jnp.ndarray:
    """x: [B_local, S, d].  When called inside shard_map, ``p['wi'/'wg'/'wo']``
    arrive as the *local* expert shard ([E_local, ...], spec P('model', ...))
    while the router stays replicated; outside shard_map E_local == E.
    Returns [B_local, S, d] (psum'd over ``model`` when present)."""
    B, S, d = x.shape
    T = B * S
    E = p["router"].shape[-1]
    E_local = p["wi"].shape[0]
    k = cfg.top_k
    C = _capacity(T, E, k, cfg.capacity_factor)

    xt = x.reshape(T, d)
    logits = xt.astype(jnp.float32) @ p["router"]             # [T, E]
    # mask padding experts
    if E > cfg.num_experts:
        pad_mask = jnp.arange(E) >= cfg.num_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    gates_all = jax.nn.softmax(logits, axis=-1)
    top_gates, top_e = jax.lax.top_k(gates_all, k)             # [T, k]
    top_gates = top_gates / jnp.maximum(
        jnp.sum(top_gates, -1, keepdims=True), 1e-9)

    # ---- capacity positions: rank of each (token, slot) within its expert
    e_flat = top_e.reshape(-1)                                # [T*k]
    gate_flat = top_gates.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(e_flat)                               # group by expert
    e_sorted = e_flat[order]
    # position within expert group = index - first occurrence of the expert
    first = jnp.searchsorted(e_sorted, e_sorted, side="left")
    pos = jnp.arange(T * k) - first                           # [T*k]
    keep = pos < C

    # ---- local expert window
    shard = jax.lax.axis_index(model_axis) if model_axis else 0
    e_start = shard * E_local
    local = (e_sorted >= e_start) & (e_sorted < e_start + E_local) & keep
    dest = jnp.where(local, (e_sorted - e_start) * C + pos, E_local * C)

    tok_sorted = tok_flat[order]
    gate_sorted = gate_flat[order]
    buf = jnp.zeros((E_local * C + 1, d), x.dtype)
    buf = buf.at[dest].set(xt[tok_sorted] *
                           local[:, None].astype(x.dtype))
    buf = buf[: E_local * C].reshape(E_local, C, d)

    # ---- batched expert FFN (gated) over local experts ----------------------
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"]) * \
        jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"]))
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"])                # [E_local,C,d]

    # ---- combine: scatter-add gated outputs back to tokens ------------------
    y_flat = jnp.concatenate(
        [y.reshape(E_local * C, d), jnp.zeros((1, d), y.dtype)], axis=0)
    contrib = y_flat[dest] * (gate_sorted * local)[:, None].astype(y.dtype)
    out = jnp.zeros((T, d), jnp.float32).at[tok_sorted].add(
        contrib.astype(jnp.float32))
    if model_axis:
        out = jax.lax.psum(out, model_axis)
    return out.astype(x.dtype).reshape(B, S, d)


def shared_expert_ffn(p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """Always-on shared experts: a plain gated FFN, computed *outside* the
    expert-parallel shard_map so it is tensor-parallel like any dense FFN
    (never redundantly replicated across the model axis)."""
    sp = p["shared"]
    h = (x @ sp["wi"]) * jax.nn.silu(x @ sp["wg"])
    return h @ sp["wo"]
