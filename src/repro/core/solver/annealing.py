"""Baseline M: AutoTVM-style ML-guided search (§V).

Simulated annealing over intra-layer scheme encodings, guided by a learned
surrogate (ridge regression over log-features, standing in for XGBoost —
no offline xgboost wheel in this container).  Batch-tune loop: propose a
batch of neighbors, rank with the surrogate, evaluate the top fraction with
the detailed model, refit.  Inter-layer options are taken from the same
chain enumeration as the other solvers (AutoTVM handles intra-layer only).
"""
from __future__ import annotations

import math
import random
import time
from typing import Dict, List, Optional, Tuple

from ...hw.template import HWTemplate
from ...workloads.layers import DIMS, LayerGraph, LayerSpec
from ..cost_batch import score_schemes
from ..cost_model import CostBreakdown, combine_segment, evaluate_layer, invalid
from ..directives import LayerScheme, canonical_orders, divisors
from .interlayer import dp_prioritize, io_flags, _consumer_map
from .intralayer import Constraints, solve_intra_layer
from .random_search import _random_scheme


def _features(scheme: LayerScheme) -> List[float]:
    f: List[float] = []
    for lv in scheme.levels:
        for d in DIMS:
            f.append(math.log1p(lv.tf(d)))
            f.append(math.log1p(lv.sf(d)))
    return f + [1.0]


class _Ridge:
    """Tiny ridge regression on-line surrogate (normal equations)."""

    def __init__(self, dim: int, lam: float = 1.0):
        self.dim = dim
        self.lam = lam
        self.X: List[List[float]] = []
        self.y: List[float] = []
        self.w: Optional[List[float]] = None

    def fit(self) -> None:
        n, d = len(self.X), self.dim
        if n < d // 2:
            self.w = None
            return
        # solve (X^T X + lam I) w = X^T y with Gaussian elimination
        A = [[self.lam if i == j else 0.0 for j in range(d)] for i in range(d)]
        b = [0.0] * d
        for xi, yi in zip(self.X, self.y):
            for i in range(d):
                b[i] += xi[i] * yi
                for j in range(d):
                    A[i][j] += xi[i] * xi[j]
        for col in range(d):
            piv = max(range(col, d), key=lambda r: abs(A[r][col]))
            if abs(A[piv][col]) < 1e-12:
                self.w = None
                return
            A[col], A[piv] = A[piv], A[col]
            b[col], b[piv] = b[piv], b[col]
            for r in range(col + 1, d):
                m = A[r][col] / A[col][col]
                for j in range(col, d):
                    A[r][j] -= m * A[col][j]
                b[r] -= m * b[col]
        w = [0.0] * d
        for i in range(d - 1, -1, -1):
            s = b[i] - sum(A[i][j] * w[j] for j in range(i + 1, d))
            w[i] = s / A[i][i]
        self.w = w

    def predict(self, x: List[float]) -> float:
        if self.w is None:
            return 0.0
        return sum(wi * xi for wi, xi in zip(self.w, x))

    def add(self, x: List[float], y: float) -> None:
        self.X.append(x)
        self.y.append(y)


def solve_layer_annealing(layer: LayerSpec, hw: HWTemplate,
                          constr: Optional[Constraints] = None,
                          iters: int = 64, batch: int = 32,
                          eval_frac: float = 0.25, seed: int = 0,
                          ) -> Tuple[Optional[LayerScheme], CostBreakdown]:
    constr = constr or Constraints(nodes=hw.node_array)
    rng = random.Random(seed ^ (hash(layer.name) & 0xFFFF))
    surrogate = _Ridge(dim=len(DIMS) * 2 * len(hw.levels) + 1)
    best: Tuple[Optional[LayerScheme], CostBreakdown] = (None, invalid("none"))
    cur: Optional[LayerScheme] = None
    cur_cost = float("inf")
    T = 1.0
    for it in range(iters):
        cands = [_random_scheme(layer, hw, constr, rng) for _ in range(batch)]
        if surrogate.w is not None:
            cands.sort(key=lambda s: surrogate.predict(_features(s)))
        n_eval = max(1, int(len(cands) * eval_frac))
        # detailed-model scoring of the surrogate-selected top fraction is
        # one vectorized batch; the SA walk below consumes the results in
        # the original order so the rng stream is untouched
        res = score_schemes(cands[:n_eval], hw,
                            nodes_assigned=constr.num_nodes,
                            src_onchip=constr.src_onchip,
                            dst_onchip=constr.dst_onchip)
        for bi, scheme in enumerate(cands[:n_eval]):
            cost = res.breakdown(bi)
            y = math.log1p(cost.energy_pj) if cost.valid else 60.0
            surrogate.add(_features(scheme), y)
            if not cost.valid:
                continue
            if cost.energy_pj < best[1].energy_pj:
                best = (scheme, cost)
            # SA accept/step
            if cost.energy_pj < cur_cost or \
                    rng.random() < math.exp(-(cost.energy_pj - cur_cost)
                                            / max(1e-9, cur_cost * T)):
                cur, cur_cost = scheme, cost.energy_pj
        surrogate.fit()
        T *= 0.95
    if best[0] is None:
        return solve_intra_layer(layer, hw, constr)
    return best


def solve(graph: LayerGraph, hw: HWTemplate, iters: int = 64,
          batch: int = 32, max_seg_len: int = 4, seed: int = 0):
    """ML-guided search: SA+surrogate intra-layer tuning within the shared
    inter-layer machinery (AutoTVM explores inter-layer exhaustively)."""
    from .kapla import solve as kapla_solve

    def layer_solver(layer, hw_, constr):
        return solve_layer_annealing(layer, hw_, constr, iters, batch,
                                     seed=seed)

    return kapla_solve(graph, hw, k_s=1, max_seg_len=max_seg_len,
                       layer_solver=layer_solver)
