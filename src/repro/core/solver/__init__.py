from . import annealing, exhaustive, memo, random_search
from .interlayer import Chain, PruneStats, dp_prioritize, enumerate_segments
from .intralayer import Constraints, solve_intra_layer
from .kapla import NetworkSchedule, solve

__all__ = [
    "Chain", "Constraints", "NetworkSchedule", "PruneStats", "annealing",
    "dp_prioritize", "enumerate_segments", "exhaustive", "memo",
    "random_search", "solve", "solve_intra_layer",
]
