from . import annealing, exhaustive, memo, random_search
from .interlayer import (Chain, PruneStats, dp_prioritize,
                         dp_prioritize_scalar, enumerate_segments,
                         enumerate_segments_scalar, segment_pool)
from .intralayer import Constraints, solve_intra_layer
from .kapla import (NetworkSchedule, greedy_chain, rebatch_scheme,
                    seed_chains_from, solve, solve_greedy, solve_many,
                    solve_topk, warm_layer_solver)
from .multinode import (MultiNodePlan, NodeMesh, plan_multinode,
                        repartition)

__all__ = [
    "Chain", "Constraints", "MultiNodePlan", "NetworkSchedule",
    "NodeMesh", "PruneStats", "annealing",
    "dp_prioritize", "dp_prioritize_scalar", "enumerate_segments",
    "enumerate_segments_scalar", "exhaustive", "greedy_chain", "memo",
    "plan_multinode", "random_search", "rebatch_scheme", "repartition",
    "seed_chains_from", "segment_pool",
    "solve", "solve_greedy", "solve_intra_layer", "solve_many",
    "solve_topk", "warm_layer_solver",
]
