"""KAPLA top-level solve: inter-layer DP prioritization + intra-layer
bottom-up cost descent, then final scoring with the detailed model (§IV)."""
from __future__ import annotations

import dataclasses
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Tuple

from ...hw.template import HWTemplate
from ...workloads.layers import LayerGraph, LayerSpec
from ..cost_model import CostBreakdown, combine_segment, evaluate_layer, invalid
from ..directives import LayerScheme
from .interlayer import Chain, PruneStats, dp_prioritize, io_flags, _consumer_map
from .intralayer import Constraints, solve_intra_layer


@dataclasses.dataclass
class NetworkSchedule:
    graph_name: str
    chain: Optional[Chain]
    layer_schemes: Dict[str, LayerScheme]
    layer_costs: Dict[str, CostBreakdown]
    total_energy_pj: float
    total_latency_cycles: float
    solve_seconds: float
    prune_stats: Optional[PruneStats] = None

    @property
    def valid(self) -> bool:
        return self.total_energy_pj != float("inf")

    def scheme(self, layer_name: str) -> LayerScheme:
        """The solved intra-layer scheme for one layer (KeyError if the
        layer was not scheduled)."""
        return self.layer_schemes[layer_name]

    def lower(self, graph: LayerGraph, hw: HWTemplate, repair: bool = True):
        """Compile this schedule into an executable ``NetworkPlan`` (the
        network lowering tier; see ``repro.lower.netplan``).  Imported
        lazily so the numpy-only solver core never pulls in jax."""
        from ...lower.netplan import lower_network
        return lower_network(self, graph, hw, repair=repair)

    # -- JSON (de)serialization ----------------------------------------------
    def to_json(self) -> Dict:
        """Serializable form of the whole solved schedule: per-layer schemes
        (with embedded layer specs), per-layer cost breakdowns, and the
        chosen inter-layer chain — enough to cache a solve or ship it to an
        executor without re-running the solver."""
        chain = None
        if self.chain is not None:
            chain = [{"start": s.start, "stop": s.stop,
                      "alloc": [list(a) for a in s.alloc],
                      "granule_frac": s.granule_frac}
                     for s in self.chain.segments]
        return {
            "graph_name": self.graph_name,
            "chain": chain,
            "layer_schemes": {n: s.to_json()
                              for n, s in self.layer_schemes.items()},
            "layer_costs": {n: dataclasses.asdict(c)
                            for n, c in self.layer_costs.items()},
            "total_energy_pj": self.total_energy_pj,
            "total_latency_cycles": self.total_latency_cycles,
            "solve_seconds": self.solve_seconds,
            "prune_stats": None if self.prune_stats is None
            else dataclasses.asdict(self.prune_stats),
        }

    @staticmethod
    def from_json(d: Dict, graph: Optional[LayerGraph] = None
                  ) -> "NetworkSchedule":
        """Rebuild a schedule; pass ``graph`` to re-bind schemes to existing
        ``LayerSpec`` objects (names must match) instead of reconstructing
        them from the embedded JSON."""
        from .interlayer import SegmentScheme
        chain = None
        if d.get("chain") is not None:
            chain = Chain(segments=tuple(
                SegmentScheme(start=s["start"], stop=s["stop"],
                              alloc=tuple(tuple(a) for a in s["alloc"]),
                              granule_frac=s["granule_frac"])
                for s in d["chain"]), est_cost=0.0)
        schemes = {}
        for name, sj in d["layer_schemes"].items():
            layer = graph.by_name[name] if graph is not None else None
            schemes[name] = LayerScheme.from_json(sj, layer=layer)
        costs = {n: CostBreakdown(**c)
                 for n, c in d.get("layer_costs", {}).items()}
        stats = d.get("prune_stats")
        return NetworkSchedule(
            graph_name=d["graph_name"], chain=chain, layer_schemes=schemes,
            layer_costs=costs,
            total_energy_pj=d["total_energy_pj"],
            total_latency_cycles=d["total_latency_cycles"],
            solve_seconds=d.get("solve_seconds", 0.0),
            prune_stats=None if stats is None else PruneStats(**stats))


def solve_segment(graph: LayerGraph, hw: HWTemplate, seg, consumers,
                  layer_solver=solve_intra_layer,
                  ) -> Tuple[Optional[CostBreakdown],
                             Dict[str, LayerScheme], Dict[str, CostBreakdown]]:
    """Solve every layer of one segment with ``layer_solver``.

    If fine-grained pipelining turns out infeasible at the intra-layer level
    (the conservative inter-layer check is allowed false positives, §IV-B),
    the segment degrades to coarse time-sharing of the same node regions."""
    seg_layers = graph.layers[seg.start:seg.stop]
    names = {l.name for l in seg_layers}
    for pipelined in ((True, False) if seg.length > 1 else (False,)):
        schemes: Dict[str, LayerScheme] = {}
        costs: Dict[str, CostBreakdown] = {}
        seg_costs: List[CostBreakdown] = []
        ok = True
        for i, layer in enumerate(seg_layers):
            src_on, dst_on = io_flags(graph, names, layer, consumers)
            if pipelined:
                constr = Constraints(
                    nodes=seg.alloc[i], src_onchip=src_on, dst_onchip=dst_on,
                    full_reduction_onchip=dst_on and seg.length > 1,
                    outer_dims=("N",) if seg.length > 1 else ())
            else:
                constr = Constraints(nodes=seg.alloc[i])
            scheme, cost = layer_solver(layer, hw, constr)
            if scheme is None or not cost.valid:
                ok = False
                break
            schemes[layer.name] = scheme
            costs[layer.name] = cost
            seg_costs.append(cost)
        if not ok:
            continue
        granules = max(1, int(round(1.0 / seg.granule_frac))) if pipelined \
            else 1
        total = combine_segment(seg_costs, granules=granules)
        if not pipelined and seg.length > 1:
            # coarse time-sharing: stages run back-to-back, not overlapped
            total.latency_cycles = sum(c.latency_cycles for c in seg_costs)
        return total, schemes, costs
    return None, {}, {}


def _seg_key(seg) -> Tuple:
    return (seg.start, seg.stop, seg.alloc, seg.granule_frac)


def _solve_chain(graph: LayerGraph, hw: HWTemplate, chain: Chain,
                 layer_solver=solve_intra_layer,
                 seg_cache: Optional[Dict] = None,
                 consumers: Optional[Dict] = None,
                 ) -> Tuple[float, float, Dict[str, LayerScheme],
                            Dict[str, CostBreakdown]]:
    consumers = consumers if consumers is not None else _consumer_map(graph)
    energy = 0.0
    latency = 0.0
    schemes: Dict[str, LayerScheme] = {}
    costs: Dict[str, CostBreakdown] = {}
    for seg in chain.segments:
        # k_S candidate chains share most of their segments: solve each
        # distinct (range, alloc, granule) segment once per solve() call
        key = _seg_key(seg)
        if seg_cache is not None and key in seg_cache:
            seg_total, seg_schemes, seg_costs = seg_cache[key]
        else:
            seg_total, seg_schemes, seg_costs = solve_segment(
                graph, hw, seg, consumers, layer_solver)
            if seg_cache is not None:
                seg_cache[key] = (seg_total, seg_schemes, seg_costs)
        if seg_total is None:
            return float("inf"), float("inf"), {}, {}
        schemes.update(seg_schemes)
        costs.update(seg_costs)
        energy += seg_total.energy_pj
        latency += seg_total.latency_cycles
    return energy, latency, schemes, costs


def solve(graph: LayerGraph, hw: HWTemplate, k_s: int = 4,
          max_seg_len: int = 4, objective: str = "energy",
          layer_solver=solve_intra_layer,
          max_workers: Optional[int] = None) -> NetworkSchedule:
    """Two-level solve: batched inter-layer DP prioritization on top, then
    the k_S candidate chains' distinct segments detail-solved concurrently
    (the intra-layer judge is numpy-bound and releases the GIL, and the
    memo layer is thread-safe).  ``max_workers=1`` forces a serial solve.

    Pre-solving every distinct segment trades the old per-chain early-abort
    for parallelism; that abort was nearly dead code, since the coarse
    time-sharing fallback in ``solve_segment`` is valid by construction and
    segments therefore almost never fail outright."""
    t0 = time.perf_counter()
    stats = PruneStats()
    chains = dp_prioritize(graph, hw, k_s=k_s, max_seg_len=max_seg_len,
                           objective=objective, stats=stats)
    best = NetworkSchedule(graph.name, None, {}, {}, float("inf"),
                           float("inf"), 0.0, stats)
    consumers = _consumer_map(graph)
    # the chains share most of their segments: collect the distinct ones up
    # front and solve them in parallel before the (cheap) chain scoring
    distinct: Dict[Tuple, object] = {}
    for chain in chains:
        for seg in chain.segments:
            distinct.setdefault(_seg_key(seg), seg)
    workers = max_workers if max_workers is not None else \
        min(8, os.cpu_count() or 1)
    workers = max(1, min(workers, len(distinct)))
    seg_cache: Dict = {}
    if workers > 1:
        with ThreadPoolExecutor(max_workers=workers) as ex:
            futs = {key: ex.submit(solve_segment, graph, hw, seg, consumers,
                                   layer_solver)
                    for key, seg in distinct.items()}
            seg_cache = {key: f.result() for key, f in futs.items()}
    else:
        seg_cache = {key: solve_segment(graph, hw, seg, consumers,
                                        layer_solver)
                     for key, seg in distinct.items()}
    for chain in chains:
        e, lat, schemes, costs = _solve_chain(graph, hw, chain, layer_solver,
                                              seg_cache, consumers)
        score = e if objective == "energy" else e * lat \
            if objective == "edp" else lat
        best_score = best.total_energy_pj if objective == "energy" else \
            best.total_energy_pj * best.total_latency_cycles \
            if objective == "edp" else best.total_latency_cycles
        if score < best_score:
            best = NetworkSchedule(graph.name, chain, schemes, costs, e, lat,
                                   0.0, stats)
    best.solve_seconds = time.perf_counter() - t0
    return best
