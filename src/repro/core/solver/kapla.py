"""KAPLA top-level solve: inter-layer DP prioritization + intra-layer
bottom-up cost descent, then final scoring with the detailed model (§IV).

Beyond the single argmin ``solve``, this module exposes the entry points
the schedule service (``repro.service``) is built on:

  * ``solve_topk`` — the k best valid chains, each detail-solved into a
    full ``NetworkSchedule`` (measured re-ranking picks among them);
  * ``seed_chains_from`` + ``solve(..., seed_chains=, use_dp=False)`` —
    warm-starting a solve from a previously solved schedule of the same
    graph family (e.g. a different batch size), skipping the DP;
  * ``solve_many`` — several graphs solved together, with the distinct
    segments of *all* requests pooled into one ThreadPoolExecutor pass
    (the server's request-coalescing batch path).
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

from ...hw.template import HWTemplate
from ...obs import metrics, trace
from ...runtime import inject
from ...workloads.layers import LayerGraph, LayerSpec
from ..cost_model import CostBreakdown, attribute_costs, combine_segment, \
    cycle_terms, evaluate_layer
from ..directives import LayerScheme
from .interlayer import Chain, PruneStats, dp_prioritize, io_flags, \
    _consumer_map
from .intralayer import Constraints, solve_intra_layer

# -- telemetry (repro.obs) ---------------------------------------------------
_m_segments = metrics.counter(
    "solver_segments_total", "detail-solved segments, by outcome",
    ("outcome",))
_m_segcache = metrics.counter(
    "solver_segcache_total",
    "per-solve segment-cache lookups during chain scoring", ("outcome",))
_m_candidates = metrics.counter(
    "solver_candidates_total",
    "inter-layer segment candidates, by pruning stage", ("stage",))
_m_chains = metrics.counter(
    "solver_chains_total", "candidate chains, by scoring outcome",
    ("outcome",))
_m_solve_seconds = metrics.histogram(
    "solver_solve_seconds", "end-to-end network solve wall clock",
    ("entry",))


@dataclasses.dataclass
class NetworkSchedule:
    graph_name: str
    chain: Optional[Chain]
    layer_schemes: Dict[str, LayerScheme]
    layer_costs: Dict[str, CostBreakdown]
    total_energy_pj: float
    total_latency_cycles: float
    solve_seconds: float
    prune_stats: Optional[PruneStats] = None
    # per-chain-segment fine-grained-pipelining flags (aligned with
    # chain.segments): whether the segment runs overlapped (granule
    # forwarding) or degraded to coarse time-sharing.  Recorded so a
    # deserialized schedule can be re-scored bit-identically without
    # re-running the intra-layer solver (``rescore``).
    seg_pipelined: Optional[Tuple[bool, ...]] = None
    # the solver flight-recorder block (obs.explain): candidate funnel,
    # per-term cost attribution, runners-up.  A plain JSON-safe dict so
    # it round-trips through to_json/from_json and therefore persists
    # inside ScheduleStore records untouched.  None unless the solve ran
    # with explain enabled — the default keeps solves overhead-free.
    explain: Optional[Dict] = None

    @property
    def valid(self) -> bool:
        return self.total_energy_pj != float("inf")

    def scheme(self, layer_name: str) -> LayerScheme:
        """The solved intra-layer scheme for one layer (KeyError if the
        layer was not scheduled)."""
        return self.layer_schemes[layer_name]

    def lower(self, graph: LayerGraph, hw: HWTemplate, repair: bool = True):
        """Compile this schedule into an executable ``NetworkPlan`` (the
        network lowering tier; see ``repro.lower.netplan``).  Imported
        lazily so the numpy-only solver core never pulls in jax."""
        from ...lower.netplan import lower_network
        return lower_network(self, graph, hw, repair=repair)

    def to_graph(self) -> LayerGraph:
        """Rebuild a ``LayerGraph`` from the layer specs embedded in the
        schemes, in schedule order — lets a store-loaded schedule be
        re-scored or lowered without the original graph object (the
        schemes' dict order is the solve's topological order)."""
        return LayerGraph(self.graph_name,
                          [s.layer for s in self.layer_schemes.values()])

    # -- re-scoring ----------------------------------------------------------
    def rescore(self, graph: Optional[LayerGraph] = None,
                hw: Optional[HWTemplate] = None
                ) -> Tuple[float, float, Dict[str, CostBreakdown]]:
        """Recompute (total_energy_pj, total_latency_cycles, layer_costs)
        from the stored schemes by replaying the chain's segment context —
        io flags, the recorded pipelined/coarse choice, granule combining.
        Bit-identical to the original solve for schedules produced by
        ``solve`` (the store's parity gate).  ``hw`` is required; ``graph``
        defaults to ``to_graph()``."""
        if hw is None:
            raise ValueError("rescore needs the HWTemplate the schedule "
                             "was solved for")
        graph = graph if graph is not None else self.to_graph()
        consumers = _consumer_map(graph)
        if self.chain is None or not self.chain.segments:
            costs = {n: evaluate_layer(s, hw)
                     for n, s in self.layer_schemes.items()}
            e = sum(c.energy_pj for c in costs.values())
            lat = sum(c.latency_cycles for c in costs.values())
            return e, lat, costs
        pipe = self.seg_pipelined if self.seg_pipelined is not None \
            else tuple(False for _ in self.chain.segments)
        energy = 0.0
        latency = 0.0
        costs: Dict[str, CostBreakdown] = {}
        for seg, pipelined in zip(self.chain.segments, pipe):
            seg_layers = graph.layers[seg.start:seg.stop]
            names = {l.name for l in seg_layers}
            seg_costs: List[CostBreakdown] = []
            for i, layer in enumerate(seg_layers):
                src_on, dst_on = io_flags(graph, names, layer, consumers)
                nodes = seg.alloc[i][0] * seg.alloc[i][1]
                c = evaluate_layer(
                    self.layer_schemes[layer.name], hw,
                    nodes_assigned=nodes,
                    src_onchip=src_on if pipelined else False,
                    dst_onchip=dst_on if pipelined else False)
                costs[layer.name] = c
                seg_costs.append(c)
            granules = max(1, int(round(1.0 / seg.granule_frac))) \
                if pipelined else 1
            total = combine_segment(seg_costs, granules=granules)
            if not pipelined and seg.length > 1:
                total.latency_cycles = sum(c.latency_cycles
                                           for c in seg_costs)
            energy += total.energy_pj
            latency += total.latency_cycles
        return energy, latency, costs

    # -- JSON (de)serialization ----------------------------------------------
    def to_json(self) -> Dict:
        """Serializable form of the whole solved schedule: per-layer schemes
        (with embedded layer specs), per-layer cost breakdowns, and the
        chosen inter-layer chain — enough to cache a solve or ship it to an
        executor without re-running the solver."""
        chain = None
        if self.chain is not None:
            pipe = self.seg_pipelined if self.seg_pipelined is not None \
                else tuple(None for _ in self.chain.segments)
            chain = [{"start": s.start, "stop": s.stop,
                      "alloc": [list(a) for a in s.alloc],
                      "granule_frac": s.granule_frac,
                      "pipelined": p}
                     for s, p in zip(self.chain.segments, pipe)]
        return {
            "graph_name": self.graph_name,
            "chain": chain,
            "chain_est_cost": None if self.chain is None
            else self.chain.est_cost,
            "layer_schemes": {n: s.to_json()
                              for n, s in self.layer_schemes.items()},
            "layer_costs": {n: dataclasses.asdict(c)
                            for n, c in self.layer_costs.items()},
            "total_energy_pj": self.total_energy_pj,
            "total_latency_cycles": self.total_latency_cycles,
            "solve_seconds": self.solve_seconds,
            "prune_stats": None if self.prune_stats is None
            else dataclasses.asdict(self.prune_stats),
            "explain": self.explain,
        }

    @staticmethod
    def from_json(d: Dict, graph: Optional[LayerGraph] = None
                  ) -> "NetworkSchedule":
        """Rebuild a schedule; pass ``graph`` to re-bind schemes to existing
        ``LayerSpec`` objects (names must match) instead of reconstructing
        them from the embedded JSON.  Fully functional without a live graph
        (store reads): ``to_graph``/``rescore``/``lower`` all work off the
        embedded specs."""
        from .interlayer import SegmentScheme
        chain = None
        pipelined: Optional[Tuple[bool, ...]] = None
        if d.get("chain") is not None:
            chain = Chain(segments=tuple(
                SegmentScheme(start=s["start"], stop=s["stop"],
                              alloc=tuple(tuple(a) for a in s["alloc"]),
                              granule_frac=s["granule_frac"])
                for s in d["chain"]),
                est_cost=d.get("chain_est_cost") or 0.0)
            flags = [s.get("pipelined") for s in d["chain"]]
            if all(f is not None for f in flags):
                pipelined = tuple(bool(f) for f in flags)
        schemes = {}
        for name, sj in d["layer_schemes"].items():
            layer = graph.by_name[name] if graph is not None else None
            schemes[name] = LayerScheme.from_json(sj, layer=layer)
        costs = {n: CostBreakdown(**c)
                 for n, c in d.get("layer_costs", {}).items()}
        stats = d.get("prune_stats")
        return NetworkSchedule(
            graph_name=d["graph_name"], chain=chain, layer_schemes=schemes,
            layer_costs=costs,
            total_energy_pj=d["total_energy_pj"],
            total_latency_cycles=d["total_latency_cycles"],
            solve_seconds=d.get("solve_seconds", 0.0),
            prune_stats=None if stats is None else PruneStats(**stats),
            seg_pipelined=pipelined, explain=d.get("explain"))


def solve_segment(graph: LayerGraph, hw: HWTemplate, seg, consumers,
                  layer_solver=solve_intra_layer,
                  ) -> Tuple[Optional[CostBreakdown],
                             Dict[str, LayerScheme],
                             Dict[str, CostBreakdown], bool]:
    """Solve every layer of one segment with ``layer_solver``.

    If fine-grained pipelining turns out infeasible at the intra-layer level
    (the conservative inter-layer check is allowed false positives, §IV-B),
    the segment degrades to coarse time-sharing of the same node regions.
    Returns (total, schemes, costs, pipelined)."""
    with trace.span("solve.segment", graph=graph.name,
                    seg=f"{seg.start}:{seg.stop}") as sp:
        total, schemes, costs, pipelined = _solve_segment_impl(
            graph, hw, seg, consumers, layer_solver)
        outcome = "infeasible" if total is None else \
            "pipelined" if pipelined else "coarse"
        sp.set(outcome=outcome)
    _m_segments.inc(outcome=outcome)
    return total, schemes, costs, pipelined


def _solve_segment_impl(graph: LayerGraph, hw: HWTemplate, seg, consumers,
                        layer_solver):
    # chaos hook: a seeded injector can crash ("error") or stall ("slow")
    # this segment solve — thread-pool workers inherit the global injector
    inject.maybe_fault("solve.segment",
                       key=f"{graph.name}:{seg.start}:{seg.stop}")
    seg_layers = graph.layers[seg.start:seg.stop]
    names = {l.name for l in seg_layers}
    for pipelined in ((True, False) if seg.length > 1 else (False,)):
        schemes: Dict[str, LayerScheme] = {}
        costs: Dict[str, CostBreakdown] = {}
        seg_costs: List[CostBreakdown] = []
        ok = True
        for i, layer in enumerate(seg_layers):
            src_on, dst_on = io_flags(graph, names, layer, consumers)
            if pipelined:
                constr = Constraints(
                    nodes=seg.alloc[i], src_onchip=src_on, dst_onchip=dst_on,
                    full_reduction_onchip=dst_on and seg.length > 1,
                    outer_dims=("N",) if seg.length > 1 else ())
            else:
                constr = Constraints(nodes=seg.alloc[i])
            scheme, cost = layer_solver(layer, hw, constr)
            if scheme is None or not cost.valid:
                ok = False
                break
            schemes[layer.name] = scheme
            costs[layer.name] = cost
            seg_costs.append(cost)
        if not ok:
            continue
        granules = max(1, int(round(1.0 / seg.granule_frac))) if pipelined \
            else 1
        total = combine_segment(seg_costs, granules=granules)
        if not pipelined and seg.length > 1:
            # coarse time-sharing: stages run back-to-back, not overlapped
            total.latency_cycles = sum(c.latency_cycles for c in seg_costs)
        return total, schemes, costs, pipelined
    return None, {}, {}, False


def _seg_key(seg) -> Tuple:
    return seg.key


def _chain_key(chain: Chain) -> Tuple:
    return chain.key


def seed_chains_from(schedule: NetworkSchedule, graph: LayerGraph
                     ) -> List[Chain]:
    """Warm-start candidate chains derived from a previously solved
    schedule of the same graph *family* (identical layer structure, any
    batch size): the stored segment slicing and node allocations are
    reused, with pipelined granule fractions re-derived for the new
    graph's batch dimension.  Returns [] when the stored chain does not
    tile this graph's layer list."""
    from .interlayer import SegmentScheme
    if schedule.chain is None or not schedule.chain.segments:
        return []
    segs = schedule.chain.segments
    n = len(graph.layers)
    expect = 0
    for s in segs:
        if s.start != expect or s.stop > n:
            return []
        expect = s.stop
    if expect != n:
        return []
    out = []
    for s in segs:
        gf = 1.0 if s.granule_frac >= 1.0 \
            else 1.0 / graph.layers[s.start].dim("N")
        out.append(SegmentScheme(s.start, s.stop, s.alloc, gf))
    return [Chain(segments=tuple(out), est_cost=0.0)]


def rebatch_scheme(stored: LayerScheme,
                   layer: LayerSpec) -> Optional[LayerScheme]:
    """Adapt a stored intra-layer scheme to a layer identical except in
    batch (N): spatial N unrolling is preserved exactly, temporal N
    factors are re-fit inner -> outer (each level keeps the largest
    divisor of the remaining batch it held before — shrinking a temporal
    tile only shrinks footprints, so capacity validity is preserved), and
    the outermost level absorbs the leftover.  Returns None when the new
    batch does not cover the stored spatial unrolling — the caller falls
    back to a real intra-layer solve; the judge re-scores the result
    either way."""
    levels = [lv.copy() for lv in stored.levels]
    spatial = 1
    for lv in levels:
        spatial *= lv.sf("N")
    new_n = layer.dim("N")
    if spatial <= 0 or new_n % spatial:
        return None
    r = new_n // spatial
    for lv in levels[:-1]:
        keep = math.gcd(lv.tf("N"), r)
        if keep > 1:
            lv.t["N"] = keep
        else:
            lv.t.pop("N", None)
        r //= keep
    levels[-1].t["N"] = r
    return LayerScheme(layer, levels)


def scheme_transfers(scheme: LayerScheme, layer: LayerSpec,
                     constr: Constraints) -> bool:
    """Whether a rebatched scheme satisfies the *solver-side* constraints
    the judge does not check: forwarding granularity (outer_dims leading
    the DRAM order) and full on-chip reduction for pipelined producers."""
    top = scheme.levels[-1]
    if constr.full_reduction_onchip and \
            any(top.tf(d) > 1 for d in layer.reduction_dims):
        return False
    if constr.outer_dims and \
            tuple(top.order[:len(constr.outer_dims)]) \
            != tuple(constr.outer_dims):
        return False
    return True


def warm_layer_solver(stored_schemes: Dict[str, LayerScheme],
                      layer_solver=solve_intra_layer):
    """An intra-layer solver that *transfers* stored schemes first: the
    stored scheme for the layer's name is rebatched to the requested
    layer, checked against the inter-layer constraints, and scored with
    the detailed judge — replacing a greedy solve + order enumeration
    with a single evaluation.  Layers without a transferable scheme fall
    through to ``layer_solver``.  This is what makes a family near-miss
    (same graph, different batch) a *warm* start rather than a re-solve.
    """
    def solver(layer: LayerSpec, hw: HWTemplate, constr: Constraints):
        stored = stored_schemes.get(layer.name)
        if stored is not None:
            cand = rebatch_scheme(stored, layer)
            if cand is not None and scheme_transfers(cand, layer, constr):
                cost = evaluate_layer(cand, hw,
                                      nodes_assigned=constr.num_nodes,
                                      src_onchip=constr.src_onchip,
                                      dst_onchip=constr.dst_onchip)
                if cost.valid:
                    return cand, cost
        return layer_solver(layer, hw, constr)
    return solver


def _invalid_schedule(graph: LayerGraph,
                      stats: Optional[PruneStats]) -> NetworkSchedule:
    return NetworkSchedule(graph.name, None, {}, {}, float("inf"),
                           float("inf"), 0.0, stats)


def _chain_score(energy: float, latency: float, objective: str) -> float:
    return energy if objective == "energy" else energy * latency \
        if objective == "edp" else latency


def _pool_solve_segments(jobs: Sequence[Tuple], hw: HWTemplate,
                         max_workers: Optional[int]) -> None:
    """Detail-solve distinct segments, possibly spanning several graphs, in
    one shared ThreadPoolExecutor (the intra-layer judge is numpy-bound and
    releases the GIL; the memo layer is thread-safe).  ``jobs`` are
    (graph, consumers, seg_cache, distinct, layer_solver) tuples; results
    land in each job's seg_cache dict."""
    flat = []
    for graph, consumers, seg_cache, distinct, solver in jobs:
        for key, seg in distinct.items():
            flat.append((graph, consumers, seg_cache, key, seg, solver))
    workers = max_workers if max_workers is not None else \
        min(8, os.cpu_count() or 1)
    workers = max(1, min(workers, len(flat) or 1))
    if workers > 1:
        with ThreadPoolExecutor(max_workers=workers) as ex:
            futs = [(seg_cache, key,
                     ex.submit(solve_segment, graph, hw, seg, consumers,
                               solver))
                    for graph, consumers, seg_cache, key, seg, solver
                    in flat]
            for seg_cache, key, f in futs:
                seg_cache[key] = f.result()
    else:
        for graph, consumers, seg_cache, key, seg, solver in flat:
            seg_cache[key] = solve_segment(graph, hw, seg, consumers,
                                           solver)


def _solve_chain(graph: LayerGraph, hw: HWTemplate, chain: Chain,
                 layer_solver=solve_intra_layer,
                 seg_cache: Optional[Dict] = None,
                 consumers: Optional[Dict] = None,
                 ) -> Tuple[float, float, Dict[str, LayerScheme],
                            Dict[str, CostBreakdown], Tuple[bool, ...]]:
    consumers = consumers if consumers is not None else _consumer_map(graph)
    energy = 0.0
    latency = 0.0
    schemes: Dict[str, LayerScheme] = {}
    costs: Dict[str, CostBreakdown] = {}
    pipelined: List[bool] = []
    for seg in chain.segments:
        # k_S candidate chains share most of their segments: solve each
        # distinct (range, alloc, granule) segment once per solve() call
        key = _seg_key(seg)
        if seg_cache is not None and key in seg_cache:
            _m_segcache.inc(outcome="hit")
            seg_total, seg_schemes, seg_costs, pipe = seg_cache[key]
        else:
            if seg_cache is not None:
                _m_segcache.inc(outcome="miss")
            seg_total, seg_schemes, seg_costs, pipe = solve_segment(
                graph, hw, seg, consumers, layer_solver)
            if seg_cache is not None:
                seg_cache[key] = (seg_total, seg_schemes, seg_costs, pipe)
        if seg_total is None:
            return float("inf"), float("inf"), {}, {}, ()
        schemes.update(seg_schemes)
        costs.update(seg_costs)
        pipelined.append(pipe)
        energy += seg_total.energy_pj
        latency += seg_total.latency_cycles
    return energy, latency, schemes, costs, tuple(pipelined)


def _record_prune(stats: PruneStats, before: Tuple[int, int, int]
                  ) -> None:
    """Publish one DP run's candidate funnel (enumerated -> validity ->
    Pareto-kept) as counter deltas against the pre-run snapshot."""
    _m_candidates.inc(stats.total - before[0], stage="enumerated")
    _m_candidates.inc(stats.after_validity - before[1], stage="valid")
    _m_candidates.inc(stats.after_pareto - before[2], stage="kept")


def _candidate_chains(graph: LayerGraph, hw: HWTemplate, k_s: int,
                      max_seg_len: int, objective: str,
                      stats: PruneStats,
                      seed_chains: Optional[Sequence[Chain]],
                      use_dp: bool, explain=None) -> List[Chain]:
    """DP-prioritized chains plus deduplicated warm-start seeds (seeds
    first, so ties between a seed and an identical DP chain keep the
    seed's detail solve)."""
    chains: List[Chain] = list(seed_chains or ())
    if use_dp or not chains:
        chains = chains + dp_prioritize(graph, hw, k_s=k_s,
                                        max_seg_len=max_seg_len,
                                        objective=objective, stats=stats,
                                        explain=explain)
    seen = set()
    uniq = []
    for c in chains:
        key = _chain_key(c)
        if key not in seen:
            seen.add(key)
            uniq.append(c)
    return uniq


#: runners-up captured into an explain record (cost deltas only — the
#: losing chains' detail solves are not persisted)
EXPLAIN_MAX_RUNNERS_UP = 8


def _finish_explain(sink, graph: LayerGraph, hw: HWTemplate,
                    objective: str,
                    scored: Sequence[Tuple[float, int, "NetworkSchedule"]],
                    best: "NetworkSchedule") -> Dict:
    """Fill the winner / runners-up sections of an explain sink from one
    ``solve_topk`` scoring pass and return the finished record."""
    sink.set("graph", graph.name)
    sink.set("objective", objective)
    pipe = best.seg_pipelined or ()
    segments: List[Dict] = []
    if best.chain is not None:
        for i, seg in enumerate(best.chain.segments):
            seg_layers = graph.layers[seg.start:seg.stop]
            seg_attr = attribute_costs(
                best.layer_costs[l.name] for l in seg_layers
                if l.name in best.layer_costs)
            segments.append({
                "start": seg.start, "stop": seg.stop,
                "alloc": [list(a) for a in seg.alloc],
                "granule_frac": seg.granule_frac,
                "pipelined": bool(pipe[i]) if i < len(pipe) else None,
                "attribution": seg_attr})
    costs = list(best.layer_costs.values())
    cyc = {"cyc_compute": 0.0, "cyc_dram": 0.0, "cyc_gbuf": 0.0}
    for name, c in best.layer_costs.items():
        macs = best.layer_schemes[name].layer.total_macs()
        for k_, v in cycle_terms(c, macs, hw).items():
            cyc[k_] += v
    grid_h, grid_w = hw.node_array
    n_costs = max(1, len(costs))
    winner = {
        "score": _chain_score(best.total_energy_pj,
                              best.total_latency_cycles, objective),
        "energy_pj": best.total_energy_pj,
        "latency_cycles": best.total_latency_cycles,
        "segments": segments,
        "attribution": attribute_costs(costs),
        "cycle_terms": cyc,
        "occupancy": {
            "avg_pes_used": sum(c.pes_used for c in costs) / n_costs,
            "avg_nodes_used": sum(c.nodes_used for c in costs) / n_costs,
            "grid_nodes": grid_h * grid_w,
            "pes_per_node": hw.num_pes_per_node,
        },
    }
    sink.set_winner(winner)
    runners: List[Dict] = []
    for rank, (score, _, sched) in enumerate(
            scored[1:1 + EXPLAIN_MAX_RUNNERS_UP], start=2):
        delta = score - winner["score"]
        runners.append({
            "rank": rank, "score": score, "delta": delta,
            "delta_frac": delta / winner["score"] if winner["score"]
            else 0.0,
            "segments": [] if sched.chain is None else
            [{"start": s.start, "stop": s.stop,
              "granule_frac": s.granule_frac}
             for s in sched.chain.segments]})
    sink.set_runners_up(runners)
    # the funnel groups of the winning chain, for the rendered table
    funnel = sink.record.get("funnel")
    if funnel and best.chain is not None:
        want = {(s.start, s.stop) for s in best.chain.segments}
        funnel["winner_groups"] = [
            g for g in funnel.get("groups", ())
            if (g["start"], g["stop"]) in want]
    return sink.to_json()


def solve_topk(graph: LayerGraph, hw: HWTemplate, k: int = 1,
               k_s: int = 4, max_seg_len: int = 4,
               objective: str = "energy", layer_solver=solve_intra_layer,
               max_workers: Optional[int] = None,
               seed_chains: Optional[Sequence[Chain]] = None,
               use_dp: bool = True,
               stats_out: Optional[PruneStats] = None,
               explain=False) -> List[NetworkSchedule]:
    """The k best valid chains, each detail-solved into a full
    ``NetworkSchedule``, best first (detailed-model score under
    ``objective``).  ``solve`` is the ``k=1`` argmin special case; the
    autotuner re-ranks the returned candidates by *measured* runtime.

    ``seed_chains`` prepends warm-start candidate chains (see
    ``seed_chains_from``); ``use_dp=False`` skips the DP entirely and
    detail-solves only the seeds — the store's warm path, trading
    optimality for speed.  ``stats_out``, when given, receives the prune
    counters even when no valid schedule exists (the returned list is
    then empty).

    ``explain`` turns on the solver flight recorder: pass ``True`` (or
    an ``obs.explain.ExplainSink`` to share across tiers) and the best
    schedule's ``.explain`` carries the candidate funnel, per-term cost
    attribution and runners-up — persisted through ``to_json`` into
    store records.  Off by default: the disabled path adds nothing."""
    t0 = time.perf_counter()
    stats = stats_out if stats_out is not None else PruneStats()
    sink = None
    if explain:
        from ...obs.explain import ExplainSink
        sink = explain if isinstance(explain, ExplainSink) \
            else ExplainSink()
    k_eff = max(k_s, k)
    before = (stats.total, stats.after_validity, stats.after_pareto)
    with trace.span("solve.dp", graph=graph.name, k_s=k_eff):
        chains = _candidate_chains(graph, hw, k_eff, max_seg_len,
                                   objective, seed_chains=seed_chains,
                                   stats=stats, use_dp=use_dp,
                                   explain=sink)
    _record_prune(stats, before)
    consumers = _consumer_map(graph)
    # the chains share most of their segments: collect the distinct ones up
    # front and solve them in parallel before the (cheap) chain scoring
    distinct: Dict[Tuple, object] = {}
    for chain in chains:
        for seg in chain.segments:
            distinct.setdefault(_seg_key(seg), seg)
    seg_cache: Dict = {}
    with trace.span("solve.segments_pool", graph=graph.name,
                    n=len(distinct)):
        _pool_solve_segments([(graph, consumers, seg_cache, distinct,
                               layer_solver)], hw, max_workers)
    scored: List[Tuple[float, int, NetworkSchedule]] = []
    with trace.span("solve.chain_score", graph=graph.name,
                    n=len(chains)):
        for ci, chain in enumerate(chains):
            e, lat, schemes, costs, pipe = _solve_chain(
                graph, hw, chain, layer_solver, seg_cache, consumers)
            score = _chain_score(e, lat, objective)
            if score == float("inf"):
                _m_chains.inc(outcome="infeasible")
                continue
            _m_chains.inc(outcome="scored")
            scored.append((score, ci, NetworkSchedule(
                graph.name, chain, schemes, costs, e, lat, 0.0, stats,
                pipe)))
    scored.sort(key=lambda t: (t[0], t[1]))     # stable: DP order on ties
    out = [s for _, _, s in scored[:max(1, k)]]
    if sink is not None and out:
        out[0].explain = _finish_explain(sink, graph, hw, objective,
                                         scored, out[0])
    elapsed = time.perf_counter() - t0
    _m_solve_seconds.observe(elapsed, entry="topk")
    for s in out:
        s.solve_seconds = elapsed
    return out


def solve(graph: LayerGraph, hw: HWTemplate, k_s: int = 4,
          max_seg_len: int = 4, objective: str = "energy",
          layer_solver=solve_intra_layer,
          max_workers: Optional[int] = None,
          seed_chains: Optional[Sequence[Chain]] = None,
          use_dp: bool = True, explain=False) -> NetworkSchedule:
    """Two-level solve: batched inter-layer DP prioritization on top, then
    the k_S candidate chains' distinct segments detail-solved concurrently
    (the intra-layer judge is numpy-bound and releases the GIL, and the
    memo layer is thread-safe).  ``max_workers=1`` forces a serial solve.

    Pre-solving every distinct segment trades the old per-chain early-abort
    for parallelism; that abort was nearly dead code, since the coarse
    time-sharing fallback in ``solve_segment`` is valid by construction and
    segments therefore almost never fail outright."""
    t0 = time.perf_counter()
    stats = PruneStats()
    res = solve_topk(graph, hw, k=1, k_s=k_s, max_seg_len=max_seg_len,
                     objective=objective, layer_solver=layer_solver,
                     max_workers=max_workers, seed_chains=seed_chains,
                     use_dp=use_dp, stats_out=stats, explain=explain)
    if not res:
        best = _invalid_schedule(graph, stats)
        best.solve_seconds = time.perf_counter() - t0
        return best
    return res[0]


def greedy_chain(graph: LayerGraph, hw: HWTemplate) -> Chain:
    """The trivial chain: every layer alone in its own segment on the
    full node array, no pipelining.  Always tiles the graph, never needs
    the DP, and its segments are valid whenever *any* schedule is — the
    first-valid floor of the service's degradation ladder."""
    from .interlayer import SegmentScheme
    H, W = hw.node_array
    return Chain(segments=tuple(
        SegmentScheme(i, i + 1, ((H, W),), 1.0)
        for i in range(len(graph.layers))), est_cost=0.0)


def solve_greedy(graph: LayerGraph, hw: HWTemplate,
                 objective: str = "energy",
                 layer_solver=solve_intra_layer,
                 max_workers: Optional[int] = None,
                 **_opts) -> NetworkSchedule:
    """First-valid greedy solve: detail-solve only the trivial chain
    (``greedy_chain``), skipping the DP and the k_S candidate
    enumeration.  The cheapest answer the solver can produce — what a
    deadline-blown service request degrades to rather than timing out
    empty-handed.  Extra solver options (k_s, max_seg_len) are accepted
    and ignored so request options can be passed through unchanged."""
    return solve(graph, hw, k_s=1, max_seg_len=1, objective=objective,
                 layer_solver=layer_solver, max_workers=max_workers,
                 seed_chains=[greedy_chain(graph, hw)], use_dp=False)


def solve_many(items: Sequence[Tuple[LayerGraph, HWTemplate]],
               k_s: int = 4, max_seg_len: int = 4,
               objective: str = "energy", layer_solver=solve_intra_layer,
               max_workers: Optional[int] = None,
               seed_chains: Optional[Sequence[Optional[Sequence[Chain]]]]
               = None, seeds_only: bool = True,
               layer_solvers: Optional[Sequence] = None,
               ) -> List[NetworkSchedule]:
    """Solve several (graph, hw) requests together: each request's DP runs
    first (vectorized, cheap), then the distinct detail-solve segments of
    *all* requests are pooled into one ThreadPoolExecutor pass — the
    schedule server's coalescing batch path.  Layers repeated across
    requests (same canonical signature + hw) additionally collapse in the
    intra-layer memo.  ``seed_chains[i]``, when given, warm-starts request
    ``i``; with ``seeds_only`` (the default, matching ``LocalClient``'s
    warm path) a seeded request skips its DP entirely and detail-solves
    just the seeds.  ``layer_solvers[i]`` overrides the intra-layer solver
    per request (e.g. ``warm_layer_solver`` transferring stored schemes)."""
    t0 = time.perf_counter()
    per: List[Tuple] = []
    jobs = []
    for i, (graph, hw) in enumerate(items):
        stats = PruneStats()
        seeds = seed_chains[i] if seed_chains is not None else None
        solver = layer_solvers[i] if layer_solvers is not None \
            and layer_solvers[i] is not None else layer_solver
        with trace.span("solve.dp", graph=graph.name, k_s=k_s):
            chains = _candidate_chains(graph, hw, k_s, max_seg_len,
                                       objective, stats, seeds,
                                       use_dp=not (seeds and seeds_only))
        _record_prune(stats, (0, 0, 0))
        consumers = _consumer_map(graph)
        distinct: Dict[Tuple, object] = {}
        for chain in chains:
            for seg in chain.segments:
                distinct.setdefault(_seg_key(seg), seg)
        seg_cache: Dict = {}
        per.append((graph, hw, chains, consumers, seg_cache, stats,
                    solver))
        jobs.append((graph, consumers, seg_cache, distinct, solver))
    # hw is shared per pooled pass in practice; solve per-request hw anyway
    # by grouping jobs on hw identity
    by_hw: Dict[HWTemplate, List] = {}
    for (graph, hw, *_), job in zip(per, jobs):
        by_hw.setdefault(hw, []).append(job)
    for hw_key, hw_jobs in by_hw.items():
        with trace.span("solve.segments_pool", n=len(hw_jobs)):
            _pool_solve_segments(hw_jobs, hw_key, max_workers)
    out: List[NetworkSchedule] = []
    elapsed = time.perf_counter() - t0
    for graph, hw, chains, consumers, seg_cache, stats, solver in per:
        best: Optional[Tuple[float, int, NetworkSchedule]] = None
        for ci, chain in enumerate(chains):
            e, lat, schemes, costs, pipe = _solve_chain(
                graph, hw, chain, solver, seg_cache, consumers)
            score = _chain_score(e, lat, objective)
            if score == float("inf"):
                _m_chains.inc(outcome="infeasible")
                continue
            _m_chains.inc(outcome="scored")
            if best is None or (score, ci) < (best[0], best[1]):
                best = (score, ci, NetworkSchedule(
                    graph.name, chain, schemes, costs, e, lat, elapsed,
                    stats, pipe))
        sched = best[2] if best is not None else \
            _invalid_schedule(graph, stats)
        sched.solve_seconds = elapsed
        out.append(sched)
    _m_solve_seconds.observe(elapsed, entry="many")
    return out
