"""Baseline S: exhaustive search over the directive scheme space (§V).

Enumerates, per layer: node-parallel spatial splits, per-level temporal
factorizations (divisor ladders with early capacity pruning), loop orders and
sharing toggles — every candidate scored with the detailed cost model.
A ``budget`` caps the enumeration for very large layers (reported when hit);
within budget the search is exhaustive over the same space KAPLA navigates.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from ...hw.template import HWTemplate
from ...workloads.layers import DIMS, LayerGraph, LayerSpec
from ..cost_model import CostBreakdown, combine_segment, evaluate_layer, invalid
from ..directives import (LayerScheme, LevelBlocking, canonical_orders,
                          divisors)
from .interlayer import io_flags, _consumer_map
from .intralayer import Constraints, _pe_axis_dims, solve_intra_layer


def _axis_splits(total: int, budget: int) -> List[int]:
    """Divisors of ``total`` that fit within a spatial axis ``budget``."""
    return [f for f in divisors(total) if f <= budget]


def enumerate_intra_schemes(layer: LayerSpec, hw: HWTemplate,
                            constr: Constraints,
                            budget: int = 50000) -> Iterator[LayerScheme]:
    """Yield candidate schemes; early-prunes on per-level capacity."""
    n_levels = len(hw.levels)
    pe_axes = _pe_axis_dims(hw)
    # PE-level spatial: one dim per axis (hardware-constrained patterns)
    pe_opts: List[Dict[str, int]] = []
    for d0 in list(pe_axes[0]) + [None]:
        for d1 in list(pe_axes[1]) + [None]:
            if d0 == d1:
                continue
            for f0 in (_axis_splits(layer.dim(d0), hw.pe_array[0])
                       if d0 else [1]):
                for f1 in (_axis_splits(layer.dim(d1), hw.pe_array[1])
                           if d1 else [1]):
                    s = {}
                    if d0 and f0 > 1:
                        s[d0] = f0
                    if d1 and f1 > 1:
                        s[d1] = f1
                    pe_opts.append(s)
    # node-level spatial: up to two dims across the assigned region
    node_opts: List[Dict[str, int]] = [{}]
    H, W = constr.nodes
    for d0, d1 in itertools.permutations(DIMS, 2):
        for f0 in _axis_splits(layer.dim(d0), H):
            for f1 in _axis_splits(layer.dim(d1), W):
                if f0 * f1 > 1:
                    node_opts.append({k: v for k, v in
                                      ((d0, f0), (d1, f1)) if v > 1})
    seen_nodes = set()
    node_uniq = []
    for o in node_opts:
        key = tuple(sorted(o.items()))
        if key not in seen_nodes:
            seen_nodes.add(key)
            node_uniq.append(o)

    # seed the spatial option lists with KAPLA's own stacking point so the
    # exhaustive space is a superset of what the fast solver reaches (the
    # directive space is shared; only the walk differs)
    seed, _ = solve_intra_layer(layer, hw, constr)
    if seed is not None:
        pe_opts.insert(0, {d: f for d, f in seed.levels[0].s.items() if f > 1})
        node_uniq.insert(0,
                         {d: f for d, f in seed.levels[1].s.items() if f > 1})

    count = 0
    orders = canonical_orders()
    for pe_s in pe_opts:
        for node_s in node_uniq:
            # temporal factors: for each dim, split leftover across
            # REGF / GBUF / DRAM as (t0, t1, rest) over divisors
            leftover = {}
            for d in DIMS:
                tot = layer.dim(d)
                tot //= pe_s.get(d, 1) * node_s.get(d, 1)
                leftover[d] = tot
            per_dim_opts = []
            for d in DIMS:
                opts = []
                for t0 in divisors(leftover[d]):
                    for t1 in divisors(leftover[d] // t0):
                        opts.append((d, t0, t1, leftover[d] // t0 // t1))
                per_dim_opts.append(opts)
            for combo in itertools.product(*per_dim_opts):
                count += 1
                if count > budget:
                    return
                lv0 = LevelBlocking(s=dict(pe_s))
                lv1 = LevelBlocking(s=dict(node_s))
                lv2 = LevelBlocking()
                for d, t0, t1, t2 in combo:
                    if t0 > 1:
                        lv0.t[d] = t0
                    if t1 > 1:
                        lv1.t[d] = t1
                    if t2 > 1:
                        lv2.t[d] = t2
                scheme = LayerScheme(layer, [lv0, lv1, lv2])
                # early capacity pruning, inner levels first
                if scheme.level_footprint_bytes(0) > hw.levels[0].capacity_bytes:
                    continue
                if scheme.level_footprint_bytes(1) > hw.levels[1].capacity_bytes:
                    continue
                shr_opts: List[Dict[str, int]] = [{}]
                if hw.levels[-1].same_level_transfer:
                    for tname, rel in layer.tensors.items():
                        repl = 1
                        for d, f in lv1.s.items():
                            if d not in rel:
                                repl *= f
                        if repl > 1:
                            shr_opts.append({tname: repl})
                for o_mid, o_top, shr in itertools.product(orders, orders,
                                                           shr_opts):
                    lv1o = lv1.copy()
                    lv2o = lv2.copy()
                    lv1o.order, lv2o.order = o_mid, o_top
                    lv1o.shr = dict(shr)
                    if constr.outer_dims and \
                            o_top[: len(constr.outer_dims)] != constr.outer_dims:
                        continue
                    yield LayerScheme(layer, [lv0.copy(), lv1o, lv2o])


def solve_layer_exhaustive(layer: LayerSpec, hw: HWTemplate,
                           constr: Optional[Constraints] = None,
                           budget: int = 50000,
                           ) -> Tuple[Optional[LayerScheme], CostBreakdown]:
    constr = constr or Constraints(nodes=hw.node_array)
    best: Tuple[Optional[LayerScheme], CostBreakdown] = (None, invalid("none"))
    for scheme in enumerate_intra_schemes(layer, hw, constr, budget):
        cost = evaluate_layer(scheme, hw, nodes_assigned=constr.num_nodes,
                              src_onchip=constr.src_onchip,
                              dst_onchip=constr.dst_onchip)
        if cost.valid and cost.energy_pj < best[1].energy_pj:
            best = (scheme, cost)
    if best[0] is None:     # budget exhausted before a valid point: fall back
        return solve_intra_layer(layer, hw, constr)
    return best


def solve(graph: LayerGraph, hw: HWTemplate, budget_per_layer: int = 50000,
          max_seg_len: int = 4):
    """Exhaustive inter+intra search: every segment option is solved in full
    detail (no estimate-based pruning), then an exact DP over segmentation
    picks the globally optimal chain (optimal because detailed segment costs
    compose additively)."""
    from .interlayer import enumerate_segments
    from .kapla import NetworkSchedule, solve_segment

    t0 = time.perf_counter()
    consumers = _consumer_map(graph)
    n = len(graph.layers)

    def layer_solver(layer, hw_, constr):
        return solve_layer_exhaustive(layer, hw_, constr, budget_per_layer)

    seg_cands = {i: enumerate_segments(graph, hw, i, max_seg_len)
                 for i in range(n)}
    INF = float("inf")
    best_cost = [INF] * (n + 1)
    best_prev: List[Optional[Tuple[int, float, Dict, Dict]]] = [None] * (n + 1)
    best_cost[0] = 0.0
    detail_cache: Dict = {}
    for i in range(1, n + 1):
        for start in range(max(0, i - max_seg_len), i):
            if best_cost[start] == INF:
                continue
            for seg in seg_cands[start]:
                if seg.stop != i:
                    continue
                key = (seg.start, seg.stop, seg.alloc, seg.granule_frac)
                if key not in detail_cache:
                    tot, schemes, costs = solve_segment(
                        graph, hw, seg, consumers, layer_solver)
                    detail_cache[key] = None if tot is None else \
                        (tot.energy_pj, tot.latency_cycles, schemes, costs)
                entry = detail_cache[key]
                if entry is None:
                    continue
                e, lat, schemes, costs = entry
                if best_cost[start] + e < best_cost[i]:
                    best_cost[i] = best_cost[start] + e
                    best_prev[i] = (start, lat, schemes, costs)

    schemes_all: Dict[str, LayerScheme] = {}
    costs_all: Dict[str, CostBreakdown] = {}
    latency = 0.0
    i = n
    while i > 0 and best_prev[i] is not None:
        start, lat, schemes, costs = best_prev[i]
        schemes_all.update(schemes)
        costs_all.update(costs)
        latency += lat
        i = start
    return NetworkSchedule(graph.name, None, schemes_all, costs_all,
                           best_cost[n], latency,
                           time.perf_counter() - t0)
