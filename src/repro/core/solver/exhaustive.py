"""Baseline S: exhaustive search over the directive scheme space (§V).

Enumerates, per layer: node-parallel spatial splits, per-level temporal
factorizations (divisor ladders), loop orders and sharing toggles.  The
enumeration is *batched*: temporal combos are generated directly as flat
factor tables (mixed-radix index decoding, no per-candidate ``LayerScheme``
or dict copies), capacity-pruned in-array, expanded with the order/sharing
variants, and scored with the vectorized cost model in large chunks.
A ``budget`` caps the enumeration for very large layers (reported when hit);
within budget the search is exhaustive over the same space KAPLA navigates.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ...hw.template import HWTemplate
from ...workloads.layers import DIMS, LayerGraph, LayerSpec
from ..cost_batch import FactorTable, evaluate_batch, pack_order
from ..cost_model import CostBreakdown, combine_segment, evaluate_layer, invalid
from ..directives import (LayerScheme, LevelBlocking, canonical_orders,
                          divisors)
from .interlayer import io_flags, _consumer_map
from .intralayer import Constraints, _pe_axis_dims, solve_intra_layer
from .memo import exhaustive_cache, solve_key

# expanded (temporal-combo x order/shr-variant) lanes scored per numpy call
_MAX_LANES = 65536


def _axis_splits(total: int, budget: int) -> List[int]:
    """Divisors of ``total`` that fit within a spatial axis ``budget``."""
    return [f for f in divisors(total) if f <= budget]


def _spatial_blocks(layer: LayerSpec, hw: HWTemplate, constr: Constraints,
                    ) -> Tuple[List[Dict[str, int]], List[Dict[str, int]]]:
    """PE-level and node-level spatial unrolling options, seeded with
    KAPLA's own stacking point so the exhaustive space is a superset of what
    the fast solver reaches (the directive space is shared; only the walk
    differs)."""
    pe_axes = _pe_axis_dims(hw)
    pe_opts: List[Dict[str, int]] = []
    for d0 in list(pe_axes[0]) + [None]:
        for d1 in list(pe_axes[1]) + [None]:
            if d0 == d1:
                continue
            for f0 in (_axis_splits(layer.dim(d0), hw.pe_array[0])
                       if d0 else [1]):
                for f1 in (_axis_splits(layer.dim(d1), hw.pe_array[1])
                           if d1 else [1]):
                    s = {}
                    if d0 and f0 > 1:
                        s[d0] = f0
                    if d1 and f1 > 1:
                        s[d1] = f1
                    pe_opts.append(s)
    node_opts: List[Dict[str, int]] = [{}]
    H, W = constr.nodes
    for d0, d1 in itertools.permutations(DIMS, 2):
        for f0 in _axis_splits(layer.dim(d0), H):
            for f1 in _axis_splits(layer.dim(d1), W):
                if f0 * f1 > 1:
                    node_opts.append({k: v for k, v in
                                      ((d0, f0), (d1, f1)) if v > 1})
    seen_nodes = set()
    node_uniq = []
    for o in node_opts:
        key = tuple(sorted(o.items()))
        if key not in seen_nodes:
            seen_nodes.add(key)
            node_uniq.append(o)

    seed, _ = solve_intra_layer(layer, hw, constr)
    if seed is not None:
        pe_opts.insert(0, {d: f for d, f in seed.levels[0].s.items() if f > 1})
        node_uniq.insert(0,
                         {d: f for d, f in seed.levels[1].s.items() if f > 1})
    return pe_opts, node_uniq


def _order_shr_variants(layer: LayerSpec, hw: HWTemplate,
                        constr: Constraints, node_s: Dict[str, int],
                        ) -> List[Tuple[Tuple[str, ...], Tuple[str, ...],
                                        Dict[str, int]]]:
    """(o_mid, o_top, shr) cross product for one node-spatial block, in the
    same iteration order as the historical scalar enumeration."""
    orders = canonical_orders()
    shr_opts: List[Dict[str, int]] = [{}]
    if hw.levels[-1].same_level_transfer:
        for tname, rel in layer.tensors.items():
            repl = 1
            for d, f in node_s.items():
                if d not in rel:
                    repl *= f
            if repl > 1:
                shr_opts.append({tname: repl})
    out = []
    for o_mid, o_top, shr in itertools.product(orders, orders, shr_opts):
        if constr.outer_dims and \
                o_top[: len(constr.outer_dims)] != tuple(constr.outer_dims):
            continue
        out.append((o_mid, o_top, shr))
    return out


def _footprint_mask(layer: LayerSpec, hw: HWTemplate, t: np.ndarray,
                    s_col: np.ndarray) -> np.ndarray:
    """Early capacity pruning at REGF and GBUF, vectorized over the combo
    axis (shr = 1 at this stage, mirroring the scalar enumeration which
    pruned before applying sharing toggles)."""
    cum = np.cumprod(t * s_col[:, :, None], axis=0)       # [L, ND, C]
    ratio = cum / s_col[:, :, None]
    mask = np.ones(t.shape[-1], dtype=bool)
    for level in (0, 1):
        fp = np.zeros(t.shape[-1])
        for tname, rel in layer.tensors.items():
            relvec = np.array([d in rel for d in DIMS])
            tl = np.prod(np.where(relvec[:, None], ratio[level], 1.0), axis=0)
            unit = layer.inner_unit(tname) if level == 0 \
                else layer.unit.get(tname, 1.0)
            fp += tl * unit
        mask &= fp * layer.bytes_per_elem <= hw.levels[level].capacity_bytes
    return mask


def iter_scheme_tables(layer: LayerSpec, hw: HWTemplate,
                       constr: Constraints,
                       budget: int = 50000) -> Iterator[FactorTable]:
    """Yield capacity-pruned candidate batches as factor tables.

    Covers the same candidate space as the historical per-scheme generator:
    each yielded table is (surviving temporal combos) x (order/shr variants)
    for one spatial block, combo-major / variant-minor."""
    n_levels = len(hw.levels)
    if n_levels < 3:
        raise ValueError("exhaustive table enumeration needs >= 3 levels")
    pe_opts, node_uniq = _spatial_blocks(layer, hw, constr)
    remaining = budget
    for pe_s in pe_opts:
        for node_s in node_uniq:
            if remaining <= 0:
                return
            leftover = {}
            for d in DIMS:
                tot = layer.dim(d)
                tot //= pe_s.get(d, 1) * node_s.get(d, 1)
                leftover[d] = tot
            # per-dim (t0, t1, t2) options as arrays
            opts: List[np.ndarray] = []
            for d in DIMS:
                o = [(t0, t1, leftover[d] // t0 // t1)
                     for t0 in divisors(leftover[d])
                     for t1 in divisors(leftover[d] // t0)]
                opts.append(np.asarray(o, dtype=np.int64))
            radix = [len(o) for o in opts]
            n_combos = int(np.prod(radix))
            take = min(n_combos, remaining)
            remaining -= take

            variants = _order_shr_variants(layer, hw, constr, node_s)
            if not variants:
                continue
            V = len(variants)
            # pre-pack the per-variant order/shr columns [levels, ., V]
            tnames = list(layer.tensors)
            var_order = np.empty((n_levels, len(DIMS), V), dtype=np.int8)
            var_omask = np.empty((n_levels, len(DIMS), V), dtype=bool)
            d_idx, d_mask = pack_order(LevelBlocking().order)
            var_order[:] = np.asarray(d_idx, dtype=np.int8)[None, :, None]
            var_omask[:] = np.asarray(d_mask)[None, :, None]
            var_shr = np.ones((n_levels, len(tnames), V), dtype=np.int64)
            for v, (o_mid, o_top, shr) in enumerate(variants):
                for lvl, o in ((1, o_mid), (n_levels - 1, o_top)):
                    idx, msk = pack_order(o)
                    var_order[lvl, :, v] = idx
                    var_omask[lvl, :, v] = msk
                for tname, f in shr.items():
                    var_shr[1, tnames.index(tname), v] = f

            s_col = np.ones((n_levels, len(DIMS)), dtype=np.int64)
            for d, f in pe_s.items():
                s_col[0, DIMS.index(d)] = f
            for d, f in node_s.items():
                s_col[1, DIMS.index(d)] = f

            chunk = max(1, _MAX_LANES // max(1, V))
            strides = np.ones(len(DIMS), dtype=np.int64)
            for i in range(len(DIMS) - 2, -1, -1):
                strides[i] = strides[i + 1] * radix[i + 1]
            done = 0
            while done < take:
                c = min(chunk, take - done)
                lin = np.arange(done, done + c, dtype=np.int64)
                done += c
                t = np.ones((n_levels, len(DIMS), c), dtype=np.int64)
                for di in range(len(DIMS)):
                    digits = (lin // strides[di]) % radix[di]
                    picked = opts[di][digits]            # [c, 3]
                    t[0, di] = picked[:, 0]
                    t[1, di] = picked[:, 1]
                    t[2, di] = picked[:, 2]
                keep = _footprint_mask(layer, hw, t, s_col)
                S = int(keep.sum())
                if S == 0:
                    continue
                t = t[:, :, keep]
                # expand combos x variants, combo-major
                B = S * V
                ft = FactorTable(
                    layer,
                    t=np.repeat(t, V, axis=2),
                    s=np.repeat(s_col[:, :, None], B, axis=2),
                    order=np.tile(var_order, (1, 1, S)),
                    omask=np.tile(var_omask, (1, 1, S)),
                    shr=np.tile(var_shr, (1, 1, S)))
                yield ft


def enumerate_intra_schemes(layer: LayerSpec, hw: HWTemplate,
                            constr: Constraints,
                            budget: int = 50000) -> Iterator[LayerScheme]:
    """Compatibility wrapper: materialize each table lane as a
    ``LayerScheme`` (prefer ``iter_scheme_tables`` + ``evaluate_batch``)."""
    for ft in iter_scheme_tables(layer, hw, constr, budget):
        for b in range(ft.batch):
            yield ft.scheme_at(b)


def solve_layer_exhaustive(layer: LayerSpec, hw: HWTemplate,
                           constr: Optional[Constraints] = None,
                           budget: int = 50000, use_cache: bool = True,
                           ) -> Tuple[Optional[LayerScheme], CostBreakdown]:
    constr = constr or Constraints(nodes=hw.node_array)
    key = solve_key(layer, hw, constr, extra=("budget", budget))
    if use_cache:
        hit = exhaustive_cache.get(key, layer)
        if hit is not None:
            return hit
    best: Tuple[Optional[LayerScheme], CostBreakdown] = (None, invalid("none"))
    for ft in iter_scheme_tables(layer, hw, constr, budget):
        res = evaluate_batch(ft, hw, nodes_assigned=constr.num_nodes,
                             src_onchip=constr.src_onchip,
                             dst_onchip=constr.dst_onchip)
        bi = res.best("energy")
        if bi >= 0 and res.energy_pj[bi] < best[1].energy_pj:
            best = (ft.scheme_at(bi), res.breakdown(bi))
    if best[0] is None:     # budget exhausted before a valid point: fall back
        best = solve_intra_layer(layer, hw, constr)
    if use_cache:
        exhaustive_cache.put(key, best[0], best[1])
    return best


def solve(graph: LayerGraph, hw: HWTemplate, budget_per_layer: int = 50000,
          max_seg_len: int = 4):
    """Exhaustive inter+intra search: every segment option is solved in full
    detail (no estimate-based pruning), then an exact DP over segmentation
    picks the globally optimal chain (optimal because detailed segment costs
    compose additively)."""
    from .interlayer import segment_pool
    from .kapla import NetworkSchedule, solve_segment

    t0 = time.perf_counter()
    consumers = _consumer_map(graph)
    n = len(graph.layers)

    def layer_solver(layer, hw_, constr):
        return solve_layer_exhaustive(layer, hw_, constr, budget_per_layer)

    # narrow alloc family: every candidate here is detail-solved in full, so
    # the widened 2-D region splits would blow up the exhaustive budget;
    # one multi-start batched shot covers all start indices
    seg_cands = segment_pool(graph, hw, range(n), max_seg_len, wide=False)
    INF = float("inf")
    best_cost = [INF] * (n + 1)
    best_prev: List[Optional[Tuple[int, float, Dict, Dict]]] = [None] * (n + 1)
    best_cost[0] = 0.0
    detail_cache: Dict = {}
    for i in range(1, n + 1):
        for start in range(max(0, i - max_seg_len), i):
            if best_cost[start] == INF:
                continue
            for seg in seg_cands[start]:
                if seg.stop != i:
                    continue
                key = seg.key
                if key not in detail_cache:
                    tot, schemes, costs, _pipe = solve_segment(
                        graph, hw, seg, consumers, layer_solver)
                    detail_cache[key] = None if tot is None else \
                        (tot.energy_pj, tot.latency_cycles, schemes, costs)
                entry = detail_cache[key]
                if entry is None:
                    continue
                e, lat, schemes, costs = entry
                if best_cost[start] + e < best_cost[i]:
                    best_cost[i] = best_cost[start] + e
                    best_prev[i] = (start, lat, schemes, costs)

    schemes_all: Dict[str, LayerScheme] = {}
    costs_all: Dict[str, CostBreakdown] = {}
    latency = 0.0
    i = n
    while i > 0 and best_prev[i] is not None:
        start, lat, schemes, costs = best_prev[i]
        schemes_all.update(schemes)
        costs_all.update(costs)
        latency += lat
        i = start
    return NetworkSchedule(graph.name, None, schemes_all, costs_all,
                           best_cost[n], latency,
                           time.perf_counter() - t0)
