"""Inter-layer scheduling: segment slicing + layer pipelining (KAPLA §IV-B).

Validity  -> conservative pruning (min aggregated-buffer requirement).
Efficiency -> optimistic lower-bound cost, Pareto pruning, and
              dynamic-programming prioritization keeping top-k_S chains.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from ...hw.template import HWTemplate
from ...workloads.layers import LayerGraph, LayerSpec
from ..estimate import estimate_layer, min_buffer_requirement_bytes


@dataclasses.dataclass(frozen=True)
class SegmentScheme:
    """One inter-layer candidate for a contiguous run of layers."""

    start: int
    stop: int                              # [start, stop)
    alloc: Tuple[Tuple[int, int], ...]     # node region (h, w) per layer
    granule_frac: float                    # forwarded fmap fraction
    est_energy: float = 0.0
    est_latency: float = 0.0
    est_dram: float = 0.0

    @property
    def length(self) -> int:
        return self.stop - self.start


@dataclasses.dataclass
class PruneStats:
    total: int = 0
    after_validity: int = 0
    after_pareto: int = 0


def _alloc_options(hw: HWTemplate, layers: Sequence[LayerSpec],
                   ) -> List[Tuple[Tuple[int, int], ...]]:
    """Partition the node grid into per-layer column strips.

    Options: (a) proportional to MACs, (b) equal split — both rounded to
    whole columns with every layer getting >= 1 column.
    """
    H, W = hw.node_array
    n = len(layers)
    if n == 1:
        return [((H, W),)]
    if n > W:
        return []
    outs = []
    macs = [max(1.0, l.total_macs()) for l in layers]
    total = sum(macs)
    for mode in ("prop", "equal"):
        cols = []
        left = W
        for i, l in enumerate(layers):
            if i == n - 1:
                c = left
            else:
                share = macs[i] / total if mode == "prop" else 1.0 / n
                c = max(1, min(left - (n - 1 - i), round(W * share)))
            cols.append(c)
            left -= c
        if left != 0 or min(cols) < 1:
            continue
        outs.append(tuple((H, c) for c in cols))
    # dedupe
    seen, uniq = set(), []
    for o in outs:
        if o not in seen:
            seen.add(o)
            uniq.append(o)
    return uniq


def enumerate_segments(graph: LayerGraph, hw: HWTemplate, start: int,
                       max_len: int = 4,
                       stats: Optional[PruneStats] = None,
                       ) -> List[SegmentScheme]:
    """All (conservatively) valid segment candidates starting at ``start``."""
    out: List[SegmentScheme] = []
    layers = graph.layers
    consumers = _consumer_map(graph)
    max_len = max_len if hw.spatial_layer_pipe else 1
    for stop in range(start + 1, min(start + max_len, len(layers)) + 1):
        seg = layers[start:stop]
        names = {l.name for l in seg}
        for alloc in _alloc_options(hw, seg):
            for gf in ((1.0,) if stop - start == 1
                       else (1.0 / seg[0].dim("N"), 1.0)):
                if stats:
                    stats.total += 1
                cand = _estimate_segment(graph, hw, start, stop, alloc, gf,
                                         names, consumers)
                if cand is None:
                    continue
                if stats:
                    stats.after_validity += 1
                out.append(cand)
    out = _pareto_prune(out)
    if stats:
        stats.after_pareto += len(out)
    return out


def _consumer_map(graph: LayerGraph) -> Dict[str, List[str]]:
    cons: Dict[str, List[str]] = {l.name: [] for l in graph.layers}
    for l in graph.layers:
        for s in l.src:
            if s in cons:
                cons[s].append(l.name)
    return cons


def io_flags(graph: LayerGraph, seg_names: set, layer: LayerSpec,
             consumers: Dict[str, List[str]]) -> Tuple[bool, bool]:
    src_onchip = bool(layer.src) and all(s in seg_names for s in layer.src)
    cons = consumers.get(layer.name, [])
    dst_onchip = bool(cons) and all(c in seg_names for c in cons)
    return src_onchip, dst_onchip


def _estimate_segment(graph: LayerGraph, hw: HWTemplate, start: int,
                      stop: int, alloc, gf: float, names: set,
                      consumers) -> Optional[SegmentScheme]:
    e = lat = dram = 0.0
    for i, layer in enumerate(graph.layers[start:stop]):
        src_on, dst_on = io_flags(graph, names, layer, consumers)
        nodes = alloc[i][0] * alloc[i][1]
        need = min_buffer_requirement_bytes(layer, gf, src_on, dst_on)
        if need > nodes * hw.gbuf.capacity_bytes:
            return None                      # conservative validity pruning
        est = estimate_layer(layer, hw, nodes, gf, src_on, dst_on)
        if not est.valid:
            return None
        e += est.energy_lb_pj
        lat = max(lat, est.latency_lb_cycles)
        dram += est.dram_bytes_lb
    # fine-grained forwarding: fill cost of one granule per stage
    lat = lat + lat * gf * max(0, stop - start - 1)
    return SegmentScheme(start, stop, alloc, gf, e, lat, dram)


def _pareto_prune(cands: List[SegmentScheme]) -> List[SegmentScheme]:
    """Drop candidates dominated on (energy, latency, dram) within the same
    [start, stop) range."""
    out: List[SegmentScheme] = []
    by_range: Dict[Tuple[int, int], List[SegmentScheme]] = {}
    for c in cands:
        by_range.setdefault((c.start, c.stop), []).append(c)
    for group in by_range.values():
        keep = []
        for c in group:
            dominated = any(
                o is not c
                and o.est_energy <= c.est_energy
                and o.est_latency <= c.est_latency
                and o.est_dram <= c.est_dram
                and (o.est_energy, o.est_latency, o.est_dram)
                != (c.est_energy, c.est_latency, c.est_dram)
                for o in group)
            if not dominated:
                keep.append(c)
        out.extend(keep)
    return out


@dataclasses.dataclass
class Chain:
    segments: Tuple[SegmentScheme, ...]
    est_cost: float


def dp_prioritize(graph: LayerGraph, hw: HWTemplate, k_s: int = 4,
                  max_seg_len: int = 4, objective: str = "energy",
                  stats: Optional[PruneStats] = None) -> List[Chain]:
    """DP over the (topologically ordered) layer list: best segment chains
    ending at each layer, keeping top-k_S everywhere (§IV-B)."""
    n = len(graph.layers)
    seg_cache: Dict[int, List[SegmentScheme]] = {
        i: enumerate_segments(graph, hw, i, max_seg_len, stats)
        for i in range(n)}

    def seg_cost(s: SegmentScheme) -> float:
        return s.est_energy if objective == "energy" else \
            s.est_energy * s.est_latency if objective == "edp" else \
            s.est_latency

    best: List[List[Chain]] = [[] for _ in range(n + 1)]
    best[0] = [Chain((), 0.0)]
    for i in range(1, n + 1):
        cands: List[Chain] = []
        for seg_start in range(max(0, i - max_seg_len), i):
            for seg in seg_cache[seg_start]:
                if seg.stop != i:
                    continue
                for prev in best[seg_start]:
                    cands.append(Chain(prev.segments + (seg,),
                                       prev.est_cost + seg_cost(seg)))
        cands.sort(key=lambda c: c.est_cost)
        best[i] = cands[:k_s]
        if not best[i]:
            raise RuntimeError(f"no valid segment chain up to layer {i}")
    return best[n]
