"""Inter-layer scheduling: segment slicing + layer pipelining (KAPLA §IV-B).

Validity  -> conservative pruning (min aggregated-buffer requirement).
Efficiency -> optimistic lower-bound cost, Pareto pruning, and
              dynamic-programming prioritization keeping top-k_S chains.

The hot path is fully vectorized: all (segment range, alloc option, granule
fraction) candidates are estimated in one batched shot
(``core/estimate_batch.py``), Pareto dominance is a single padded 3-D
broadcast across every (start, stop) group at once, and the DP keeps
top-k_S chains with ``argpartition`` over flat cost arrays — per-candidate
``SegmentScheme`` objects are only materialized for Pareto survivors (the
public pool API) or the winning chains (the DP).  The scalar reference path
(``enumerate_segments_scalar`` / ``dp_prioritize_scalar``) is kept for
parity tests and as the benchmark baseline; both paths are bit-exact equal.
"""
from __future__ import annotations

import dataclasses
import functools
import weakref
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ...hw.template import HWTemplate
from ...obs import trace
from ...workloads.layers import LayerGraph, LayerSpec
from ..estimate import estimate_layer, min_buffer_requirement_bytes
from ..estimate_batch import GraphPack, estimate_segments, pack_graph


@dataclasses.dataclass(frozen=True)
class SegmentScheme:
    """One inter-layer candidate for a contiguous run of layers."""

    start: int
    stop: int                              # [start, stop)
    alloc: Tuple[Tuple[int, int], ...]     # node region (h, w) per layer
    granule_frac: float                    # forwarded fmap fraction
    est_energy: float = 0.0
    est_latency: float = 0.0
    est_dram: float = 0.0

    @property
    def length(self) -> int:
        return self.stop - self.start

    @property
    def key(self) -> Tuple:
        """Identity of the detail-solve this segment induces (estimates
        excluded): the dedup key for segment caches within and across
        chains (``kapla.solve`` / ``solve_many``)."""
        return (self.start, self.stop, self.alloc, self.granule_frac)


@dataclasses.dataclass
class PruneStats:
    total: int = 0
    after_validity: int = 0
    after_pareto: int = 0


# ---------------------------------------------------------------------------
# node-region allocation options
# ---------------------------------------------------------------------------

def _axis_splits(budget: int, macs: Sequence[float]) -> List[Tuple[int, ...]]:
    """Partition ``budget`` units of one grid axis across ``len(macs)``
    layers: proportional to MACs and equal split, rounded to whole units
    with every layer getting >= 1."""
    n = len(macs)
    total = sum(macs)
    outs: List[Tuple[int, ...]] = []
    for mode in ("prop", "equal"):
        cols: List[int] = []
        left = budget
        for i in range(n):
            if i == n - 1:
                c = left
            else:
                share = macs[i] / total if mode == "prop" else 1.0 / n
                c = max(1, min(left - (n - 1 - i), round(budget * share)))
            cols.append(c)
            left -= c
        if left != 0 or min(cols) < 1:
            continue
        outs.append(tuple(cols))
    return outs


@functools.lru_cache(maxsize=16384)
def _alloc_options_cached(hw_grid: Tuple[int, int], macs: Tuple[float, ...],
                          wide: bool) -> Tuple[Tuple[Tuple[int, int], ...],
                                               ...]:
    """Partition the node grid into per-layer regions.

    Base family: full-height column strips (proportional to MACs, equal).
    ``wide`` adds 2-D (row x col) region splits: full-width row strips and
    a two-row-block layout with column strips inside each block — a
    strictly larger option space that the batched estimator prices at
    negligible cost.  Cached on (grid, MAC profile): real nets repeat layer
    runs (ResNet blocks, transformer stacks) heavily.
    """
    H, W = hw_grid
    n = len(macs)
    if n == 1:
        return (((H, W),),)
    outs: List[Tuple[Tuple[int, int], ...]] = []
    if n <= W:
        outs += [tuple((H, c) for c in cs) for cs in _axis_splits(W, macs)]
    if wide:
        if n <= H:
            outs += [tuple((r, W) for r in rs) for rs in _axis_splits(H, macs)]
        if H >= 2 and n >= 2:
            m = (n + 1) // 2
            ht, hb = H // 2, H - H // 2
            if m <= W and 1 <= n - m <= W:
                for top in _axis_splits(W, macs[:m]):
                    for bot in _axis_splits(W, macs[m:]):
                        outs.append(tuple((ht, c) for c in top) +
                                    tuple((hb, c) for c in bot))
    seen, uniq = set(), []
    for o in outs:
        if o not in seen:
            seen.add(o)
            uniq.append(o)
    return tuple(uniq)


def _alloc_options(hw: HWTemplate, layers: Sequence[LayerSpec],
                   wide: bool = True,
                   ) -> List[Tuple[Tuple[int, int], ...]]:
    macs = tuple(max(1.0, l.total_macs()) for l in layers)
    return list(_alloc_options_cached(hw.node_array, macs, wide))


# ---------------------------------------------------------------------------
# graph helpers
# ---------------------------------------------------------------------------

def _consumer_map(graph: LayerGraph) -> Dict[str, List[str]]:
    cons: Dict[str, List[str]] = {l.name: [] for l in graph.layers}
    for l in graph.layers:
        for s in l.src:
            if s in cons:
                cons[s].append(l.name)
    return cons


def io_flags(graph: LayerGraph, seg_names: set, layer: LayerSpec,
             consumers: Dict[str, List[str]]) -> Tuple[bool, bool]:
    src_onchip = bool(layer.src) and all(s in seg_names for s in layer.src)
    cons = consumers.get(layer.name, [])
    dst_onchip = bool(cons) and all(c in seg_names for c in cons)
    return src_onchip, dst_onchip


# graphs carrying attached caches, so memo.clear_all() can reach them
# (id-keyed: LayerGraph is unhashable, weak values avoid leaking graphs)
_CACHED_GRAPHS: "weakref.WeakValueDictionary" = weakref.WeakValueDictionary()


def clear_graph_caches() -> None:
    """Drop every graph-attached pack / candidate-batch cache (for cold
    benchmarking; called by ``memo.clear_all``)."""
    for g in list(_CACHED_GRAPHS.values()):
        g.__dict__.pop("_estimate_pack_cache", None)
        g.__dict__.pop("_segment_batch_cache", None)
    _alloc_options_cached.cache_clear()


def graph_pack(graph: LayerGraph, hw: HWTemplate) -> GraphPack:
    """Per-(graph, hw) memoized ``pack_graph`` — the pack is immutable and
    graphs are not mutated after construction, so cache it on the graph."""
    cache = graph.__dict__.setdefault("_estimate_pack_cache", {})
    _CACHED_GRAPHS[id(graph)] = graph
    gp = cache.get(hw)
    if gp is None:
        gp = cache[hw] = pack_graph(graph, hw)
    return gp


# ---------------------------------------------------------------------------
# candidate enumeration + batched estimation
# ---------------------------------------------------------------------------

CandidateMeta = Tuple[int, int, Tuple[Tuple[int, int], ...], float]


@dataclasses.dataclass
class CandidateBatch:
    """All candidates of an enumeration, as parallel columns, plus their
    batch-estimated bounds.  Enumeration order is (start asc, stop asc,
    alloc order, granule order) — candidates of one (start, stop) group are
    contiguous."""

    starts: np.ndarray          # [C] int64
    stops: np.ndarray           # [C] int64
    gfs: np.ndarray             # [C] float64
    allocs: List[Tuple[Tuple[int, int], ...]]
    valid: np.ndarray           # [C] bool
    energy: np.ndarray          # [C]
    latency: np.ndarray         # [C]
    dram: np.ndarray            # [C]
    kept: np.ndarray            # [K] int64 indices surviving Pareto
    # lazily-built DP index caches (plain lists: fast scalar indexing)
    _starts_list: Optional[List[int]] = None
    _by_stop: Optional[List[List[int]]] = None

    def __len__(self) -> int:
        return len(self.starts)

    def scheme_at(self, c: int) -> SegmentScheme:
        return SegmentScheme(int(self.starts[c]), int(self.stops[c]),
                             self.allocs[c], float(self.gfs[c]),
                             float(self.energy[c]), float(self.latency[c]),
                             float(self.dram[c]))


def _enumerate_columns(graph: LayerGraph, hw: HWTemplate,
                       starts: Iterable[int], max_len: int, wide: bool,
                       ) -> Tuple[List[int], List[int], List, List[float]]:
    max_len = max_len if hw.spatial_layer_pipe else 1
    layers = graph.layers
    n = len(layers)
    grid = hw.node_array
    macs_all = [max(1.0, l.total_macs()) for l in layers]
    starts_l: List[int] = []
    stops_l: List[int] = []
    allocs_l: List = []
    gfs_l: List[float] = []
    for start in starts:
        gf_small = 1.0 / layers[start].dim("N")
        for stop in range(start + 1, min(start + max_len, n) + 1):
            allocs = _alloc_options_cached(
                grid, tuple(macs_all[start:stop]), wide)
            if not allocs:
                continue
            gfs = (1.0,) if stop - start == 1 else (gf_small, 1.0)
            k = len(allocs) * len(gfs)
            starts_l += [start] * k
            stops_l += [stop] * k
            allocs_l += [a for a in allocs for _ in gfs]
            gfs_l += list(gfs) * len(allocs)
    return starts_l, stops_l, allocs_l, gfs_l


def candidate_metas(graph: LayerGraph, hw: HWTemplate,
                    starts: Iterable[int], max_len: int = 4,
                    wide: bool = True) -> List[CandidateMeta]:
    """Enumerate every (start, stop, alloc, granule_frac) candidate for the
    given start indices, in deterministic order."""
    s, e, a, g = _enumerate_columns(graph, hw, starts, max_len, wide)
    return list(zip(s, e, a, g))


def estimate_candidates(graph: LayerGraph, hw: HWTemplate,
                        metas: Sequence[CandidateMeta],
                        gp: Optional[GraphPack] = None,
                        ) -> Tuple[np.ndarray, np.ndarray,
                                   np.ndarray, np.ndarray]:
    """Batch-estimate candidate metas: (valid, energy, latency, dram)."""
    cols = ([m[0] for m in metas], [m[1] for m in metas],
            [m[2] for m in metas], [m[3] for m in metas])
    return _estimate_columns(graph, hw, cols, gp)


def _estimate_columns(graph: LayerGraph, hw: HWTemplate, cols,
                      gp: Optional[GraphPack] = None,
                      ) -> Tuple[np.ndarray, np.ndarray,
                                 np.ndarray, np.ndarray]:
    starts_l, stops_l, allocs_l, gfs_l = cols
    if gp is None:
        gp = graph_pack(graph, hw)
    starts = np.asarray(starts_l, dtype=np.int64)
    stops = np.asarray(stops_l, dtype=np.int64)
    gfs = np.asarray(gfs_l, dtype=np.float64)
    # alloc tuples repeat heavily: pack node counts once per distinct alloc
    alloc_ids: Dict[Tuple, int] = {}
    uniq_rows: List[List[int]] = []
    ids = np.empty(len(allocs_l), dtype=np.int64)
    for c, alloc in enumerate(allocs_l):
        aid = alloc_ids.get(alloc)
        if aid is None:
            aid = alloc_ids[alloc] = len(uniq_rows)
            uniq_rows.append([h * w for h, w in alloc])
        ids[c] = aid
    lmax = max(len(r) for r in uniq_rows)
    mat = np.ones((len(uniq_rows), lmax))
    for i, r in enumerate(uniq_rows):
        mat[i, :len(r)] = r
    return estimate_segments(gp, hw, starts, stops, gfs, mat[ids])


# ---------------------------------------------------------------------------
# Pareto pruning (vectorized dominance on stacked cost arrays)
# ---------------------------------------------------------------------------

def _pareto_keep_mask(e: np.ndarray, lat: np.ndarray,
                      d: np.ndarray) -> np.ndarray:
    """Dominance check within one candidate group; exact-cost duplicates
    are all kept (mirrors the scalar rule)."""
    le = (e[None, :] <= e[:, None]) & (lat[None, :] <= lat[:, None]) \
        & (d[None, :] <= d[:, None])
    neq = (e[None, :] != e[:, None]) | (lat[None, :] != lat[:, None]) \
        | (d[None, :] != d[:, None])
    return ~np.any(le & neq, axis=1)


def _grouped_pareto_kept(key: np.ndarray, valid: np.ndarray, e: np.ndarray,
                         lat: np.ndarray, d: np.ndarray) -> np.ndarray:
    """Indices of candidates surviving per-group Pareto pruning, where
    ``key`` is nondecreasing and identifies the (start, stop) group.  All
    groups are checked in one padded [G, M, M] broadcast; padding lanes are
    +inf and can never dominate a real candidate."""
    vidx = np.flatnonzero(valid)
    if len(vidx) == 0:
        return vidx
    g = key[vidx]
    bounds = np.flatnonzero(np.diff(g)) + 1
    group_start = np.concatenate([[0], bounds])
    sizes = np.diff(np.concatenate([group_start, [len(g)]]))
    G, M = len(group_start), int(sizes.max())
    pos = np.arange(len(g)) - np.repeat(group_start, sizes)
    gix = np.repeat(np.arange(G), sizes)
    inf = float("inf")
    eg = np.full((G, M), inf)
    lg = np.full((G, M), inf)
    dg = np.full((G, M), inf)
    eg[gix, pos] = e[vidx]
    lg[gix, pos] = lat[vidx]
    dg[gix, pos] = d[vidx]
    le = (eg[:, None, :] <= eg[:, :, None]) \
        & (lg[:, None, :] <= lg[:, :, None]) \
        & (dg[:, None, :] <= dg[:, :, None])
    neq = (eg[:, None, :] != eg[:, :, None]) \
        | (lg[:, None, :] != lg[:, :, None]) \
        | (dg[:, None, :] != dg[:, :, None])
    keep = ~np.any(le & neq, axis=2)            # [G, M]
    return vidx[keep[gix, pos]]


def _build_candidate_batch(graph: LayerGraph, hw: HWTemplate,
                           starts: List[int], max_len: int,
                           gp: Optional[GraphPack],
                           wide: bool) -> CandidateBatch:
    """Enumerate + batch-estimate + Pareto-prune in three vectorized shots."""
    cols = _enumerate_columns(graph, hw, starts, max_len, wide)
    if not cols[0]:
        z = np.zeros(0)
        zi = np.zeros(0, dtype=np.int64)
        return CandidateBatch(zi, zi, z, [], np.zeros(0, dtype=bool),
                              z, z, z, zi)
    valid, energy, latency, dram = _estimate_columns(graph, hw, cols, gp)
    sarr = np.asarray(cols[0], dtype=np.int64)
    earr = np.asarray(cols[1], dtype=np.int64)
    key = sarr * np.int64(len(graph.layers) + 1) + earr
    kept = _grouped_pareto_kept(key, valid, energy, latency, dram)
    return CandidateBatch(sarr, earr, np.asarray(cols[3]), cols[2],
                          valid, energy, latency, dram, kept)


def _candidate_batch(graph: LayerGraph, hw: HWTemplate,
                     starts: Iterable[int], max_len: int,
                     stats: Optional[PruneStats] = None,
                     wide: bool = True) -> CandidateBatch:
    """Memoized candidate batch: the enumeration/estimates are a pure
    function of (graph, hw, starts, max_len, wide), and graphs are not
    mutated after construction, so repeated DP calls (annealing restarts,
    repeated solves) reuse the packed arrays."""
    # ascending unique starts: grouped Pareto needs a monotone group key,
    # and duplicates would double-enumerate candidates
    starts = sorted(set(starts))
    key = (hw, max_len, wide, tuple(starts))
    cache = graph.__dict__.setdefault("_segment_batch_cache", {})
    _CACHED_GRAPHS[id(graph)] = graph
    cb = cache.get(key)
    if cb is None:
        while len(cache) >= 8:              # FIFO eviction: keep hot entries
            cache.pop(next(iter(cache)))
        cb = cache[key] = _build_candidate_batch(graph, hw, starts, max_len,
                                                 None, wide)
    if stats:
        stats.total += len(cb)
        stats.after_validity += int(cb.valid.sum())
        stats.after_pareto += len(cb.kept)
    return cb


# ---------------------------------------------------------------------------
# explain: the candidate funnel as a first-class record (obs.explain)
# ---------------------------------------------------------------------------

def _classify_invalid(graph: LayerGraph, hw: HWTemplate,
                      cb: CandidateBatch,
                      idx: np.ndarray) -> Dict[str, Dict]:
    """Attribute each validity-pruned candidate to its failing rule.

    The batched validity check has exactly one rule — the conservative
    min-buffer bound (``min_buffer_requirement_bytes`` vs the segment's
    aggregated GBUF).  Recompute it for the invalid lanes to name the
    *first* overflowing layer per candidate, so the explain report can
    say which layer killed the candidates, not just how many died."""
    out: Dict[str, Dict] = {
        "gbuf_min_buffer": {"count": int(len(idx)), "layers": {}}}
    if len(idx) == 0:
        return out
    gp = graph_pack(graph, hw)
    starts = cb.starts[idx]
    stops = cb.stops[idx]
    gfs = cb.gfs[idx]
    lengths = stops - starts
    lmax = int(lengths.max())
    pos = np.arange(lmax, dtype=np.int64)
    mask = pos[None, :] < lengths[:, None]
    lidx = np.minimum(starts[:, None] + pos[None, :], gp.n_layers - 1)
    src_on = gp.src_ok[lidx] & (gp.min_src[lidx] >= starts[:, None]) \
        & (gp.max_src[lidx] < stops[:, None])
    dst_on = gp.has_cons[lidx] & (gp.min_cons[lidx] >= starts[:, None]) \
        & (gp.max_cons[lidx] < stops[:, None])
    B = gp.bytes_per_elem[lidx]
    gf_c = gfs[:, None]
    need = np.where(src_on, 2.0 * gp.ifmap[lidx] * gf_c * B, 0.0) \
        + np.where(dst_on, 2.0 * gp.ofmap[lidx] * gf_c * B, 0.0)
    nodes = np.ones((len(idx), lmax))
    for r, c in enumerate(idx):
        for p, (h, w) in enumerate(cb.allocs[int(c)]):
            nodes[r, p] = h * w
    over = (need > nodes * hw.gbuf.capacity_bytes) & mask
    first = np.argmax(over, axis=1)
    layers: Dict[str, int] = {}
    for r in range(len(idx)):
        li = int(lidx[r, first[r]])
        name = graph.layers[li].name
        layers[name] = layers.get(name, 0) + 1
    out["gbuf_min_buffer"]["layers"] = layers
    return out


def funnel_from_batch(graph: LayerGraph, hw: HWTemplate,
                      cb: CandidateBatch) -> Dict:
    """One enumeration batch's candidate funnel as a JSON-safe record:
    per-(start, stop) group enumerated/valid/Pareto-kept counts, overall
    totals, and per-rule pruning attribution.

    The totals equal the ``PruneStats`` deltas a DP run records for the
    same starts *by construction* — both are computed from the same
    memoized ``CandidateBatch`` — which is what lets the Table VI bench
    and the flight recorder agree without reconciliation."""
    totals = {"enumerated": int(len(cb)),
              "after_validity": int(cb.valid.sum()),
              "after_pareto": int(len(cb.kept))}
    if len(cb) == 0:
        return {"groups": [], "totals": totals, "pruned_by_rule": {}}
    kept_mask = np.zeros(len(cb), dtype=bool)
    kept_mask[cb.kept] = True
    key = cb.starts * np.int64(len(graph.layers) + 1) + cb.stops
    bounds = np.concatenate([[0], np.flatnonzero(np.diff(key)) + 1,
                             [len(cb)]])
    groups = []
    for gi in range(len(bounds) - 1):
        a, b = int(bounds[gi]), int(bounds[gi + 1])
        groups.append({"start": int(cb.starts[a]),
                       "stop": int(cb.stops[a]),
                       "enumerated": b - a,
                       "valid": int(cb.valid[a:b].sum()),
                       "kept": int(kept_mask[a:b].sum())})
    rules = _classify_invalid(graph, hw, cb, np.flatnonzero(~cb.valid))
    return {"groups": groups, "totals": totals, "pruned_by_rule": rules}


def funnel_report(graph: LayerGraph, hw: HWTemplate,
                  starts: Optional[Iterable[int]] = None,
                  max_len: int = 4, wide: bool = True) -> Dict:
    """The candidate funnel for these start indices (every layer when
    None) — a cache hit on the memoized batch right after a solve of the
    same shape, so extracting the funnel costs ~nothing."""
    if starts is None:
        starts = range(len(graph.layers))
    cb = _candidate_batch(graph, hw, starts, max_len, None, wide)
    return funnel_from_batch(graph, hw, cb)


def segment_pool(graph: LayerGraph, hw: HWTemplate,
                 starts: Iterable[int], max_len: int = 4,
                 stats: Optional[PruneStats] = None,
                 wide: bool = True) -> Dict[int, List[SegmentScheme]]:
    """Valid, Pareto-pruned segment candidates per start index, computed in
    one batched estimation shot across all starts."""
    starts = list(starts)
    cb = _candidate_batch(graph, hw, starts, max_len, stats, wide)
    out: Dict[int, List[SegmentScheme]] = {s: [] for s in starts}
    for c in cb.kept:
        out[int(cb.starts[c])].append(cb.scheme_at(c))
    return out


def enumerate_segments(graph: LayerGraph, hw: HWTemplate, start: int,
                       max_len: int = 4,
                       stats: Optional[PruneStats] = None,
                       wide: bool = True) -> List[SegmentScheme]:
    """All (conservatively) valid segment candidates starting at ``start``
    — a thin wrapper over the batched estimator."""
    return segment_pool(graph, hw, [start], max_len, stats,
                        wide=wide)[start]


# ---------------------------------------------------------------------------
# scalar reference path (parity tests + benchmark baseline)
# ---------------------------------------------------------------------------

def estimate_segment_scalar(graph: LayerGraph, hw: HWTemplate, start: int,
                            stop: int, alloc, gf: float, names: set,
                            consumers) -> Optional[SegmentScheme]:
    """One ``estimate_layer`` call per layer: the PR-1 scalar upper level."""
    e = lat = dram = 0.0
    for i, layer in enumerate(graph.layers[start:stop]):
        src_on, dst_on = io_flags(graph, names, layer, consumers)
        nodes = alloc[i][0] * alloc[i][1]
        need = min_buffer_requirement_bytes(layer, gf, src_on, dst_on)
        if need > nodes * hw.gbuf.capacity_bytes:
            return None                      # conservative validity pruning
        est = estimate_layer(layer, hw, nodes, gf, src_on, dst_on)
        if not est.valid:
            return None
        e += est.energy_lb_pj
        lat = max(lat, est.latency_lb_cycles)
        dram += est.dram_bytes_lb
    # fine-grained forwarding: fill cost of one granule per stage
    lat = lat + lat * gf * max(0, stop - start - 1)
    return SegmentScheme(start, stop, alloc, gf, e, lat, dram)


_estimate_segment = estimate_segment_scalar        # back-compat alias


def enumerate_segments_scalar(graph: LayerGraph, hw: HWTemplate, start: int,
                              max_len: int = 4,
                              stats: Optional[PruneStats] = None,
                              wide: bool = True) -> List[SegmentScheme]:
    out: List[SegmentScheme] = []
    consumers = _consumer_map(graph)
    names: set = set()
    last_range = None
    for start_, stop, alloc, gf in candidate_metas(graph, hw, [start],
                                                   max_len, wide=wide):
        if stats:
            stats.total += 1
        if (start_, stop) != last_range:    # one name-set per (start, stop)
            names = {l.name for l in graph.layers[start_:stop]}
            last_range = (start_, stop)
        cand = estimate_segment_scalar(graph, hw, start_, stop, alloc, gf,
                                       names, consumers)
        if cand is None:
            continue
        if stats:
            stats.after_validity += 1
        out.append(cand)
    out = _pareto_prune(out)
    if stats:
        stats.after_pareto += len(out)
    return out


def _pareto_prune(cands: List[SegmentScheme]) -> List[SegmentScheme]:
    """Drop candidates dominated on (energy, latency, dram) within the same
    [start, stop) range."""
    out: List[SegmentScheme] = []
    by_range: Dict[Tuple[int, int], List[SegmentScheme]] = {}
    for c in cands:
        by_range.setdefault((c.start, c.stop), []).append(c)
    for group in by_range.values():
        e = np.array([c.est_energy for c in group])
        lat = np.array([c.est_latency for c in group])
        d = np.array([c.est_dram for c in group])
        keep = _pareto_keep_mask(e, lat, d)
        out.extend(c for c, k in zip(group, keep) if k)
    return out


# ---------------------------------------------------------------------------
# DP prioritization
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Chain:
    segments: Tuple[SegmentScheme, ...]
    est_cost: float

    @property
    def key(self) -> Tuple:
        """Segmentation identity (per-segment keys): equal keys mean the
        same detail solve — chain dedup across DP results and warm-start
        seeds."""
        return tuple(s.key for s in self.segments)


def _seg_cost_fn(objective: str):
    def seg_cost(s: SegmentScheme) -> float:
        return s.est_energy if objective == "energy" else \
            s.est_energy * s.est_latency if objective == "edp" else \
            s.est_latency
    return seg_cost


def dp_prioritize(graph: LayerGraph, hw: HWTemplate, k_s: int = 4,
                  max_seg_len: int = 4, objective: str = "energy",
                  stats: Optional[PruneStats] = None,
                  explain=None) -> List[Chain]:
    """DP over the (topologically ordered) layer list: best segment chains
    ending at each layer, keeping top-k_S everywhere (§IV-B).

    Array-based: per layer index, all (segment, predecessor-chain) costs
    are formed with one broadcast per predecessor start and the top-k_S
    selected with argpartition over the flat array — ``SegmentScheme`` /
    ``Chain`` objects exist only for the returned chains.

    ``explain``, when an ``obs.explain.ExplainSink``, receives the
    candidate funnel of this run (``funnel_from_batch`` over the same
    memoized batch the DP consumed, so counts match ``stats`` exactly).
    """
    n = len(graph.layers)
    with trace.span("dp.enumerate", graph=graph.name, layers=n):
        cb = _candidate_batch(graph, hw, range(n), max_seg_len, stats)
    if explain is not None:
        explain.set_funnel(funnel_from_batch(graph, hw, cb))
    if objective == "energy":
        costv = cb.energy
    elif objective == "edp":
        costv = cb.energy * cb.latency
    else:
        costv = cb.latency
    # kept candidates bucketed by stop; order within a bucket is (start asc,
    # enumeration order) because kept indices are ascending
    if cb._by_stop is None:
        stops_l = cb.stops.tolist()
        buckets: List[List[int]] = [[] for _ in range(n + 1)]
        for c in cb.kept.tolist():
            buckets[stops_l[c]].append(c)
        cb._by_stop = buckets
        cb._starts_list = cb.starts.tolist()
    by_stop = cb._by_stop
    starts_l = cb._starts_list

    best_costs: List[Optional[np.ndarray]] = [None] * (n + 1)
    # back[i][r] = (candidate index in cb, predecessor rank at its start)
    back: List[List[Tuple[int, int]]] = [[] for _ in range(n + 1)]
    best_costs[0] = np.zeros(1)
    back[0] = [(-1, -1)]
    with trace.span("dp.select", graph=graph.name, k_s=k_s):
        for i in range(1, n + 1):
            ids = by_stop[i]
            parts: List[np.ndarray] = []
            groups: List[Tuple[List[int], int, int]] = []   # (cands, k, offset)
            off = 0
            j = 0
            n_ids = len(ids)
            while j < n_ids:
                s = starts_l[ids[j]]
                j2 = j
                while j2 < n_ids and starts_l[ids[j2]] == s:
                    j2 += 1
                prev = best_costs[s]
                if prev is not None and len(prev):
                    cands = ids[j:j2]
                    # [m, k] candidate-major: same order as the scalar loops
                    parts.append((costv[cands][:, None] + prev[None, :]).ravel())
                    groups.append((cands, len(prev), off))
                    off += len(cands) * len(prev)
                j = j2
            if not parts:
                raise RuntimeError(f"no valid segment chain up to layer {i}")
            costs = np.concatenate(parts) if len(parts) > 1 else parts[0]
            if len(costs) > k_s:
                sel = np.argpartition(costs, k_s - 1)[:k_s]
                # tie-break on the flat index so the kept order matches the
                # scalar DP's stable sort (up to equal-cost boundary members)
                sel = sel[np.lexsort((sel, costs[sel]))]
            else:
                sel = np.argsort(costs, kind="stable")
            best_costs[i] = costs[sel]
            back_i: List[Tuple[int, int]] = []
            for jf in sel:
                jf = int(jf)
                for cands, k, goff in groups:
                    if jf < goff + len(cands) * k:
                        local = jf - goff
                        back_i.append((cands[local // k], local % k))
                        break
            back[i] = back_i

    def build(i: int, rank: int) -> Tuple[SegmentScheme, ...]:
        segs: List[SegmentScheme] = []
        while True:                     # iterative: chains can be ~n long
            c, rank = back[i][rank]
            if c < 0:
                return tuple(reversed(segs))
            segs.append(cb.scheme_at(c))
            i = starts_l[c]

    return [Chain(build(n, r), float(best_costs[n][r]))
            for r in range(len(best_costs[n]))]


def dp_prioritize_scalar(graph: LayerGraph, hw: HWTemplate, k_s: int = 4,
                         max_seg_len: int = 4, objective: str = "energy",
                         stats: Optional[PruneStats] = None) -> List[Chain]:
    """The PR-1 scalar DP: per-index Python sort over Chain objects, fed by
    the scalar per-candidate estimator.  Kept as the parity reference and
    benchmark baseline for the array DP above."""
    n = len(graph.layers)
    seg_cache: Dict[int, List[SegmentScheme]] = {
        i: enumerate_segments_scalar(graph, hw, i, max_seg_len, stats)
        for i in range(n)}
    seg_cost = _seg_cost_fn(objective)

    best: List[List[Chain]] = [[] for _ in range(n + 1)]
    best[0] = [Chain((), 0.0)]
    for i in range(1, n + 1):
        cands: List[Chain] = []
        for seg_start in range(max(0, i - max_seg_len), i):
            for seg in seg_cache[seg_start]:
                if seg.stop != i:
                    continue
                for prev in best[seg_start]:
                    cands.append(Chain(prev.segments + (seg,),
                                       prev.est_cost + seg_cost(seg)))
        cands.sort(key=lambda c: c.est_cost)
        best[i] = cands[:k_s]
        if not best[i]:
            raise RuntimeError(f"no valid segment chain up to layer {i}")
    return best[n]
