"""Multi-node tier: place a solved segment chain onto an N-node mesh.

KAPLA's scope is *scalable multi-node* accelerators; the intra-layer
(dataflow) and inter-layer (segment chain) tiers solve one node.  This
module is the third tier above them: given a solved ``NetworkSchedule``
it decides which node of an N-node mesh runs each chain segment, using
node-granular directives —

  ``stack``      consecutive chain segments grouped onto one node group
                 (a *part*); parts form a node-level pipeline;
  ``replicate``  a part duplicated across ``width`` nodes for
                 request-level throughput (round-robin dispatch; every
                 replica runs the identical full-batch kernels, so
                 results stay bit-identical wherever a request lands).

The tier has the same pragmatic prune-then-prioritize shape as the
inter-layer solver:

  validity   -> conservative pruning: a replicate width must divide the
                batch, parts must fit the node budget, and a boundary
                granule that overflows a node's aggregate GBUF demotes
                the link transfer to DRAM staging (a cost penalty, not
                a crash);
  efficiency -> inter-node link bandwidth and hop count are first-class
                cost terms (MAESTRO-style communication-aware costing:
                nodes are not free parallelism), and a top-k DP over
                (segments placed, nodes used) prioritizes candidates.

Per-segment intra/inter-layer schemes are **reused verbatim** from the
solved schedule — this tier only places them.  That is what makes
``repartition`` after a node loss incremental: parts whose nodes all
survive keep their assignments untouched, and only the dead nodes'
segments (the *dirty* set) are re-placed and re-scored.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from ...hw.template import HWTemplate
from ...workloads.layers import LayerGraph
from ...runtime.fault import NodeFailure
from ..cost_model import combine_segment
from .interlayer import _consumer_map
from .kapla import NetworkSchedule

TOPOLOGIES = ("ring", "chain", "full")

OBJECTIVES = ("throughput", "latency", "energy")


@dataclasses.dataclass(frozen=True)
class NodeMesh:
    """The inter-node fabric: N identical nodes (each an ``HWTemplate``
    accelerator) joined by links of finite bandwidth.  ``hops`` is the
    routing distance the cost model charges per transferred byte."""

    nodes: int = 4
    link_bandwidth_bytes_per_cycle: float = 16.0
    link_energy_pj_per_byte: float = 2.0
    topology: str = "ring"

    def __post_init__(self):
        if self.nodes < 1:
            raise ValueError(f"mesh needs >= 1 node, got {self.nodes}")
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"unknown topology {self.topology!r}; "
                             f"one of {TOPOLOGIES}")
        if self.link_bandwidth_bytes_per_cycle <= 0:
            raise ValueError("link bandwidth must be positive")

    def hops(self, a: int, b: int) -> int:
        if a == b:
            return 0
        if self.topology == "full":
            return 1
        d = abs(a - b)
        return min(d, self.nodes - d) if self.topology == "ring" else d


@dataclasses.dataclass(frozen=True)
class SegmentCost:
    """One chain segment's solved cost + the byte totals the node tier
    charges to links: resident weights and boundary output size."""

    index: int
    start: int
    stop: int                              # [start, stop) into the order
    latency_cycles: float
    energy_pj: float
    weight_bytes: float
    out_bytes: float


@dataclasses.dataclass(frozen=True)
class NodeAssignment:
    """One part: a ``stack`` of consecutive chain segments on a node
    group, ``replicate``d across ``len(node_ids)`` nodes."""

    part: int
    seg_start: int                         # [seg_start, seg_stop) chain
    seg_stop: int                          # segment indices
    node_ids: Tuple[int, ...]
    compute_cycles: float
    energy_pj: float
    inbound_bytes: float
    inbound_hops: int                      # worst inbound routing distance
    link_cycles: float
    onchip_staged: bool

    @property
    def width(self) -> int:
        return len(self.node_ids)

    @property
    def stage_cycles(self) -> float:
        """Steady-state cycles this part adds per request: compute is
        amortized over replicas, link transfer is not."""
        return self.compute_cycles / max(1, self.width) + self.link_cycles


@dataclasses.dataclass
class MultiNodePruneStats:
    total: int = 0
    after_validity: int = 0
    kept: int = 0


@dataclasses.dataclass
class MultiNodePlan:
    """A solved placement of one schedule's chain onto a ``NodeMesh``."""

    graph_name: str
    mesh: NodeMesh
    parts: Tuple[NodeAssignment, ...]
    bottleneck_cycles: float               # slowest pipeline stage
    latency_cycles: float                  # one request end-to-end
    total_energy_pj: float                 # compute + link energy
    link_bytes: float
    est_cost: float
    objective: str = "throughput"
    prune: Optional[MultiNodePruneStats] = None

    @property
    def nodes_used(self) -> int:
        return len({n for p in self.parts for n in p.node_ids})

    @property
    def n_segments(self) -> int:
        return self.parts[-1].seg_stop if self.parts else 0

    def part_of_segment(self, seg_index: int) -> NodeAssignment:
        for p in self.parts:
            if p.seg_start <= seg_index < p.seg_stop:
                return p
        raise KeyError(f"segment {seg_index} is not placed "
                       f"(plan covers [0, {self.n_segments}))")

    def to_json(self) -> Dict:
        return {
            "graph": self.graph_name,
            "mesh": dataclasses.asdict(self.mesh),
            "objective": self.objective,
            "bottleneck_cycles": self.bottleneck_cycles,
            "latency_cycles": self.latency_cycles,
            "total_energy_pj": self.total_energy_pj,
            "link_bytes": self.link_bytes,
            "nodes_used": self.nodes_used,
            "parts": [{
                "segments": [p.seg_start, p.seg_stop],
                "node_ids": list(p.node_ids),
                "compute_cycles": p.compute_cycles,
                "link_cycles": p.link_cycles,
                "inbound_bytes": p.inbound_bytes,
                "inbound_hops": p.inbound_hops,
                "onchip_staged": p.onchip_staged,
            } for p in self.parts],
        }

    def describe(self) -> str:
        lines = [f"meshplan[{self.graph_name}] {len(self.parts)} parts "
                 f"on {self.nodes_used}/{self.mesh.nodes} nodes "
                 f"({self.mesh.topology}), "
                 f"bottleneck {self.bottleneck_cycles:.0f} cyc"]
        for p in self.parts:
            lines.append(
                f"  part{p.part} segs[{p.seg_start}:{p.seg_stop}) "
                f"nodes {list(p.node_ids)} "
                f"stage {p.stage_cycles:.0f} cyc "
                f"in {p.inbound_bytes:.0f}B/{p.inbound_hops}hop"
                + ("" if p.onchip_staged else " (DRAM-staged)"))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# solved per-segment costs + cross-segment tensor flows
# ---------------------------------------------------------------------------

def _chain_ranges(schedule: NetworkSchedule,
                  graph: LayerGraph) -> List[Tuple[int, int]]:
    """[start, stop) per chain segment — the same fallback rule as
    ``lower.netplan._segments`` (singletons without a chain), so part
    indices align with ``NetworkPlan.segments``."""
    if schedule.chain is not None and schedule.chain.segments:
        return [(s.start, s.stop) for s in schedule.chain.segments]
    return [(i, i + 1) for i in range(len(graph.layers))]


def segment_costs(schedule: NetworkSchedule,
                  graph: LayerGraph) -> List[SegmentCost]:
    """Per-chain-segment solved latency/energy (recomposed from the
    schedule's ``layer_costs`` via ``combine_segment`` — the schemes are
    reused, never re-solved) plus weight/output byte totals."""
    ranges = _chain_ranges(schedule, graph)
    segs = schedule.chain.segments \
        if schedule.chain is not None and schedule.chain.segments else None
    pipe = schedule.seg_pipelined or (True,) * len(ranges)
    consumers = _consumer_map(graph)
    out: List[SegmentCost] = []
    for i, (start, stop) in enumerate(ranges):
        layers = graph.layers[start:stop]
        costs = [schedule.layer_costs.get(l.name) for l in layers]
        if all(c is not None and c.valid for c in costs):
            gfrac = segs[i].granule_frac if segs else 1.0
            granules = max(1, round(1.0 / gfrac)) \
                if (i < len(pipe) and pipe[i] and gfrac > 0) else 1
            total = combine_segment(costs, granules)
            lat, en = total.latency_cycles, total.energy_pj
        elif segs is not None:
            lat, en = segs[i].est_latency, segs[i].est_energy
        else:
            lat, en = 0.0, 0.0
        wbytes = sum(l.tensor_size("W") * l.bytes_per_elem
                     for l in layers if "W" in l.tensors)
        names = {l.name for l in layers}
        obytes = sum(l.tensor_size("O") * l.bytes_per_elem for l in layers
                     if not consumers[l.name]
                     or any(c not in names for c in consumers[l.name]))
        out.append(SegmentCost(i, start, stop, lat, en, wbytes, obytes))
    return out


def cross_segment_bytes(graph: LayerGraph,
                        ranges: Sequence[Tuple[int, int]]
                        ) -> Dict[Tuple[int, int], float]:
    """``(src_seg, dst_seg) -> bytes`` for every tensor produced in one
    chain segment and consumed in a later one (the traffic that crosses
    inter-node links when the segments land on different parts)."""
    seg_of: Dict[str, int] = {}
    for i, (start, stop) in enumerate(ranges):
        for l in graph.layers[start:stop]:
            seg_of[l.name] = i
    consumers = _consumer_map(graph)
    flows: Dict[Tuple[int, int], float] = {}
    for l in graph.layers:
        si = seg_of[l.name]
        ob = l.tensor_size("O") * l.bytes_per_elem
        for dst in {seg_of[c] for c in consumers[l.name] if c in seg_of}:
            if dst != si:
                flows[(si, dst)] = flows.get((si, dst), 0.0) + ob
    return flows


# ---------------------------------------------------------------------------
# candidate scoring (shared by the DP and incremental repartition)
# ---------------------------------------------------------------------------

#: raw candidate: (seg_start, seg_stop, node_ids) per part
_RawParts = Tuple[Tuple[int, int, Tuple[int, ...]], ...]


def _score_parts(raw: _RawParts, segcosts: Sequence[SegmentCost],
                 flows: Dict[Tuple[int, int], float], mesh: NodeMesh,
                 hw: HWTemplate) -> Tuple[Tuple[NodeAssignment, ...],
                                          float, float, float, float]:
    """-> (parts, bottleneck, latency, energy, link_bytes) for a raw
    placement.  Link transfer is charged bytes x hops / bandwidth; a
    boundary granule too large for the destination node's aggregate
    GBUF (double-buffered) is DRAM-staged: same traffic at half the
    effective link bandwidth plus a DRAM touch per byte."""
    seg_part = {}
    for pi, (s0, s1, _) in enumerate(raw):
        for s in range(s0, s1):
            seg_part[s] = pi
    gbuf_budget = hw.gbuf.capacity_bytes * hw.num_nodes
    parts: List[NodeAssignment] = []
    energy = 0.0
    link_bytes = 0.0
    for pi, (s0, s1, node_ids) in enumerate(raw):
        compute = sum(segcosts[s].latency_cycles for s in range(s0, s1))
        en = sum(segcosts[s].energy_pj for s in range(s0, s1))
        # replicate directive: weights broadcast once per extra replica
        wbytes = sum(segcosts[s].weight_bytes for s in range(s0, s1))
        en += wbytes * (len(node_ids) - 1) * mesh.link_energy_pj_per_byte
        inbound = 0.0
        worst_hops = 0
        for (src, dst), b in flows.items():
            if seg_part.get(dst) != pi or seg_part.get(src) == pi:
                continue
            inbound += b
            src_node = raw[seg_part[src]][2][0]
            worst_hops = max(worst_hops,
                             mesh.hops(src_node, node_ids[0]))
        staged = 2.0 * inbound <= gbuf_budget
        bw = mesh.link_bandwidth_bytes_per_cycle * (1.0 if staged else 0.5)
        link_cycles = inbound * max(1, worst_hops) / bw
        en += inbound * max(1, worst_hops) * mesh.link_energy_pj_per_byte
        if not staged:
            en += inbound * hw.dram.access_energy_pj_per_byte * 2.0
        link_bytes += inbound
        energy += en
        parts.append(NodeAssignment(
            part=pi, seg_start=s0, seg_stop=s1, node_ids=node_ids,
            compute_cycles=compute, energy_pj=en, inbound_bytes=inbound,
            inbound_hops=worst_hops, link_cycles=link_cycles,
            onchip_staged=staged))
    bottleneck = max((p.stage_cycles for p in parts), default=0.0)
    latency = sum(p.compute_cycles + p.link_cycles for p in parts)
    return tuple(parts), bottleneck, latency, energy, link_bytes


def _cost_key(objective: str, bottleneck: float, latency: float,
              energy: float) -> Tuple[float, float]:
    if objective == "latency":
        return (latency, energy)
    if objective == "energy":
        return (energy, bottleneck)
    return (bottleneck, energy)


def _finish_plan(schedule: NetworkSchedule, raw: _RawParts,
                 segcosts: Sequence[SegmentCost],
                 flows: Dict[Tuple[int, int], float], mesh: NodeMesh,
                 hw: HWTemplate, objective: str,
                 prune: Optional[MultiNodePruneStats]) -> MultiNodePlan:
    parts, bottleneck, latency, energy, link_bytes = _score_parts(
        raw, segcosts, flows, mesh, hw)
    cost = _cost_key(objective, bottleneck, latency, energy)[0]
    return MultiNodePlan(
        graph_name=schedule.graph_name, mesh=mesh, parts=parts,
        bottleneck_cycles=bottleneck, latency_cycles=latency,
        total_energy_pj=energy, link_bytes=link_bytes, est_cost=cost,
        objective=objective, prune=prune)


# ---------------------------------------------------------------------------
# prune-then-prioritize DP (the inter-layer tier's shape, node-granular)
# ---------------------------------------------------------------------------

def plan_multinode(schedule: NetworkSchedule, graph: LayerGraph,
                   hw: HWTemplate, mesh: Optional[NodeMesh] = None,
                   k: int = 4,
                   objective: str = "throughput",
                   explain=None) -> MultiNodePlan:
    """Place ``schedule``'s chain segments onto ``mesh``.

    A DP over (chain segments placed, nodes consumed) enumerates every
    contiguous ``stack`` split and ``replicate`` width, prunes invalid
    candidates conservatively (width must divide the batch; parts must
    fit the node budget) and keeps the top-``k`` prefixes per state —
    the inter-layer tier's prune-then-prioritize shape, one level up.

    ``explain``, when an ``obs.explain.ExplainSink``, receives this
    tier's placement funnel (width candidates enumerated -> batch-
    divisibility valid -> DP-frontier kept) plus the winning placement
    and its frontier runners-up with cost deltas.
    """
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"one of {OBJECTIVES}")
    mesh = mesh if mesh is not None else NodeMesh()
    if not graph.layers:
        raise ValueError(f"graph {graph.name!r} has no layers to place")
    segcosts = segment_costs(schedule, graph)
    ranges = [(c.start, c.stop) for c in segcosts]
    flows = cross_segment_bytes(graph, ranges)
    batch = graph.layers[0].dim("N")
    S = len(segcosts)
    stats = MultiNodePruneStats()

    # frontier[s] = top-k (cost_key, raw_parts, next_free_node) with the
    # first s chain segments placed; parts claim nodes left to right so
    # hop distances are concrete during the DP
    frontier: Dict[int, List[Tuple[Tuple[float, float],
                                   _RawParts, int]]] = {0: [((0.0, 0.0),
                                                             (), 0)]}
    for stop in range(1, S + 1):
        cands: List[Tuple[Tuple[float, float], _RawParts, int]] = []
        for start in range(stop):
            for _, raw, free in frontier.get(start, ()):
                avail = mesh.nodes - free
                if avail < 1:
                    continue
                for width in range(1, avail + 1):
                    stats.total += 1
                    if batch % width:
                        continue            # replicate validity: the
                    stats.after_validity += 1  # batch must split evenly
                    node_ids = tuple(range(free, free + width))
                    new_raw = raw + ((start, stop, node_ids),)
                    _, bottleneck, latency, energy, _ = _score_parts(
                        new_raw, segcosts, flows, mesh, hw)
                    cands.append((_cost_key(objective, bottleneck,
                                            latency, energy),
                                  new_raw, free + width))
        cands.sort(key=lambda c: (c[0], len(c[1]), c[2]))
        frontier[stop] = cands[:max(1, k)]
        stats.kept += len(frontier[stop])
    if not frontier.get(S):
        raise NodeFailure(
            f"no valid placement of {S} segments on {mesh.nodes} "
            f"node(s) for graph {graph.name!r}", permanent=True)
    _, best_raw, _ = frontier[S][0]
    if explain is not None:
        best_cost = float(frontier[S][0][0][0])
        runners = []
        for rank, (ck, raw, _) in enumerate(frontier[S][1:], start=2):
            delta = float(ck[0]) - best_cost
            runners.append({
                "rank": rank, "cost": float(ck[0]), "delta": delta,
                "delta_frac": delta / best_cost if best_cost else 0.0,
                "parts": [[s0, s1, list(nodes)]
                          for s0, s1, nodes in raw]})
        explain.set_multinode({
            "mesh": dataclasses.asdict(mesh),
            "objective": objective,
            "funnel": {"total": stats.total,
                       "after_validity": stats.after_validity,
                       "kept": stats.kept},
            "winner": {"cost": best_cost,
                       "parts": [[s0, s1, list(nodes)]
                                 for s0, s1, nodes in best_raw]},
            "runners_up": runners,
        })
    return _finish_plan(schedule, best_raw, segcosts, flows, mesh, hw,
                        objective, stats)


# ---------------------------------------------------------------------------
# incremental repartition after node loss
# ---------------------------------------------------------------------------

def repartition(plan: MultiNodePlan, schedule: NetworkSchedule,
                graph: LayerGraph, hw: HWTemplate,
                survivors: Sequence[int]
                ) -> Tuple[MultiNodePlan, List[int]]:
    """Re-place ``plan`` onto the surviving nodes, **incrementally**.

    Parts whose nodes all survive keep their assignments verbatim; a
    part that lost replicas shrinks its width (largest batch divisor of
    the survivors); a part that lost every node moves whole to the
    least-loaded survivor.  Only the moved/shrunk parts' chain segments
    are returned as *dirty* — their per-segment schemes are still reused
    from the schedule; nothing below this tier is re-solved.

    -> (new plan, sorted dirty chain-segment indices).  Raises
    ``NodeFailure`` (permanent) when no nodes survive.
    """
    surv = sorted(set(survivors))
    if not surv:
        raise NodeFailure("no surviving nodes to repartition onto",
                          lost_devices=plan.mesh.nodes, permanent=True)
    bad = [n for n in surv if not 0 <= n < plan.mesh.nodes]
    if bad:
        raise ValueError(f"survivors {bad} outside mesh "
                         f"[0, {plan.mesh.nodes})")
    batch = graph.layers[0].dim("N")
    sset = set(surv)
    load = {n: 0.0 for n in surv}
    for p in plan.parts:
        alive = [n for n in p.node_ids if n in sset]
        for n in alive:
            load[n] += p.compute_cycles / len(alive)
    raw: List[Tuple[int, int, Tuple[int, ...]]] = []
    dirty: List[int] = []
    for p in plan.parts:
        alive = [n for n in p.node_ids if n in sset]
        if tuple(alive) == p.node_ids:
            raw.append((p.seg_start, p.seg_stop, p.node_ids))
            continue
        if alive:
            width = len(alive)
            while batch % width:
                width -= 1                  # keep replicate validity
            node_ids = tuple(alive[:width])
        else:
            target = min(surv, key=lambda n: load[n])
            load[target] += p.compute_cycles
            node_ids = (target,)
        raw.append((p.seg_start, p.seg_stop, node_ids))
        dirty.extend(range(p.seg_start, p.seg_stop))
    segcosts = segment_costs(schedule, graph)
    flows = cross_segment_bytes(graph, [(c.start, c.stop)
                                        for c in segcosts])
    new_plan = _finish_plan(schedule, tuple(raw), segcosts, flows,
                            plan.mesh, hw, plan.objective, plan.prune)
    return new_plan, sorted(set(dirty))


__all__ = ["NodeMesh", "SegmentCost", "NodeAssignment", "MultiNodePlan",
           "MultiNodePruneStats", "segment_costs", "cross_segment_bytes",
           "plan_multinode", "repartition", "TOPOLOGIES", "OBJECTIVES"]
