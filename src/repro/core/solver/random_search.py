"""Baseline R: Timeloop-style random sampling of the scheme space (§V).

Each candidate at each level is evaluated with probability ``p`` (segment
slicing is never skipped, since skipping segments may leave incomplete
chains — exactly the paper's caveat)."""
from __future__ import annotations

import random
import time
from typing import Dict, Optional, Tuple

from ...hw.template import HWTemplate
from ...workloads.layers import DIMS, LayerGraph, LayerSpec
from ..cost_batch import score_schemes
from ..cost_model import CostBreakdown, combine_segment, evaluate_layer, invalid
from ..directives import (LayerScheme, LevelBlocking, canonical_orders,
                          divisors)
from .interlayer import enumerate_segments, io_flags, _consumer_map
from .intralayer import Constraints, _pe_axis_dims, solve_intra_layer


def _random_scheme(layer: LayerSpec, hw: HWTemplate, constr: Constraints,
                   rng: random.Random) -> LayerScheme:
    pe_axes = _pe_axis_dims(hw)
    lv0, lv1, lv2 = LevelBlocking(), LevelBlocking(), LevelBlocking()
    # PE spatial
    for ax in (0, 1):
        d = rng.choice(list(pe_axes[ax]))
        opts = [f for f in divisors(layer.dim(d)) if f <= hw.pe_array[ax]]
        f = rng.choice(opts)
        if f > 1:
            lv0.s[d] = lv0.sf(d) * f
    # node spatial
    H, W = constr.nodes
    for budget in (H, W):
        d = rng.choice(DIMS)
        rem = layer.dim(d) // (lv0.sf(d) * lv1.sf(d))
        opts = [f for f in divisors(rem) if f <= budget]
        f = rng.choice(opts)
        if f > 1:
            lv1.s[d] = lv1.sf(d) * f
    # temporal splits
    for d in DIMS:
        rem = layer.dim(d) // (lv0.sf(d) * lv1.sf(d))
        t0 = rng.choice(divisors(rem))
        t1 = rng.choice(divisors(rem // t0))
        t2 = rem // t0 // t1
        if t0 > 1:
            lv0.t[d] = t0
        if t1 > 1:
            lv1.t[d] = t1
        if t2 > 1:
            lv2.t[d] = t2
    orders = canonical_orders()
    lv1.order = rng.choice(orders)
    top_orders = [o for o in orders
                  if not constr.outer_dims
                  or o[: len(constr.outer_dims)] == constr.outer_dims]
    lv2.order = rng.choice(top_orders or orders)
    return LayerScheme(layer, [lv0, lv1, lv2])


def solve_layer_random(layer: LayerSpec, hw: HWTemplate,
                       constr: Optional[Constraints] = None,
                       samples: int = 2000, p: float = 0.1,
                       seed: int = 0,
                       ) -> Tuple[Optional[LayerScheme], CostBreakdown]:
    constr = constr or Constraints(nodes=hw.node_array)
    rng = random.Random(seed ^ hash(layer.name) & 0xFFFF)
    best: Tuple[Optional[LayerScheme], CostBreakdown] = (None, invalid("none"))
    sampled = []
    for _ in range(samples):
        if rng.random() > p:
            continue                      # candidate skipped
        sampled.append(_random_scheme(layer, hw, constr, rng))
    if sampled:
        # score the whole sample set as one vectorized batch
        res = score_schemes(sampled, hw, nodes_assigned=constr.num_nodes,
                            src_onchip=constr.src_onchip,
                            dst_onchip=constr.dst_onchip)
        bi = res.best("energy")
        if bi >= 0:
            best = (sampled[bi], res.breakdown(bi))
    if best[0] is None:
        return solve_intra_layer(layer, hw, constr)
    return best


def solve(graph: LayerGraph, hw: HWTemplate, samples: int = 2000,
          p: float = 0.1, max_seg_len: int = 4, seed: int = 0):
    """Random search: random intra-layer sampling within the shared
    inter-layer machinery (segments are never skipped, per the paper)."""
    from .kapla import solve as kapla_solve

    def layer_solver(layer, hw_, constr):
        return solve_layer_random(layer, hw_, constr, samples, p, seed)

    return kapla_solve(graph, hw, k_s=1, max_seg_len=max_seg_len,
                       layer_solver=layer_solver)
