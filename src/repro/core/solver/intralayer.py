"""Intra-layer bottom-up greedy cost descending (KAPLA §IV-C, Algorithm 1).

Work through the memory hierarchy inner -> outer.  At each level, run a
*stacking* pass (spatial — parallelize tensors across the level's unit array)
then a *caching* pass (temporal — enlarge the per-buffer tensors), each time
greedily choosing a dimension that helps the currently most-accessed tensor,
tie-broken by the second most accessed.  Dimensions grow one smallest prime
step at a time ("next smallest blocked size"), so buffer-capacity validity
holds *by construction* — no top-down factorization retries.

Loop orders and same-level-sharing toggles are enumerated at the end and
scored with the detailed cost model.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from ...hw.template import HWTemplate
from ...workloads.layers import DIMS, LayerSpec
from ..cost_batch import score_schemes
from ..cost_model import CostBreakdown, evaluate_layer, invalid
from ..directives import (LayerScheme, LevelBlocking, canonical_orders,
                          smallest_prime_factor)
from .memo import intra_cache, solve_key


@dataclasses.dataclass
class Constraints:
    """Constraints imposed by the chosen inter-layer scheme."""

    nodes: Tuple[int, int] = (16, 16)      # node region assigned to the layer
    src_onchip: bool = False
    dst_onchip: bool = False
    # pipelined producers must finish accumulation on-chip so granules can be
    # forwarded as soon as produced (matched access patterns, §III-A):
    full_reduction_onchip: bool = False
    # forwarding granularity: the outermost DRAM loop must be over these dims
    outer_dims: Tuple[str, ...] = ()

    @property
    def num_nodes(self) -> int:
        return self.nodes[0] * self.nodes[1]


def _pe_axis_dims(hw: HWTemplate) -> Tuple[Sequence[str], Sequence[str]]:
    """Dims allowed on each PE-array axis per the hardware's PE dataflow."""
    if hw.pe_dataflow == "systolic":
        return ("C",), ("K", "N")          # weight-stationary MXU-style
    # row-stationary: cols <- fmap rows (Y), rows <- filter rows folded with
    # channels/filters (K, C); X slides within the PE.
    return ("K", "C"), ("Y", "X", "N")


def _helps(layer: LayerSpec, tname: str) -> List[str]:
    """Dims whose blocking at this level reduces ``tname``'s outer traffic
    (dims NOT indexing the tensor; reduction dims for the output)."""
    rel = set(layer.tensors[tname])
    if tname == "O":
        # partial-sum revisit traffic is driven by the reduction loops:
        # keeping them inside the output's residency level is what helps
        return [d for d in layer.reduction_dims if layer.dim(d) > 1]
    return [d for d in DIMS if d not in rel and layer.dim(d) > 1]


class _State:
    """Mutable solver state: factors allocated so far, per dim."""

    def __init__(self, layer: LayerSpec, n_levels: int):
        self.layer = layer
        self.levels = [LevelBlocking() for _ in range(n_levels)]
        self.scheme = LayerScheme(layer, self.levels)

    def remaining(self, d: str) -> int:
        return self.layer.dim(d) // self.scheme.allocated(d)

    def traffic_metric(self, tname: str) -> float:
        """Optimistic outer traffic for a tensor: total size x refetch factor
        from still-unallocated irrelevant dims."""
        m = self.layer.tensor_size(tname)
        rel = self.layer.tensors[tname]
        for d in DIMS:
            if d not in rel:
                m *= self.remaining(d)
        if tname == "O":
            m *= 1.5 if any(self.remaining(d) > 1
                            for d in self.layer.reduction_dims) else 1.0
        return m

    def ranked_tensors(self) -> List[str]:
        return sorted(self.layer.tensors,
                      key=lambda t: -self.traffic_metric(t))

    def finalize_leftovers(self) -> None:
        """Assign all remaining factors to the outermost level temporally."""
        top = self.levels[-1]
        for d in DIMS:
            r = self.remaining(d)
            if r > 1:
                top.t[d] = top.tf(d) * r


def _stacking_pass(st: _State, level: int, hw: HWTemplate,
                   axis_budgets: List[int],
                   allowed_axis_dims: Tuple[Sequence[str], Sequence[str]],
                   ) -> None:
    """Spatially unroll dims across this level's unit array (greedy)."""
    lv = st.levels[level]
    while True:
        grew = False
        for tname in st.ranked_tensors():
            cands = [d for d in _helps(st.layer, tname) if st.remaining(d) > 1]
            # fallback: pure sharding still buys parallelism
            if not cands:
                cands = [d for d in DIMS if st.remaining(d) > 1]
            for d in cands:
                p = smallest_prime_factor(st.remaining(d))
                for ax in (0, 1):
                    if d not in allowed_axis_dims[ax] or axis_budgets[ax] < p:
                        continue
                    lv.s[d] = lv.sf(d) * p
                    axis_budgets[ax] //= p
                    grew = True
                    break
                if grew:
                    break
            if grew:
                break
        if not grew:
            return


def _caching_pass(st: _State, level: int, hw: HWTemplate,
                  first_dims: Sequence[str] = ()) -> None:
    """Temporally enlarge per-buffer tensors until capacity is used up.

    ``first_dims`` are exhausted first (used to keep reduction dims fully
    on-chip for pipelined producers)."""
    lv = st.levels[level]
    cap = hw.levels[level].capacity_bytes
    blocked: set = set()
    for d in first_dims:
        while st.remaining(d) > 1 and (level, d) not in blocked:
            p = smallest_prime_factor(st.remaining(d))
            lv.t[d] = lv.tf(d) * p
            if st.scheme.level_footprint_bytes(level) > cap:
                lv.t[d] //= p
                blocked.add((level, d))
    while True:
        grew = False
        for tname in st.ranked_tensors():
            cands = [d for d in _helps(st.layer, tname)
                     if st.remaining(d) > 1 and (level, d) not in blocked]
            if not cands:
                cands = [d for d in DIMS
                         if st.remaining(d) > 1 and (level, d) not in blocked]
            for d in cands:
                p = smallest_prime_factor(st.remaining(d))
                lv.t[d] = lv.tf(d) * p
                if st.scheme.level_footprint_bytes(level) > cap:
                    lv.t[d] //= p          # revert, mark dim done here
                    blocked.add((level, d))
                    continue
                grew = True
                break
            if grew:
                break
        if not grew:
            return


def _order_candidates(constr: Constraints) -> List[Tuple[str, ...]]:
    orders = canonical_orders()
    if constr.outer_dims:
        orders = [o for o in orders
                  if o[: len(constr.outer_dims)] == tuple(constr.outer_dims)] \
            or orders
    return orders


def solve_intra_layer(layer: LayerSpec, hw: HWTemplate,
                      constr: Optional[Constraints] = None,
                      use_cache: bool = True,
                      ) -> Tuple[Optional[LayerScheme], CostBreakdown]:
    """Algorithm 1: returns (best scheme, its detailed cost).

    Results are memoized on the canonical layer signature + hardware
    fingerprint + constraints (``use_cache=False`` forces a cold solve)."""
    constr = constr or Constraints(nodes=hw.node_array)
    key = solve_key(layer, hw, constr)
    if use_cache:
        hit = intra_cache.get(key, layer)
        if hit is not None:
            return hit
    n_levels = len(hw.levels)
    st = _State(layer, n_levels)

    # Level 0 (REGF): spatial mapping constrained by the PE dataflow template.
    pe_axes = _pe_axis_dims(hw)
    _stacking_pass(st, 0, hw, list(hw.pe_array), pe_axes)
    _caching_pass(st, 0, hw)

    # Level 1 (GBUF): free node parallelization within the assigned region.
    if n_levels >= 3:
        all_dims = tuple(d for d in DIMS)
        _stacking_pass(st, 1, hw, list(constr.nodes), (all_dims, all_dims))
        first = tuple(layer.reduction_dims) if constr.full_reduction_onchip \
            else ()
        _caching_pass(st, 1, hw, first_dims=first)

    st.finalize_leftovers()
    if constr.full_reduction_onchip:
        top = st.levels[-1]
        for d in layer.reduction_dims:
            if top.tf(d) > 1:   # pull reduction leftovers into GBUF caching
                st.levels[-2].t[d] = st.levels[-2].tf(d) * top.tf(d)
                top.t[d] = 1
        cap = hw.levels[-2].capacity_bytes
        if st.scheme.level_footprint_bytes(n_levels - 2) > cap:
            bad = invalid("cannot keep reduction on-chip")
            if use_cache:
                intra_cache.put(key, None, bad)
            return None, bad

    # ---- enumerate loop orders (GBUF x DRAM) and sharing toggles ------------
    # The whole order x order x shr cross product is scored as ONE batch with
    # the vectorized cost model; candidates share the greedy factors and only
    # vary in order/shr, so they are packed without per-candidate dict copies.
    orders_top = _order_candidates(constr)
    orders_mid = canonical_orders()
    shr_opts: List[Dict[str, int]] = [{}]
    if hw.levels[-1].same_level_transfer and n_levels >= 3:
        for tname in layer.tensors:
            repl = st.scheme.replication(tname, 1)
            if repl > 1:
                shr_opts.append({tname: repl})
    variants = list(itertools.product(orders_top, orders_mid, shr_opts))

    def materialize(o_top, o_mid, shr) -> LayerScheme:
        cand_levels = [lv.copy() for lv in st.levels]
        cand_levels[-1].order = o_top
        cand_levels[1].order = o_mid
        cand_levels[1].shr = dict(shr)
        return LayerScheme(layer, cand_levels)

    best: Tuple[Optional[LayerScheme], CostBreakdown] = (None, invalid("none"))
    if n_levels >= 3:
        # zero-copy candidate views: levels share the greedy factor dicts,
        # only order/shr differ; evaluation never mutates them
        cands = [LayerScheme(layer, [
            st.levels[0],
            LevelBlocking(t=st.levels[1].t, s=st.levels[1].s,
                          order=o_mid, shr=dict(shr)),
            *st.levels[2:-1],
            LevelBlocking(t=st.levels[-1].t, s=st.levels[-1].s,
                          order=o_top)])
            for o_top, o_mid, shr in variants]
        res = score_schemes(cands, hw, nodes_assigned=constr.num_nodes,
                            src_onchip=constr.src_onchip,
                            dst_onchip=constr.dst_onchip)
        bi = res.best("energy")
        if bi >= 0:
            best = (materialize(*variants[bi]), res.breakdown(bi))
    else:
        for o_top, o_mid, shr in variants:
            cand = materialize(o_top, o_mid, shr)
            cost = evaluate_layer(cand, hw, nodes_assigned=constr.num_nodes,
                                  src_onchip=constr.src_onchip,
                                  dst_onchip=constr.dst_onchip)
            if cost.valid and cost.energy_pj < best[1].energy_pj:
                best = (cand, cost)
    if use_cache:
        intra_cache.put(key, best[0], best[1])
    return best
