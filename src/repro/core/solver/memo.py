"""Solver-wide memoization (layer-signature caches).

Real networks repeat identical layer shapes (ResNet blocks, LSTM cells,
MobileNet's stacked dw/pw pairs), and the inter-layer DP re-solves the same
(layer, constraints) pair across many candidate chains.  A *canonical layer
signature* — the layer's shape/tensor structure with the identity stripped
(name, graph edges) — plus the hardware fingerprint and the inter-layer
constraints fully determine an intra-layer solve, so repeated layers are
solved exactly once per process.

Cached values store the scheme's levels detached from any particular
``LayerSpec`` so a hit can be re-bound to the requesting layer object.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, Hashable, Optional, Tuple

from ...hw.template import HWTemplate
from ...obs import metrics
from ...workloads.layers import LayerSpec
from ..cost_model import CostBreakdown
from ..directives import LayerScheme

_m_memo = metrics.counter(
    "solver_memo_total", "layer-signature memo lookups",
    ("cache", "outcome"))


def _freeze_mapping(m) -> Tuple:
    if m is None:
        return ()
    return tuple(sorted((k, v if not isinstance(v, frozenset)
                         else tuple(sorted(v))) for k, v in m.items()))


def layer_signature(layer: LayerSpec) -> Hashable:
    """Canonical shape signature: everything that feeds the cost model,
    nothing that identifies the layer within a graph (name, src edges)."""
    return (layer.kind,
            _freeze_mapping(layer.dims),
            _freeze_mapping({t: tuple(sorted(rel))
                             for t, rel in layer.tensors.items()}),
            _freeze_mapping(layer.unit),
            _freeze_mapping(layer.unit_inner),
            layer.macs_per_point,
            tuple(sorted(layer.reduction_dims)),
            layer.bytes_per_elem,
            layer.has_weights)


def constraints_key(constr) -> Hashable:
    return (tuple(constr.nodes), constr.src_onchip, constr.dst_onchip,
            constr.full_reduction_onchip, tuple(constr.outer_dims))


def solve_key(layer: LayerSpec, hw: HWTemplate, constr,
              extra: Hashable = None) -> Hashable:
    """Full memo key for one intra-layer solve.  ``hw`` is a frozen
    dataclass and hashes by value, i.e. equal presets share entries."""
    return (layer_signature(layer), hw, constraints_key(constr), extra)


class SolveCache:
    """Bounded dict cache for (scheme, cost) solve results.

    Schemes are stored as detached level lists and re-bound to the caller's
    layer on lookup; costs are copied so callers can never corrupt an entry.

    Thread-safe: ``kapla.solve`` fans segment solves out to a thread pool,
    so concurrent get/put on the same key must be benign (both threads
    compute the same value; last put wins).
    """

    def __init__(self, max_entries: int = 4096, name: str = "anon"):
        self.max_entries = max_entries
        self.name = name
        self._store: Dict[Hashable, Tuple[Optional[list], CostBreakdown]] = {}
        self._lock = threading.Lock()
        # plain ints (tests read them directly); lookups are also
        # mirrored into solver_memo_total{cache,outcome} (repro.obs)
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._store)

    def clear(self) -> None:
        with self._lock:
            self._store.clear()
            self.hits = 0
            self.misses = 0

    def get(self, key: Hashable, layer: LayerSpec
            ) -> Optional[Tuple[Optional[LayerScheme], CostBreakdown]]:
        with self._lock:
            entry = self._store.get(key)
            if entry is None:
                self.misses += 1
                _m_memo.inc(cache=self.name, outcome="miss")
                return None
            self.hits += 1
        _m_memo.inc(cache=self.name, outcome="hit")
        # entries are never mutated after insertion, so the defensive
        # copies can be built outside the lock (keeps the hit path of
        # concurrent segment solves from serializing)
        levels, cost = entry
        scheme = None if levels is None else \
            LayerScheme(layer, [lv.copy() for lv in levels])
        return scheme, dataclasses.replace(cost)

    def put(self, key: Hashable, scheme: Optional[LayerScheme],
            cost: CostBreakdown) -> None:
        levels = None if scheme is None else [lv.copy()
                                              for lv in scheme.levels]
        with self._lock:
            if len(self._store) >= self.max_entries:
                self._store.clear()         # simple epoch eviction
            self._store[key] = (levels, dataclasses.replace(cost))


# process-wide caches, one per solver family
intra_cache = SolveCache(name="intra")
exhaustive_cache = SolveCache(name="exhaustive")


def clear_all() -> None:
    """Reset every process-wide solver cache, including the lru_cached pure
    helpers and the graph-attached pack / candidate-batch caches, so 'cold'
    timings really are cold."""
    from .. import cost_batch, directives
    from . import interlayer
    intra_cache.clear()
    exhaustive_cache.clear()
    directives._divisors_cached.cache_clear()
    directives.smallest_prime_factor.cache_clear()
    directives._canonical_orders_cached.cache_clear()
    cost_batch.pack_order.cache_clear()
    interlayer.clear_graph_caches()


def stats() -> Dict[str, Any]:
    return {"intra": {"entries": len(intra_cache),
                      "hits": intra_cache.hits,
                      "misses": intra_cache.misses},
            "exhaustive": {"entries": len(exhaustive_cache),
                           "hits": exhaustive_cache.hits,
                           "misses": exhaustive_cache.misses}}
