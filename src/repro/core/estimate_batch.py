"""Batched, vectorized inter-layer segment estimation (KAPLA §IV-B).

The scalar upper level evaluates one Python ``estimate_layer`` call per
(segment range, alloc option, granule fraction, layer) candidate.  On deep
graphs (ResNet-50, transformer stacks) that scalar loop dominates the solve
now that the intra-layer judge is vectorized (``cost_batch.py``).  Here the
whole candidate set is packed into flat numpy arrays instead:

  * ``pack_graph`` precomputes every per-layer scalar the optimistic
    estimator needs (MACs, tensor sizes, candidate-independent energy
    terms, producer/consumer index ranges) once per graph;
  * ``estimate_segments`` evaluates validity masks
    (``min_buffer_requirement_bytes``), energy / latency / DRAM lower
    bounds, and the pipelining fill term for *all* candidates in one
    vectorized shot.

The math is arranged to be **bit-exact** with the scalar reference path
(``estimate.estimate_layer`` + ``interlayer.estimate_segment_scalar``):
per-layer partial sums are precomputed in the scalar accumulation order,
per-candidate reductions run sequentially over the (short) segment axis,
and the four (src_onchip, dst_onchip) DRAM variants are tabulated rather
than derived by subtraction.  Parity is enforced by
``tests/test_interlayer_batch.py``.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Tuple

import numpy as np

from ..hw.template import HWTemplate
from ..workloads.layers import LayerGraph


@dataclasses.dataclass(frozen=True)
class GraphPack:
    """Per-layer scalars of a ``LayerGraph`` packed as flat arrays.

    ``dram_variants[i, v]`` holds layer *i*'s DRAM lower-bound element count
    for on-chip flag combination ``v = src_onchip + 2 * dst_onchip``.
    Producer/consumer layer-index ranges let segment-membership io flags be
    computed with pure comparisons: for a contiguous segment [start, stop),
    ``src_onchip = src_ok & (min_src >= start) & (max_src < stop)`` and
    ``dst_onchip = has_cons & (min_cons >= start) & (max_cons < stop)``.
    """

    n_layers: int
    macs: np.ndarray            # [n] float64
    bytes_per_elem: np.ndarray  # [n] float64
    ifmap: np.ndarray           # [n] ifmap_size()
    ofmap: np.ndarray           # [n] ofmap_size()
    base_energy: np.ndarray     # [n] MAC + REGF + GBUF energy terms
    dram_variants: np.ndarray   # [n, 4] DRAM elems per (src, dst) combo
    src_ok: np.ndarray          # [n] bool: has srcs and all exist in graph
    min_src: np.ndarray         # [n] int64
    max_src: np.ndarray         # [n] int64
    has_cons: np.ndarray        # [n] bool
    min_cons: np.ndarray        # [n] int64
    max_cons: np.ndarray        # [n] int64


def pack_graph(graph: LayerGraph, hw: HWTemplate) -> GraphPack:
    idx = {l.name: i for i, l in enumerate(graph.layers)}
    n = len(graph.layers)
    macs = np.empty(n)
    bpe = np.empty(n)
    ifmap = np.empty(n)
    ofmap = np.empty(n)
    base_e = np.empty(n)
    dram_var = np.empty((n, 4))
    src_ok = np.zeros(n, dtype=bool)
    min_src = np.zeros(n, dtype=np.int64)
    max_src = np.zeros(n, dtype=np.int64)
    has_cons = np.zeros(n, dtype=bool)
    min_cons = np.zeros(n, dtype=np.int64)
    max_cons = np.zeros(n, dtype=np.int64)

    cons: list = [[] for _ in range(n)]
    for j, l in enumerate(graph.layers):
        for s in l.src:
            si = idx.get(s)
            if si is not None:
                cons[si].append(j)

    e_regf = hw.levels[0].access_energy_pj_per_byte
    e_gbuf = hw.levels[1].access_energy_pj_per_byte
    for i, l in enumerate(graph.layers):
        B = float(l.bytes_per_elem)
        m = l.total_macs()
        macs[i] = m
        bpe[i] = B
        ifmap[i] = l.ifmap_size()
        ofmap[i] = l.ofmap_size()
        # candidate-independent energy, accumulated exactly like the scalar
        # estimator: MAC ops, REGF operand traffic, one GBUF pass
        op_e = hw.mac_energy_pj if l.has_weights else 0.2 * hw.mac_energy_pj
        gbuf_elems = 0.0
        for t in l.tensors:
            gbuf_elems += l.tensor_size(t)
        e = 0.0
        e += m * op_e
        e += m * 3 * B * e_regf
        e += gbuf_elems * B * e_gbuf
        base_e[i] = e
        # DRAM lower bound per on-chip combo, same accumulation order as the
        # scalar loop (terms omitted, never subtracted)
        for v in range(4):
            s_on, d_on = bool(v & 1), bool(v & 2)
            acc = 0.0
            for t in l.tensors:
                if t == "I" and s_on:
                    continue
                if t == "O" and d_on:
                    continue
                acc += l.tensor_size(t)
            dram_var[i, v] = acc
        if l.src and all(s in idx for s in l.src):
            src_ok[i] = True
            sidx = [idx[s] for s in l.src]
            min_src[i] = min(sidx)
            max_src[i] = max(sidx)
        if cons[i]:
            has_cons[i] = True
            min_cons[i] = min(cons[i])
            max_cons[i] = max(cons[i])
    return GraphPack(n, macs, bpe, ifmap, ofmap, base_e, dram_var,
                     src_ok, min_src, max_src, has_cons, min_cons, max_cons)


def pack_fingerprint(gp: GraphPack) -> bytes:
    """Deterministic digest of a ``GraphPack``'s arrays — the per-layer
    numeric content the inter-layer solver actually consumes, with layer
    *identity* (names) already stripped by construction.  Renaming layers
    leaves the digest unchanged; reordering, reshaping or re-batching any
    layer changes it.  The schedule store's content signatures
    (``repro.service.signature``) are built on this."""
    h = hashlib.sha256()
    h.update(str(gp.n_layers).encode())
    for arr in (gp.macs, gp.bytes_per_elem, gp.ifmap, gp.ofmap,
                gp.base_energy, gp.dram_variants, gp.src_ok, gp.min_src,
                gp.max_src, gp.has_cons, gp.min_cons, gp.max_cons):
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.digest()


def estimate_segments(gp: GraphPack, hw: HWTemplate,
                      starts: np.ndarray, stops: np.ndarray,
                      gfs: np.ndarray, nodes: np.ndarray,
                      ) -> Tuple[np.ndarray, np.ndarray,
                                 np.ndarray, np.ndarray]:
    """Estimate a batch of segment candidates in one vectorized shot.

    starts/stops/gfs: [C] candidate arrays; nodes: [C, Lmax] node counts per
    segment position (padded past each candidate's length with 1).

    Returns (valid, energy_lb_pj, latency_lb_cycles, dram_bytes_lb), each
    [C], with inf on invalid lanes.  Numerically identical to running the
    scalar ``estimate_segment_scalar`` per candidate.
    """
    C, Lmax = nodes.shape
    lengths = stops - starts
    pos = np.arange(Lmax, dtype=np.int64)
    mask = pos[None, :] < lengths[:, None]                   # [C, Lmax]
    lidx = np.minimum(starts[:, None] + pos[None, :], gp.n_layers - 1)

    starts_c = starts[:, None]
    stops_c = stops[:, None]
    src_on = gp.src_ok[lidx] & (gp.min_src[lidx] >= starts_c) \
        & (gp.max_src[lidx] < stops_c)
    dst_on = gp.has_cons[lidx] & (gp.min_cons[lidx] >= starts_c) \
        & (gp.max_cons[lidx] < stops_c)

    B = gp.bytes_per_elem[lidx]
    gf_c = gfs[:, None]
    # min_buffer_requirement_bytes, src term added before dst term
    need = np.where(src_on, 2.0 * gp.ifmap[lidx] * gf_c * B, 0.0) \
        + np.where(dst_on, 2.0 * gp.ofmap[lidx] * gf_c * B, 0.0)
    agg_gbuf = nodes * hw.gbuf.capacity_bytes
    valid = np.all((need <= agg_gbuf) | ~mask, axis=1)

    variant = src_on.astype(np.int64) + 2 * dst_on.astype(np.int64)
    dram_bytes_cp = gp.dram_variants[lidx, variant] * B       # [C, Lmax]
    energy_cp = gp.base_energy[lidx] + dram_bytes_cp * \
        hw.levels[-1].access_energy_pj_per_byte

    pes = nodes * hw.num_pes_per_node
    lat_cp = np.maximum(
        gp.macs[lidx] / np.maximum(1, pes),
        dram_bytes_cp / hw.levels[-1].bandwidth_bytes_per_cycle /
        max(1, hw.dram_ports))

    # sequential reductions over the (short) segment axis: same association
    # order as the scalar per-layer accumulation loop, so sums are bit-exact
    energy = np.zeros(C)
    latency = np.zeros(C)
    dram = np.zeros(C)
    for p in range(Lmax):
        m = mask[:, p]
        energy = np.where(m, energy + energy_cp[:, p], energy)
        latency = np.where(m, np.maximum(latency, lat_cp[:, p]), latency)
        dram = np.where(m, dram + dram_bytes_cp[:, p], dram)
    # fine-grained forwarding: fill cost of one granule per extra stage
    latency = latency + latency * gfs * np.maximum(0, lengths - 1)

    inf = float("inf")
    return (valid,
            np.where(valid, energy, inf),
            np.where(valid, latency, inf),
            np.where(valid, dram, inf))
