"""KAPLA -> mesh sharding: the paper's solver structure applied to TPU pods.

The mapping (DESIGN.md §2): `stack` over mesh axes = PartitionSpec axis
assignment; `shr` (buffer sharing) = ZeRO-style optimizer-state sharding over
the data axis; validity check = per-chip HBM footprint; efficiency estimate =
the same 3-term roofline (compute / HBM / ICI) reported in EXPERIMENTS.md.

``plan_sharding`` enumerates a small candidate set (with/without ZeRO,
attention sharded vs replicated where head counts don't divide the model
axis), runs the conservative validity check on each (never rejects a plan
that could fit), estimates cost for the survivors, and returns the best —
inter-layer-style pruning + prioritization, at pod scale.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..hw.template import TPUPodSpec

PyTree = Any


@dataclasses.dataclass
class ShardingPlan:
    cfg_name: str
    shape_name: str
    param_specs: PyTree
    opt_specs: PyTree
    batch_specs: Dict[str, Any]
    cache_specs: Optional[PyTree]
    zero_opt: bool
    attn_sharded: bool
    hbm_gb_per_chip: float
    est_step_seconds: float
    notes: List[str]

    def param_shardings(self, mesh):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), self.param_specs,
            is_leaf=lambda x: isinstance(x, P))

    def opt_shardings(self, mesh):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), self.opt_specs,
            is_leaf=lambda x: isinstance(x, P))


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        else:
            out.append(str(k))
    return tuple(out)


def _sanitize(spec: P, shape: Tuple[int, ...], axis_sizes: Dict[str, int],
              ) -> P:
    """Drop shardings whose dim is not divisible by the axis size (the
    validity guard: never emit a spec GSPMD would have to pad)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (e, n) in enumerate(zip(entries, shape)):
        if e is None:
            continue
        axes = e if isinstance(e, tuple) else (e,)
        sz = math.prod(axis_sizes.get(a, 1) for a in axes)
        if sz == 0 or n % sz != 0:
            entries[i] = None
    return P(*entries)


def _param_spec(names: Tuple[str, ...], shape: Tuple[int, ...],
                cfg: ModelConfig, tp: int, attn_sharded: bool) -> P:
    raw = _param_spec_raw(names, shape, cfg, tp, attn_sharded)
    return _sanitize(raw, shape, {"model": tp})


def _param_spec_raw(names: Tuple[str, ...], shape: Tuple[int, ...],
                    cfg: ModelConfig, tp: int, attn_sharded: bool) -> P:
    """Sharding rules per parameter family.  Stacked layer params carry a
    leading L dim (never sharded); the 'shared' hybrid block does not."""
    name = names[-1]
    stacked = "blocks" in names            # leading layer axis
    lead = (None,) * (len(shape) - 2) if len(shape) >= 2 else ()

    def spec(*tail):
        # pad leading unsharded dims so len(spec) == ndim
        pad = (None,) * (len(shape) - len(tail))
        return P(*(pad + tail))

    if name == "embed":
        return P("model", None)            # vocab-parallel embedding
    if name == "lm_head":
        return P(None, "model")            # vocab-parallel logits
    if name in ("wq",):
        return spec(None, "model") if attn_sharded else spec(None, None)
    if name in ("wk", "wv"):
        kv_ok = (cfg.num_kv_heads % tp == 0) and attn_sharded
        return spec(None, "model") if kv_ok else spec(None, None)
    if name in ("bq",):
        return spec("model") if attn_sharded else spec(None)
    if name in ("bk", "bv"):
        kv_ok = (cfg.num_kv_heads % tp == 0) and attn_sharded
        return spec("model") if kv_ok else spec(None)
    if name == "wo" and "attn" in names:
        return spec("model", None) if attn_sharded else spec(None, None)
    if name in ("wi", "wg") and "moe" in names and len(shape) >= 3 \
            and names[-2] != "shared":
        return spec("model", None, None)   # expert-parallel
    if name == "wo" and "moe" in names and names[-2] != "shared":
        return spec("model", None, None)
    if name == "router":
        return spec(None, None)
    if name in ("wi", "wg"):               # dense / shared-expert FFN
        return spec(None, "model")
    if name == "wo":
        return spec("model", None)
    if name in ("w_x", "w_z"):
        return spec(None, "model")         # di (== heads) over model
    if name == "w_dt":
        return spec(None, "model") if cfg.ssm_heads % tp == 0 \
            else spec(None, None)
    if name in ("w_b", "w_c"):
        return spec(None, None)            # small shared projections
    if name == "w_out":
        return spec("model", None)
    if name in ("conv_x_w",):
        return spec(None, "model")
    if name in ("conv_x_b", "norm") and len(shape) >= 1:
        return spec("model")
    if name in ("a_log", "dt_bias", "d_skip"):
        return spec("model") if cfg.ssm_heads % tp == 0 else spec(None)
    return P(*((None,) * len(shape)))      # norms, small biases, misc


def _zero_spec(pspec: P, shape: Tuple[int, ...], dp_axes: Tuple[str, ...],
               dp_size: int) -> P:
    """ZeRO: shard the first still-replicated, divisible dim over data —
    the paper's buffer-sharing `shr` (one copy across sibling buffers)."""
    entries = list(pspec) + [None] * (len(shape) - len(pspec))
    for i, (e, n) in enumerate(zip(entries, shape)):
        if e is None and n % dp_size == 0 and n >= dp_size:
            entries[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
            return P(*entries)
    return P(*entries)


def _bytes_of(shape, dtype) -> float:
    return math.prod(shape) * jnp.dtype(dtype).itemsize


def _sharded_bytes(shape, dtype, spec: P, mesh_shape: Dict[str, int]) -> float:
    b = _bytes_of(shape, dtype)
    for entry in spec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            b /= mesh_shape[a]
    return b


def plan_sharding(cfg: ModelConfig, shape: ShapeConfig, mesh,
                  param_shapes: PyTree, opt_state_shapes: PyTree,
                  cache_shapes: Optional[PyTree] = None,
                  pod: TPUPodSpec = TPUPodSpec()) -> ShardingPlan:
    """Pick the sharding plan via conservative validity + cost estimate."""
    mesh_shape = dict(mesh.shape)
    tp = mesh_shape.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh_shape)
    dp_size = math.prod(mesh_shape[a] for a in dp_axes) if dp_axes else 1
    chips = math.prod(mesh_shape.values())

    heads_ok = cfg.family in ("dense", "moe") and cfg.num_heads % tp == 0
    candidates = []
    for zero in (True, False):
        for attn_sharded in ((True, False) if heads_ok else (False,)):
            candidates.append((zero, attn_sharded, False))
    if cfg.family in ("ssm", "hybrid"):
        candidates = [(z, cfg.family == "hybrid" and
                       cfg.num_heads % tp == 0, False) for z in (True, False)]
    # FSDP (fully-sharded params over the data axes) is the fallback tier:
    # required for the 1T-param config whose params exceed TP-only HBM.
    # jit all-gathers each scan iteration's layer params on demand.
    candidates += [(True, heads_ok, True)]

    best = None
    notes: List[str] = []
    flat_params = jax.tree_util.tree_flatten_with_path(param_shapes)[0]

    for zero, attn_sharded, fsdp in candidates:
        # --- build specs ------------------------------------------------
        def pspec_fn(path, leaf):
            base = _param_spec(_path_names(path), leaf.shape, cfg, tp,
                               attn_sharded)
            if fsdp and dp_axes:
                return _zero_spec(base, leaf.shape, dp_axes, dp_size)
            return base
        param_specs = jax.tree_util.tree_map_with_path(pspec_fn, param_shapes)

        def ospec_fn(path, leaf):
            names = _path_names(path)
            # optimizer state mirrors the param rules on matching suffixes
            base = _param_spec(names, leaf.shape, cfg, tp, attn_sharded)
            base = P(*(list(base) + [None] * (len(leaf.shape) - len(base)))) \
                if len(base) < len(leaf.shape) else \
                P(*list(base)[: len(leaf.shape)])
            if zero and dp_axes:
                return _zero_spec(base, leaf.shape, dp_axes, dp_size)
            return base
        opt_specs = jax.tree_util.tree_map_with_path(ospec_fn,
                                                     opt_state_shapes)

        # --- conservative validity: per-chip HBM footprint ----------------
        pb = sum(_sharded_bytes(l.shape, l.dtype,
                                pspec_fn(p, l), mesh_shape)
                 for p, l in flat_params)
        ob = sum(_sharded_bytes(l.shape, l.dtype, ospec_fn(p, l), mesh_shape)
                 for p, l in
                 jax.tree_util.tree_flatten_with_path(opt_state_shapes)[0])
        grad_b = pb if shape.mode == "train" else 0.0
        # activation working set (scan keeps one block live; remat shrinks
        # the saved-residual term)
        tokens_local = shape.global_batch * (shape.seq_len if shape.mode !=
                                             "decode" else 1) / max(1, dp_size)
        act_mult = 4 if cfg.remat == "block" else 12
        act_b = tokens_local * cfg.d_model * 2 * act_mult \
            * (1 if shape.mode != "train" else cfg.num_layers ** 0.5)
        if cfg.seq_shard and tp > 1:
            act_b /= tp        # sequence-parallel residuals
        cache_b = 0.0
        if cache_shapes is not None:
            cache_b = sum(
                _sharded_bytes(l.shape, l.dtype,
                               _cache_spec(_path_names(p), l.shape, cfg, tp,
                                           dp_axes, shape, mesh_shape),
                               mesh_shape)
                for p, l in
                jax.tree_util.tree_flatten_with_path(cache_shapes)[0])
        hbm = pb + ob + grad_b + act_b + cache_b
        valid = hbm <= pod.hbm_bytes * 0.92
        # --- cost estimate: 3-term roofline -------------------------------
        flops = 6.0 * cfg.active_param_count() * shape.global_batch \
            * (shape.seq_len if shape.mode == "train" else
               (shape.seq_len if shape.mode == "prefill" else 1))
        if shape.mode != "train":
            flops /= 3.0                   # no backward
        t_compute = flops / (chips * pod.peak_flops_bf16)
        t_memory = (pb + ob + cache_b) / pod.hbm_bw
        # collective estimate: TP all-reduces of activations per layer
        t_coll = 0.0
        if tp > 1:
            act_bytes = tokens_local * cfg.d_model * 2
            per_layer = 2 * act_bytes * 2 * (tp - 1) / tp / \
                (pod.ici_link_bw * pod.ici_links_per_chip)
            t_coll = per_layer * cfg.num_layers
        if zero and shape.mode == "train":
            t_coll += pb / (pod.ici_link_bw * pod.ici_links_per_chip)
        if fsdp:
            # per-step param all-gather over the data axes
            t_coll += pb * (dp_size - 1) / max(1, dp_size) \
                / (pod.ici_link_bw * pod.ici_links_per_chip) * 2.0
        est = max(t_compute, t_memory, t_coll)
        tag = f"zero={zero} attn_sharded={attn_sharded} fsdp={fsdp}: " \
              f"hbm={hbm / 2**30:.1f}GiB valid={valid} est={est * 1e3:.1f}ms"
        notes.append(tag)
        # FSDP is fallback-only: pick it when nothing else fits
        if valid and (best is None or
                      (est < best[0] and fsdp == best[6]) or
                      (not fsdp and best[6])):
            best = (est, zero, attn_sharded, param_specs, opt_specs,
                    hbm / 2 ** 30, fsdp)

    if best is None:
        # fall back to the most aggressive sharding even if over budget —
        # report the overflow rather than refusing to plan
        zero, attn_sharded = True, heads_ok
        fsdp = True
        best_est = float("inf")
        def pspec_fn(path, leaf):
            base = _param_spec(_path_names(path), leaf.shape, cfg, tp,
                               attn_sharded)
            return _zero_spec(base, leaf.shape, dp_axes, dp_size) \
                if dp_axes else base
        param_specs = jax.tree_util.tree_map_with_path(pspec_fn, param_shapes)
        def ospec_fn(path, leaf):
            base = _param_spec(_path_names(path), leaf.shape, cfg, tp,
                               attn_sharded)
            return _zero_spec(base, leaf.shape, dp_axes, dp_size) \
                if dp_axes else base
        opt_specs = jax.tree_util.tree_map_with_path(ospec_fn,
                                                     opt_state_shapes)
        notes.append("WARNING: no candidate fits HBM; using max sharding")
        best = (best_est, zero, attn_sharded, param_specs, opt_specs,
                float("nan"), fsdp)

    est, zero, attn_sharded, param_specs, opt_specs, hbm_gb, fsdp = best

    # --- data / cache specs ---------------------------------------------
    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    batchable = shape.global_batch >= dp_size
    bspec = dp if batchable else None
    if cfg.frontend == "embed" and shape.mode != "decode":
        in_spec = P(bspec, None, None)
    else:
        in_spec = P(bspec, None)
    batch_specs = {"inputs": in_spec, "targets": P(bspec, None)}

    cache_specs = None
    if cache_shapes is not None:
        cache_specs = jax.tree_util.tree_map_with_path(
            lambda p, l: _cache_spec(_path_names(p), l.shape, cfg, tp,
                                     dp_axes, shape, mesh_shape),
            cache_shapes)

    return ShardingPlan(cfg.name, shape.name, param_specs, opt_specs,
                        batch_specs, cache_specs, zero, attn_sharded,
                        hbm_gb, est, notes)


def _cache_spec(names: Tuple[str, ...], shape_t: Tuple[int, ...],
                cfg: ModelConfig, tp: int, dp_axes: Tuple[str, ...],
                shape: ShapeConfig, mesh_shape: Dict[str, int]) -> P:
    dp_size = math.prod(mesh_shape[a] for a in dp_axes) if dp_axes else 1
    name = names[-1]
    B = shape.global_batch
    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    bspec = dp if B >= dp_size else None
    seq_spec = None if B >= dp_size or not dp_axes else "data"
    if name in ("k", "v", "k_scale", "v_scale"):
        kv_ok = cfg.num_kv_heads % tp == 0
        if kv_ok:
            return P(None, bspec, "model", seq_spec, None)
        # KV heads don't divide the model axis: shard the cache SEQUENCE
        # over 'model' instead (sequence-parallel decode attention — GSPMD
        # inserts the partial-softmax all-reduce); never replicate a
        # multi-GiB cache
        return P(None, bspec, None, "model", None)
    if name == "ssm":
        h_ok = cfg.ssm_heads % tp == 0
        return P(None, bspec, "model" if h_ok else None, None, None)
    if name == "conv_x":
        return P(None, bspec, None, "model")
    if name in ("conv_b", "conv_c"):
        return P(None, bspec, None, None)
    return P(*((None,) * len(shape_t)))
