"""Detailed analytical cost model (the stand-in for the nn-dataflow simulator).

Given a complete ``LayerScheme`` on an ``HWTemplate``, produce energy (pJ) and
latency (cycles) with per-component breakdowns.  This model is the *judge*:
all solvers (KAPLA, exhaustive, random, annealing) are scored with it.
KAPLA's internal guidance uses the cheaper optimistic estimates in
``estimate.py`` — mirroring the paper's separation of the two models.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from ..hw.template import HWTemplate
from .directives import LayerScheme


#: the per-term energy attribution order: these five fields sum to
#: ``energy_pj`` exactly (``evaluate_layer`` computes the total as their
#: sum), which is what lets the explain record's attribution reconcile
#: against a schedule's scored cost.
ENERGY_TERMS = ("mac_energy", "regf_energy", "gbuf_energy", "noc_energy",
                "dram_energy")


@dataclasses.dataclass
class CostBreakdown:
    valid: bool
    energy_pj: float = float("inf")
    latency_cycles: float = float("inf")
    mac_energy: float = 0.0
    regf_energy: float = 0.0
    gbuf_energy: float = 0.0
    noc_energy: float = 0.0
    dram_energy: float = 0.0
    dram_traffic_bytes: float = 0.0
    gbuf_traffic_bytes: float = 0.0       # per-node fill traffic
    pes_used: int = 0
    nodes_used: int = 0
    reason: str = ""

    def edp(self) -> float:
        return self.energy_pj * self.latency_cycles

    def attribution(self) -> Dict[str, float]:
        """Per-term energy attribution; values sum to ``energy_pj``."""
        return {t: getattr(self, t) for t in ENERGY_TERMS}


def attribute_costs(costs) -> Dict[str, float]:
    """Aggregate per-term attribution across breakdowns (a segment's or
    a whole schedule's ``layer_costs``).  The returned terms sum to the
    summed ``energy_pj`` up to float association order — the explain
    record's reconciliation invariant; ``total_pj`` carries the summed
    ``energy_pj`` for cross-checking."""
    out = {t: 0.0 for t in ENERGY_TERMS}
    total = 0.0
    for c in costs:
        for t in ENERGY_TERMS:
            out[t] += getattr(c, t)
        total += c.energy_pj
    out["total_pj"] = total
    return out


def invalid(reason: str) -> CostBreakdown:
    return CostBreakdown(valid=False, reason=reason)


# ---------------------------------------------------------------------------
# Measured-runtime calibration (fit by repro.lower.calibrate against real
# kernel executions; optional — nothing in the solver path requires it).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Per-term scale coefficients mapping model cycle terms to measured
    seconds:  seconds ~= a_compute*cyc_compute + a_dram*cyc_dram
    + a_gbuf*cyc_gbuf + a_step*grid_steps + intercept.

    Fitted by ``repro.lower.calibrate.fit_calibration`` from a sweep of
    executed kernel plans; ``spearman`` records the rank correlation of the
    *uncalibrated* model against the measurements it was fitted on."""

    a_compute: float = 0.0
    a_dram: float = 0.0
    a_gbuf: float = 0.0
    a_step: float = 0.0
    intercept: float = 0.0
    spearman: float = 0.0
    n_pairs: int = 0
    backend: str = "interpret"     # execution backend the fit measured

    def to_json_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json_dict(d: Dict) -> "Calibration":
        fields = {f.name for f in dataclasses.fields(Calibration)}
        return Calibration(**{k: v for k, v in d.items() if k in fields})


# One fitted Calibration per execution backend (interpreter seconds and
# compiled-XLA seconds are different units — a fit from one must never
# price the other), plus the *active* backend ``predicted_seconds``
# consults by default.
_calibrations: Dict[str, Calibration] = {}
_active_backend: Optional[str] = None


def set_calibration(cal: Optional[Calibration],
                    backend: Optional[str] = None) -> None:
    """Install a calibration for its backend and make that backend the
    active one (or clear everything, with None).  The cycle-level model
    and all parity paths are unaffected — calibration only rescales
    cycles into wall seconds."""
    global _active_backend
    if cal is None:
        if backend is None:
            _calibrations.clear()
            _active_backend = None
        else:
            _calibrations.pop(backend, None)
            if _active_backend == backend:
                _active_backend = None
        return
    backend = backend if backend is not None else cal.backend
    _calibrations[backend] = cal
    _active_backend = backend


def get_calibration(backend: Optional[str] = None) -> Optional[Calibration]:
    """The installed calibration for ``backend`` (the active backend's
    when None)."""
    if backend is None:
        backend = _active_backend
    return _calibrations.get(backend) if backend is not None else None


def load_calibration(path: str,
                     backend: Optional[str] = None) -> Calibration:
    """Load a calibration record (``BENCH_calibration.json`` shape) and
    install it under its backend — the record's ``backend`` field wins
    unless overridden, so a compiled-backend sweep loads as compiled
    coefficients, never mislabeled as interpreter ones."""
    import json
    with open(path) as f:
        d = json.load(f)
    cal = Calibration.from_json_dict({
        "backend": d.get("backend", "interpret"),
        **d.get("calibration", d)})
    if backend is not None:
        cal = dataclasses.replace(cal, backend=backend)
    set_calibration(cal)
    return cal


def cycle_terms(cb: "CostBreakdown", macs: float, hw: HWTemplate
                ) -> Dict[str, float]:
    """Recover the roofline's component cycle counts from a breakdown (the
    stored ``latency_cycles`` keeps only their max)."""
    thruput = max(1, cb.pes_used * cb.nodes_used)
    return {
        "cyc_compute": macs / thruput,
        "cyc_dram": cb.dram_traffic_bytes
        / hw.levels[-1].bandwidth_bytes_per_cycle,
        "cyc_gbuf": cb.gbuf_traffic_bytes
        / hw.levels[1].bandwidth_bytes_per_cycle,
    }


def predicted_seconds(cb: "CostBreakdown", macs: float, hw: HWTemplate,
                      grid_steps: int = 0,
                      cal: Optional[Calibration] = None,
                      backend: Optional[str] = None) -> float:
    """Wall-clock latency prediction: calibrated when a ``Calibration`` is
    installed (or passed), otherwise raw cycles over the clock.  With
    ``backend`` the per-backend fit is consulted (e.g. compiled-backend
    coefficients instead of interpreter ones); invalid breakdowns predict
    inf (mirroring the batched path's valid-lane mask)."""
    if not cb.valid:
        return float("inf")
    cal = cal if cal is not None else get_calibration(backend)
    if cal is None:
        return cb.latency_cycles / hw.freq_hz
    t = cycle_terms(cb, macs, hw)
    return (cal.a_compute * t["cyc_compute"] + cal.a_dram * t["cyc_dram"]
            + cal.a_gbuf * t["cyc_gbuf"] + cal.a_step * grid_steps
            + cal.intercept)


def evaluate_layer(scheme: LayerScheme, hw: HWTemplate,
                   nodes_assigned: Optional[int] = None,
                   src_onchip: bool = False,
                   dst_onchip: bool = False) -> CostBreakdown:
    """Energy + latency for one layer under one intra-layer scheme.

    src_onchip / dst_onchip: the layer's input / output fmap tensor is
    forwarded on-chip from/to a pipelined neighbor layer (inter-layer spatial
    pipelining), replacing its DRAM traffic with NoC forwarding.
    """
    layer = scheme.layer
    B = layer.bytes_per_elem
    n_levels = len(hw.levels)
    if len(scheme.levels) != n_levels:
        return invalid("level count mismatch")
    if not scheme.validate_factors():
        return invalid("dim factors do not multiply to layer dims")

    # ---- validity: capacity & parallelism ----------------------------------
    for i in range(n_levels - 1):
        cap = hw.levels[i].capacity_bytes
        fp = scheme.level_footprint_bytes(i)
        if fp > cap:
            return invalid(f"{hw.levels[i].name} overflow {fp:.0f}B > {cap}B")
        s_prod = scheme.levels[i].s_product()
        avail = hw.levels[i + 1].num_units
        if s_prod > avail:
            return invalid(f"spatial {s_prod} > {avail} units at level {i}")
    nodes_used = scheme.levels[1].s_product() if n_levels >= 3 else 1
    if nodes_assigned is not None and nodes_used > nodes_assigned:
        return invalid(f"uses {nodes_used} nodes > {nodes_assigned} assigned")
    pes_used = scheme.levels[0].s_product()

    macs = layer.total_macs()
    cb = CostBreakdown(valid=True, energy_pj=0.0, pes_used=pes_used,
                       nodes_used=nodes_used)

    # ---- MAC + REGF compute-operand energy ---------------------------------
    op_e = hw.mac_energy_pj if layer.has_weights else 0.2 * hw.mac_energy_pj
    cb.mac_energy = macs * op_e
    e_regf = hw.levels[0].access_energy_pj_per_byte
    cb.regf_energy = macs * 3 * B * e_regf     # 2 operand reads + psum rw

    # ---- boundary REGF <- GBUF ---------------------------------------------
    e_gbuf = hw.levels[1].access_energy_pj_per_byte
    gbuf_fill = 0.0            # per-node elements read out of one GBUF
    for t in layer.tensors:
        f = scheme.fetches_into(t, 0)
        repl = scheme.replication(t, 0)
        mc = hw.levels[1].multicast
        reads = f if mc else f * repl
        delivered = f * repl
        gbuf_fill += reads
        cb.gbuf_energy += reads * B * e_gbuf
        cb.regf_energy += delivered * B * e_regf
        shr = scheme.levels[0].shr.get(t, 1)
        if shr > 1:            # systolic same-level forwarding between PEs
            cb.regf_energy += f * (shr - 1) * B * 2 * e_regf
    cb.gbuf_traffic_bytes = gbuf_fill * B

    # ---- boundary GBUF <- DRAM (or on-chip neighbor) ------------------------
    e_dram = hw.levels[-1].access_energy_pj_per_byte
    hops = hw.avg_noc_hops(nodes_used)
    e_hop = hw.noc_hop_energy_pj_per_byte
    dram_elems = 0.0
    for t in layer.tensors:
        f = scheme.fetches_into(t, 1)
        repl = scheme.replication(t, 1)
        delivered = f * repl
        onchip = (t == "I" and src_onchip) or (t == "O" and dst_onchip)
        if onchip:
            # forwarded between neighbor node GBUFs: one extra gbuf access +
            # short NoC path instead of a DRAM round trip
            cb.gbuf_energy += f * B * e_gbuf
            cb.noc_energy += delivered * B * e_hop * 2.0
        else:
            dram_elems += f
            cb.dram_energy += f * B * e_dram
            cb.noc_energy += delivered * B * e_hop * hops
        shr = scheme.levels[1].shr.get(t, 1)
        if shr > 1:            # buffer sharing rotation between node GBUFs
            cb.gbuf_energy += f * (shr - 1) * B * 2 * e_gbuf
            cb.noc_energy += f * (shr - 1) * B * e_hop
    cb.dram_traffic_bytes = dram_elems * B

    # ---- node-level spatial reduction (all-reduce of partial outputs) ------
    red_repl = 1
    for d in layer.reduction_dims:
        red_repl *= scheme.levels[1].sf(d)
    if red_repl > 1 and "O" in layer.tensors:
        psum = scheme.fetches_into("O", 1) * (red_repl - 1)
        cb.gbuf_energy += psum * B * 2 * e_gbuf
        cb.noc_energy += psum * B * e_hop

    cb.energy_pj = (cb.mac_energy + cb.regf_energy + cb.gbuf_energy +
                    cb.noc_energy + cb.dram_energy)

    # ---- latency: roofline over compute and each bandwidth ------------------
    mac_thruput = max(1, pes_used * nodes_used)
    cyc_compute = macs / mac_thruput
    cyc_dram = cb.dram_traffic_bytes / hw.levels[-1].bandwidth_bytes_per_cycle
    cyc_gbuf = cb.gbuf_traffic_bytes / hw.levels[1].bandwidth_bytes_per_cycle
    cyc_regf = (macs / mac_thruput) * B / hw.levels[0].bandwidth_bytes_per_cycle
    cb.latency_cycles = max(cyc_compute, cyc_dram, cyc_gbuf, cyc_regf)
    return cb


def combine_segment(costs, granules: int = 1) -> CostBreakdown:
    """Compose per-layer costs of one spatially-pipelined segment.

    Layers run concurrently on disjoint node regions; the segment latency is
    the slowest layer plus a pipeline-fill term of one forwarding granule per
    stage (finer granules => smaller fill, per the paper §III-A).
    """
    total = CostBreakdown(valid=True, energy_pj=0.0, latency_cycles=0.0)
    slowest = 0.0
    for c in costs:
        if not c.valid:
            return invalid("segment contains invalid layer: " + c.reason)
        total.energy_pj += c.energy_pj
        total.mac_energy += c.mac_energy
        total.regf_energy += c.regf_energy
        total.gbuf_energy += c.gbuf_energy
        total.noc_energy += c.noc_energy
        total.dram_energy += c.dram_energy
        total.dram_traffic_bytes += c.dram_traffic_bytes
        total.nodes_used += c.nodes_used
        slowest = max(slowest, c.latency_cycles)
    fill = slowest / max(1, granules) * max(0, len(list(costs)) - 1)
    total.latency_cycles = slowest + fill
    return total
