"""Batched, vectorized detailed cost model.

Scores a whole *batch* of intra-layer scheme candidates for one
(layer, hardware, inter-layer context) at once with NumPy array math,
numerically identical (within fp tolerance) to the scalar reference judge
``cost_model.evaluate_layer``.  Candidates are packed into flat *factor
tables* — per-dim temporal/spatial factors per level, loop orders as
dim-index permutations, per-tensor sharing factors — instead of one
``LayerScheme`` object (with per-level dict copies) per candidate.

This is the hot path of every solver: KAPLA's final order x order x shr
enumeration, the exhaustive baseline's divisor-ladder sweep, and the
random/annealing baselines' sample batches all funnel through
``evaluate_batch``.  The scalar model remains the reference; parity is
enforced by ``tests/test_cost_batch.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..hw.template import HWTemplate
from ..workloads.layers import DIMS, LayerSpec
from .cost_model import CostBreakdown, invalid
from .directives import LayerScheme, LevelBlocking

DIM_IDX: Dict[str, int] = {d: i for i, d in enumerate(DIMS)}
ND = len(DIMS)


@functools.lru_cache(maxsize=None)
def pack_order(order: Sequence[str]) -> Tuple[Tuple[int, ...],
                                              Tuple[bool, ...]]:
    """Encode a loop order as (dim indices outer->inner, participation mask).

    Dims absent from ``order`` are appended as padding with mask False so
    every encoded order has exactly ``len(DIMS)`` positions; padded positions
    contribute factor 1 to the loop nest (mirroring the scalar model, which
    drops dims not listed in the order).
    """
    idx: List[int] = []
    seen = set()
    for d in order:
        di = DIM_IDX.get(d)
        if di is not None and di not in seen:
            idx.append(di)
            seen.add(di)
    mask = [True] * len(idx)
    for di in range(ND):
        if di not in seen:
            idx.append(di)
            mask.append(False)
    return tuple(idx), tuple(mask)


@dataclasses.dataclass
class FactorTable:
    """A batch of candidate schemes for one layer as flat integer arrays.

    All arrays share the trailing batch axis ``B``:

      t     [L, ND, B]  temporal blocking factor per level per dim
      s     [L, ND, B]  spatial unrolling factor per level per dim
      order [L, ND, B]  loop order as dim indices, outermost first
      omask [L, ND, B]  True where the order position is a real entry
      shr   [L, NT, B]  per-tensor sharing factor per level

    Tensor axis order is ``tensor_names`` (= iteration order of
    ``layer.tensors``).
    """

    layer: LayerSpec
    t: np.ndarray
    s: np.ndarray
    order: np.ndarray
    omask: np.ndarray
    shr: np.ndarray

    @property
    def n_levels(self) -> int:
        return self.t.shape[0]

    @property
    def batch(self) -> int:
        return self.t.shape[-1]

    @property
    def tensor_names(self) -> List[str]:
        return list(self.layer.tensors)

    # -- construction ---------------------------------------------------------
    @staticmethod
    def from_schemes(schemes: Sequence[LayerScheme]) -> "FactorTable":
        """Pack a list of ``LayerScheme`` (same layer shape, same level
        count) into one table via ``LayerScheme.factor_rows``."""
        if not schemes:
            raise ValueError("empty scheme batch")
        layer = schemes[0].layer
        tnames = list(layer.tensors)
        t_all, s_all, o_all, m_all, shr_all = [], [], [], [], []
        for sch in schemes:
            t_r, s_r, o_r, m_r, shr_r = sch.factor_rows(DIMS, tnames,
                                                        pack_order)
            t_all.append(t_r)
            s_all.append(s_r)
            o_all.append(o_r)
            m_all.append(m_r)
            shr_all.append(shr_r)
        # one bulk conversion [B, L, .] -> [L, ., B]
        return FactorTable(
            layer,
            t=np.asarray(t_all, dtype=np.int64).transpose(1, 2, 0),
            s=np.asarray(s_all, dtype=np.int64).transpose(1, 2, 0),
            order=np.asarray(o_all, dtype=np.int8).transpose(1, 2, 0),
            omask=np.asarray(m_all, dtype=bool).transpose(1, 2, 0),
            shr=np.asarray(shr_all, dtype=np.int64).transpose(1, 2, 0))

    def scheme_at(self, b: int) -> LayerScheme:
        """Materialize candidate ``b`` back into a ``LayerScheme``."""
        tnames = self.tensor_names
        levels = []
        for lv in range(self.n_levels):
            t = {DIMS[d]: int(self.t[lv, d, b]) for d in range(ND)
                 if self.t[lv, d, b] > 1}
            s = {DIMS[d]: int(self.s[lv, d, b]) for d in range(ND)
                 if self.s[lv, d, b] > 1}
            order = tuple(DIMS[int(self.order[lv, p, b])]
                          for p in range(ND) if self.omask[lv, p, b])
            shr = {tnames[ti]: int(self.shr[lv, ti, b])
                   for ti in range(len(tnames)) if self.shr[lv, ti, b] > 1}
            levels.append(LevelBlocking(t=t, s=s, order=order or
                                        LevelBlocking().order, shr=shr))
        return LayerScheme(self.layer, levels)


@dataclasses.dataclass
class BatchResult:
    """Vectorized ``CostBreakdown``: one entry per batch lane."""

    valid: np.ndarray              # bool
    energy_pj: np.ndarray          # inf on invalid lanes
    latency_cycles: np.ndarray     # inf on invalid lanes
    mac_energy: np.ndarray
    regf_energy: np.ndarray
    gbuf_energy: np.ndarray
    noc_energy: np.ndarray
    dram_energy: np.ndarray
    dram_traffic_bytes: np.ndarray
    gbuf_traffic_bytes: np.ndarray
    pes_used: np.ndarray
    nodes_used: np.ndarray

    def __len__(self) -> int:
        return len(self.valid)

    def breakdown(self, b: int) -> CostBreakdown:
        """Materialize lane ``b`` as a scalar ``CostBreakdown``."""
        if not self.valid[b]:
            return invalid("invalid candidate (batched)")
        return CostBreakdown(
            valid=True,
            energy_pj=float(self.energy_pj[b]),
            latency_cycles=float(self.latency_cycles[b]),
            mac_energy=float(self.mac_energy[b]),
            regf_energy=float(self.regf_energy[b]),
            gbuf_energy=float(self.gbuf_energy[b]),
            noc_energy=float(self.noc_energy[b]),
            dram_energy=float(self.dram_energy[b]),
            dram_traffic_bytes=float(self.dram_traffic_bytes[b]),
            gbuf_traffic_bytes=float(self.gbuf_traffic_bytes[b]),
            pes_used=int(self.pes_used[b]),
            nodes_used=int(self.nodes_used[b]))

    def best(self, objective: str = "energy") -> int:
        """Index of the first-best valid lane under ``objective``; -1 if no
        lane is valid."""
        if not self.valid.any():
            return -1
        score = self.energy_pj if objective == "energy" else \
            self.energy_pj * self.latency_cycles if objective == "edp" else \
            self.latency_cycles
        return int(np.argmin(score))

    def predicted_seconds(self, macs: float, hw: HWTemplate,
                          grid_steps=0, cal=None) -> np.ndarray:
        """Vectorized ``cost_model.predicted_seconds`` over all lanes:
        calibrated wall-clock predictions when a measured-runtime
        ``Calibration`` is installed (see ``repro.lower.calibrate``),
        otherwise raw cycles over the clock.  ``grid_steps`` may be a
        scalar or a per-lane array.  Invalid lanes stay inf."""
        from .cost_model import get_calibration
        cal = cal if cal is not None else get_calibration()
        if cal is None:
            return self.latency_cycles / hw.freq_hz
        thruput = np.maximum(1, self.pes_used * self.nodes_used)
        sec = (cal.a_compute * macs / thruput
               + cal.a_dram * self.dram_traffic_bytes
               / hw.levels[-1].bandwidth_bytes_per_cycle
               + cal.a_gbuf * self.gbuf_traffic_bytes
               / hw.levels[1].bandwidth_bytes_per_cycle
               + cal.a_step * np.asarray(grid_steps)
               + cal.intercept)
        return np.where(self.valid, sec, float("inf"))


def _nest_arrays(ft: FactorTable, level: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Concatenated temporal loop nest of all levels outer than ``level``,
    outermost position first: (factors [P, B], dim indices [P, B]).

    Positions whose dim is not part of the level's order contribute factor 1
    (exactly like the scalar ``_outer_nest`` which drops them)."""
    fs, ds = [], []
    for i in range(ft.n_levels - 1, level, -1):
        f = np.take_along_axis(ft.t[i], ft.order[i].astype(np.int64), axis=0)
        f = np.where(ft.omask[i], f, 1)
        fs.append(f)
        ds.append(ft.order[i])
    if not fs:
        B = ft.batch
        return (np.ones((0, B), dtype=np.int64),
                np.zeros((0, B), dtype=np.int8))
    return np.concatenate(fs, axis=0), np.concatenate(ds, axis=0)


def _rounds(nest_f: np.ndarray, nest_d: np.ndarray,
            relvec: np.ndarray) -> np.ndarray:
    """Vectorized ``_iters_to_innermost_relevant``: total nest iterations
    divided by the product of loops strictly inside the innermost loop over a
    relevant dim (factor-1 loops never count as relevant)."""
    if nest_f.shape[0] == 0:
        return np.ones(nest_f.shape[1], dtype=np.int64)
    rel_at = relvec[nest_d.astype(np.int64)] & (nest_f > 1)
    total = np.prod(nest_f, axis=0)
    # walking inner -> outer, keep multiplying while no relevant loop seen yet
    not_seen = np.logical_and.accumulate(~rel_at[::-1], axis=0)
    trailing = np.prod(np.where(not_seen, nest_f[::-1], 1), axis=0)
    return total // trailing


def evaluate_batch(ft: FactorTable, hw: HWTemplate,
                   nodes_assigned: Optional[int] = None,
                   src_onchip: bool = False,
                   dst_onchip: bool = False) -> BatchResult:
    """Vectorized mirror of ``cost_model.evaluate_layer`` over a batch.

    Requires a >= 3-level hierarchy (REGF / GBUF / DRAM shape), matching the
    boundary structure hard-coded in the scalar model.
    """
    layer = ft.layer
    n_levels = ft.n_levels
    if n_levels < 3:
        raise ValueError("evaluate_batch needs >= 3 memory levels")
    if len(hw.levels) != n_levels:
        raise ValueError("level count mismatch between table and hardware")
    B = layer.bytes_per_elem
    batch = ft.batch
    tnames = ft.tensor_names
    relmask = np.zeros((len(tnames), ND), dtype=bool)
    for ti, tn in enumerate(tnames):
        for d in layer.tensors[tn]:
            if d in DIM_IDX:
                relmask[ti, DIM_IDX[d]] = True

    ts = ft.t * ft.s                                  # [L, ND, B]
    cum = np.cumprod(ts, axis=0)                      # prod over levels <= l
    dims_total = np.array([layer.dim(d) for d in DIMS],
                          dtype=np.int64)[:, None]
    valid = np.all(cum[-1] == dims_total, axis=0)

    # per-level per-tensor tile sizes (own temporal in, own spatial out)
    ratio = cum / ft.s                                # float64 [L, ND, B]
    tile = np.empty((n_levels, len(tnames), batch))
    for ti, tn in enumerate(tnames):
        rel = relmask[ti]
        per_dim = np.where(rel[None, :, None], ratio, 1.0)
        tl = np.prod(per_dim, axis=1)                 # [L, B]
        tl = tl / np.maximum(1, ft.shr[:, ti, :])
        tl[0] *= layer.inner_unit(tn)
        tl[1:] *= layer.unit.get(tn, 1.0)
        tile[:, ti, :] = tl

    # ---- validity: capacity & parallelism ----------------------------------
    s_prod = np.prod(ft.s, axis=1)                    # [L, B]
    for i in range(n_levels - 1):
        fp = tile[i].sum(axis=0) * B
        valid &= fp <= hw.levels[i].capacity_bytes
        valid &= s_prod[i] <= hw.levels[i + 1].num_units
    nodes_used = s_prod[1]
    if nodes_assigned is not None:
        valid &= nodes_used <= nodes_assigned
    pes_used = s_prod[0]

    macs = layer.total_macs()
    zeros = np.zeros(batch)
    mac_e = np.empty(batch)
    regf_e = np.zeros(batch)
    gbuf_e = np.zeros(batch)
    noc_e = np.zeros(batch)
    dram_e = np.zeros(batch)

    # ---- MAC + REGF compute-operand energy ---------------------------------
    op_e = hw.mac_energy_pj if layer.has_weights else 0.2 * hw.mac_energy_pj
    mac_e[:] = macs * op_e
    e_regf = hw.levels[0].access_energy_pj_per_byte
    regf_e += macs * 3 * B * e_regf

    nest0_f, nest0_d = _nest_arrays(ft, 0)
    nest1_f, nest1_d = _nest_arrays(ft, 1)

    def fetches(ti: int, level: int) -> np.ndarray:
        nest_f, nest_d = (nest0_f, nest0_d) if level == 0 else \
            (nest1_f, nest1_d)
        rel = relmask[ti]
        shards = np.prod(np.where(rel[:, None], ft.s[level], 1), axis=0)
        rounds = _rounds(nest_f, nest_d, rel)
        base = tile[level, ti] * shards * rounds
        if tnames[ti] == "O" and layer.reduction_dims:
            rw_rel = rel.copy()
            for d in layer.reduction_dims:
                if d in DIM_IDX:
                    rw_rel[DIM_IDX[d]] = True
            rounds_rw = _rounds(nest_f, nest_d, rw_rel)
            base = np.where(rounds_rw > rounds,
                            tile[level, ti] * shards *
                            (2 * rounds_rw - rounds), base)
        return base

    def replication(ti: int, level: int) -> np.ndarray:
        rel = relmask[ti]
        return np.prod(np.where(rel[:, None], 1, ft.s[level]), axis=0)

    # ---- boundary REGF <- GBUF ---------------------------------------------
    e_gbuf = hw.levels[1].access_energy_pj_per_byte
    mc = hw.levels[1].multicast
    gbuf_fill = np.zeros(batch)
    for ti in range(len(tnames)):
        f = fetches(ti, 0)
        repl = replication(ti, 0)
        reads = f if mc else f * repl
        delivered = f * repl
        gbuf_fill += reads
        gbuf_e += reads * B * e_gbuf
        regf_e += delivered * B * e_regf
        shr = ft.shr[0, ti]
        regf_e += np.where(shr > 1, f * (shr - 1) * B * 2 * e_regf, zeros)
    gbuf_traffic = gbuf_fill * B

    # ---- boundary GBUF <- DRAM (or on-chip neighbor) ------------------------
    e_dram = hw.levels[-1].access_energy_pj_per_byte
    hops = hw.avg_noc_hops(nodes_used)
    e_hop = hw.noc_hop_energy_pj_per_byte
    dram_elems = np.zeros(batch)
    for ti, tn in enumerate(tnames):
        f = fetches(ti, 1)
        repl = replication(ti, 1)
        delivered = f * repl
        onchip = (tn == "I" and src_onchip) or (tn == "O" and dst_onchip)
        if onchip:
            gbuf_e += f * B * e_gbuf
            noc_e += delivered * B * e_hop * 2.0
        else:
            dram_elems += f
            dram_e += f * B * e_dram
            noc_e += delivered * B * e_hop * hops
        shr = ft.shr[1, ti]
        extra = shr > 1
        gbuf_e += np.where(extra, f * (shr - 1) * B * 2 * e_gbuf, zeros)
        noc_e += np.where(extra, f * (shr - 1) * B * e_hop, zeros)
    dram_traffic = dram_elems * B

    # ---- node-level spatial reduction (all-reduce of partial outputs) ------
    if "O" in layer.tensors and layer.reduction_dims:
        redvec = np.zeros(ND, dtype=bool)
        for d in layer.reduction_dims:
            if d in DIM_IDX:
                redvec[DIM_IDX[d]] = True
        red_repl = np.prod(np.where(redvec[:, None], ft.s[1], 1), axis=0)
        oi = tnames.index("O")
        psum = np.where(red_repl > 1,
                        fetches(oi, 1) * (red_repl - 1), zeros)
        gbuf_e += psum * B * 2 * e_gbuf
        noc_e += psum * B * e_hop

    energy = mac_e + regf_e + gbuf_e + noc_e + dram_e

    # ---- latency: roofline over compute and each bandwidth ------------------
    mac_thruput = np.maximum(1, pes_used * nodes_used)
    cyc_compute = macs / mac_thruput
    cyc_dram = dram_traffic / hw.levels[-1].bandwidth_bytes_per_cycle
    cyc_gbuf = gbuf_traffic / hw.levels[1].bandwidth_bytes_per_cycle
    cyc_regf = (macs / mac_thruput) * B / \
        hw.levels[0].bandwidth_bytes_per_cycle
    latency = np.maximum.reduce([cyc_compute, cyc_dram, cyc_gbuf, cyc_regf])

    inf = float("inf")
    return BatchResult(
        valid=valid,
        energy_pj=np.where(valid, energy, inf),
        latency_cycles=np.where(valid, latency, inf),
        mac_energy=mac_e, regf_energy=regf_e, gbuf_energy=gbuf_e,
        noc_energy=noc_e, dram_energy=dram_e,
        dram_traffic_bytes=dram_traffic, gbuf_traffic_bytes=gbuf_traffic,
        pes_used=pes_used, nodes_used=nodes_used)


def score_schemes(schemes: Sequence[LayerScheme], hw: HWTemplate,
                  nodes_assigned: Optional[int] = None,
                  src_onchip: bool = False,
                  dst_onchip: bool = False) -> BatchResult:
    """Pack + evaluate a list of schemes in one shot."""
    return evaluate_batch(FactorTable.from_schemes(schemes), hw,
                          nodes_assigned=nodes_assigned,
                          src_onchip=src_onchip, dst_onchip=dst_onchip)
