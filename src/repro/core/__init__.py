from . import autoshard, cost_model, directives, estimate, solver

__all__ = ["autoshard", "cost_model", "directives", "estimate", "solver"]
