"""KAPLA's fast, optimistic cost estimation (§IV-B).

These estimators deliberately ignore lower-level details and "approximate to
the optimistic cases if there is insufficient information", producing
(relatively tight) lower bounds used only to *prioritize* candidates — the
detailed model in ``cost_model.py`` is the judge.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..hw.template import HWTemplate
from ..workloads.layers import LayerSpec


@dataclasses.dataclass(frozen=True)
class LayerEstimate:
    valid: bool
    energy_lb_pj: float = float("inf")
    latency_lb_cycles: float = float("inf")
    dram_bytes_lb: float = float("inf")
    reason: str = ""


def min_buffer_requirement_bytes(layer: LayerSpec, granule_frac: float,
                                 src_onchip: bool, dst_onchip: bool) -> float:
    """Conservative minimum aggregated on-chip bytes for a pipelined layer.

    Only the forwarded fmap granules must be resident (double-buffered);
    weights may stream from DRAM.  Never overestimates => never rejects a
    valid inter-layer scheme (conservative pruning).
    """
    B = layer.bytes_per_elem
    req = 0.0
    if src_onchip:
        req += 2.0 * layer.ifmap_size() * granule_frac * B
    if dst_onchip:
        req += 2.0 * layer.ofmap_size() * granule_frac * B
    return req


def estimate_layer(layer: LayerSpec, hw: HWTemplate, nodes_assigned: int,
                   granule_frac: float = 1.0,
                   src_onchip: bool = False,
                   dst_onchip: bool = False) -> LayerEstimate:
    """Optimistic per-layer bound given only the inter-layer decisions."""
    B = layer.bytes_per_elem
    agg_gbuf = nodes_assigned * hw.gbuf.capacity_bytes
    need = min_buffer_requirement_bytes(layer, granule_frac, src_onchip,
                                        dst_onchip)
    if need > agg_gbuf:
        return LayerEstimate(False, reason=f"needs {need:.0f}B > "
                                           f"{agg_gbuf:.0f}B aggregated GBUF")

    macs = layer.total_macs()
    # DRAM lower bound: every non-forwarded tensor moves exactly once.
    dram_elems = 0.0
    gbuf_elems = 0.0
    for t in layer.tensors:
        sz = layer.tensor_size(t)
        gbuf_elems += sz
        if t == "I" and src_onchip:
            continue
        if t == "O" and dst_onchip:
            continue
        dram_elems += sz
    dram_bytes = dram_elems * B

    e = 0.0
    op_e = hw.mac_energy_pj if layer.has_weights else 0.2 * hw.mac_energy_pj
    e += macs * op_e
    e += macs * 3 * B * hw.levels[0].access_energy_pj_per_byte
    e += gbuf_elems * B * hw.levels[1].access_energy_pj_per_byte
    e += dram_bytes * hw.levels[-1].access_energy_pj_per_byte

    # optimistic utilization: all PEs of all assigned nodes are busy
    pes = nodes_assigned * hw.num_pes_per_node
    lat = max(macs / max(1, pes),
              dram_bytes / hw.levels[-1].bandwidth_bytes_per_cycle /
              max(1, hw.dram_ports))
    return LayerEstimate(True, energy_lb_pj=e, latency_lb_cycles=lat,
                         dram_bytes_lb=dram_bytes)
