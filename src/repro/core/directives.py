"""Tensor-centric dataflow directives (KAPLA §III-B).

Three directives describe a scheme, inside-out along the memory hierarchy:

  tensor(dim=size, ..., shr)   -- a (sub)tensor allocated in a buffer
  stack(dim+=shift, ..., repl) -- spatial replication/sharding across buffers
  update(dim+=step, ...)       -- ordered temporal iteration in a buffer

The pragmatic payoff is that buffer footprints, spatial parallelism and
inter-level access counts are all direct functions of the directives — no
recursive nested-loop analysis.  The solver works on a compact equivalent
(`LevelBlocking`: per-level temporal factors + order, spatial factors, and
per-tensor sharing factors) that compiles to directives via
``LayerScheme.to_directives()``.

Approximations (documented; trends preserved, as in analytical models like
nn-dataflow/Interstellar):
  * halo of sliding-window inputs folded into a per-tensor ``unit`` multiplier;
  * filter dims R,S pinned at the PE/unit level;
  * a tensor tile is refetched whenever any loop relevant to it, at any outer
    position, advances (single-resident-tile model);
  * partial sums: output traffic doubles for revisits driven by reduction
    loops placed outside the output's residency level.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import math
from typing import Dict, FrozenSet, List, Mapping, Optional, Sequence, Tuple

from ..workloads.layers import DIMS, LayerSpec

# ---------------------------------------------------------------------------
# Formal directive objects (representation layer)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TensorDecl:
    name: str
    dims: Mapping[str, float]      # dim -> size at this level (may be halo'd)
    shr: int = 1

    def size(self) -> float:
        sz = 1.0
        for v in self.dims.values():
            sz *= v
        return sz / self.shr

    def __str__(self) -> str:
        body = ", ".join(f"{d}={int(math.ceil(v))}" for d, v in self.dims.items())
        if self.shr > 1:
            body += f", shr={self.shr}"
        return f"tensor{{{self.name}}}({body})"


@dataclasses.dataclass(frozen=True)
class Stack:
    shifts: Mapping[str, int]      # dim -> shift (empty = pure replication)
    repl: int

    def __str__(self) -> str:
        parts = [f"{d}+={s}" for d, s in self.shifts.items()]
        parts.append(str(self.repl))
        return f"stack({', '.join(parts)})"


@dataclasses.dataclass(frozen=True)
class Update:
    steps: Mapping[str, int]

    def __str__(self) -> str:
        return f"update({', '.join(f'{d}+={s}' for d, s in self.steps.items())})"


@dataclasses.dataclass(frozen=True)
class LevelDirectives:
    level_name: str
    tensors: Tuple[TensorDecl, ...]
    stacks: Tuple[Stack, ...]
    updates: Tuple[Update, ...]    # outer iteration order: listed inner->outer

    def __str__(self) -> str:
        lines = [f"{self.level_name}:"]
        lines += [f"  {t}" for t in self.tensors]
        lines += [f"  {s}" for s in self.stacks]
        lines += [f"  {u}" for u in self.updates]
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Solver-side compact scheme
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LevelBlocking:
    """Blocking of one memory level.

    t:     temporal blocking factor per dim at this level's buffer.
    s:     spatial unrolling factor per dim across this level's unit array
           (PE array for level 0, node array for level 1, ...).
    order: temporal loop order at this level, outer -> inner (dims with
           t[d] > 1 participate; others are ignored).
    shr:   per-tensor sharing factor (buffer sharing / systolic) — each of the
           ``shr`` sibling buffers holds 1/shr of the tensor's tile.
    """

    t: Dict[str, int] = dataclasses.field(default_factory=dict)
    s: Dict[str, int] = dataclasses.field(default_factory=dict)
    order: Tuple[str, ...] = ("N", "X", "Y", "K", "C")
    shr: Dict[str, int] = dataclasses.field(default_factory=dict)

    def tf(self, d: str) -> int:
        return int(self.t.get(d, 1))

    def sf(self, d: str) -> int:
        return int(self.s.get(d, 1))

    def t_product(self) -> int:
        p = 1
        for v in self.t.values():
            p *= int(v)
        return p

    def s_product(self) -> int:
        p = 1
        for v in self.s.values():
            p *= int(v)
        return p

    def copy(self) -> "LevelBlocking":
        return LevelBlocking(dict(self.t), dict(self.s), tuple(self.order),
                             dict(self.shr))

    def to_json_dict(self) -> Dict:
        return {"t": dict(self.t), "s": dict(self.s),
                "order": list(self.order), "shr": dict(self.shr)}

    @staticmethod
    def from_json_dict(d: Mapping) -> "LevelBlocking":
        return LevelBlocking(
            t={k: int(v) for k, v in d.get("t", {}).items()},
            s={k: int(v) for k, v in d.get("s", {}).items()},
            order=tuple(d.get("order", LevelBlocking().order)),
            shr={k: int(v) for k, v in d.get("shr", {}).items()})


@dataclasses.dataclass
class LayerScheme:
    """A complete intra-layer scheme: one LevelBlocking per memory level,
    inner -> outer.  The outermost level's t factors are implied leftovers
    (kept explicit for clarity and checked by `validate_factors`)."""

    layer: LayerSpec
    levels: List[LevelBlocking]

    # -- factor bookkeeping ---------------------------------------------------
    def cum_factor(self, d: str, upto: int, include_own_t: bool = True) -> int:
        """Product of t and s factors of dim ``d`` for levels <= upto."""
        p = 1
        for i, lv in enumerate(self.levels[: upto + 1]):
            if i < upto or include_own_t:
                p *= lv.tf(d)
            if i <= upto:
                p *= lv.sf(d)
        return p

    def allocated(self, d: str) -> int:
        p = 1
        for lv in self.levels:
            p *= lv.tf(d) * lv.sf(d)
        return p

    def validate_factors(self) -> bool:
        return all(self.allocated(d) == self.layer.dim(d) for d in DIMS)

    # -- footprints -----------------------------------------------------------
    def tile_elems(self, tname: str, level: int) -> float:
        """Per-buffer element count of tensor ``tname`` at ``level``
        (includes this level's temporal factors, excludes its spatial ones,
        divided by the sharing factor)."""
        rel = self.layer.tensors[tname]
        sz = self.layer.inner_unit(tname) if level == 0 \
            else self.layer.unit.get(tname, 1.0)
        for d in rel:
            sz *= self.cum_factor(d, level, include_own_t=True)
            # own-level spatial factors shard across sibling buffers:
            sz /= self.levels[level].sf(d) if d in rel else 1
        sz /= max(1, self.levels[level].shr.get(tname, 1))
        return sz

    def level_footprint_bytes(self, level: int) -> float:
        return sum(self.tile_elems(t, level) for t in self.layer.tensors) \
            * self.layer.bytes_per_elem

    def parallelism(self, level: int) -> int:
        return self.levels[level].s_product()

    # -- access counting ------------------------------------------------------
    def _outer_nest(self, level: int) -> List[Tuple[str, int]]:
        """Concatenated temporal loops of all levels outer than ``level``,
        ordered outermost first."""
        nest: List[Tuple[str, int]] = []
        for i in range(len(self.levels) - 1, level, -1):
            lv = self.levels[i]
            for d in lv.order:
                if lv.tf(d) > 1:
                    nest.append((d, lv.tf(d)))
        return nest

    @staticmethod
    def _iters_to_innermost_relevant(nest: Sequence[Tuple[str, int]],
                                     rel: FrozenSet[str]) -> int:
        """Product of loop factors from the outermost loop down to (and
        including) the innermost loop whose dim is in ``rel``."""
        total = 1
        for _, f in nest:
            total *= f
        trailing = 1
        for d, f in reversed(nest):
            if d in rel:
                break
            trailing *= f
        return total // trailing

    def fetches_into(self, tname: str, level: int) -> float:
        """Elements moved from level+1 into the level-``level`` buffers under
        ONE level-(level+1) buffer, counting multicast replicas once.

        For the output tensor, reduction loops outside this level force
        partial-sum read+write revisits (2x traffic on revisits)."""
        layer = self.layer
        rel = layer.tensors[tname]
        nest = self._outer_nest(level)
        tile = self.tile_elems(tname, level)
        shards = 1
        for d in rel:
            shards *= self.levels[level].sf(d)
        rounds = self._iters_to_innermost_relevant(nest, rel)
        base = tile * shards * rounds
        if tname == "O" and layer.reduction_dims:
            rw_rel = rel | layer.reduction_dims
            rounds_rw = self._iters_to_innermost_relevant(nest, rw_rel)
            if rounds_rw > rounds:
                # each extra revisit reads + writes the partial-sum tile
                base = tile * shards * (2 * rounds_rw - rounds)
        return base

    def replication(self, tname: str, level: int) -> int:
        """How many copies of each element live across this level's array."""
        rel = self.layer.tensors[tname]
        r = 1
        for d, f in self.levels[level].s.items():
            if d not in rel:
                r *= f
        return r

    # -- compilation to formal directives -------------------------------------
    def to_directives(self, level_names: Sequence[str]) -> List[LevelDirectives]:
        out: List[LevelDirectives] = []
        for i, lv in enumerate(self.levels):
            tds = []
            for tname, rel in self.layer.tensors.items():
                dims = {}
                for d in sorted(rel):
                    dims[d] = (self.cum_factor(d, i) / lv.sf(d)) \
                        * self.layer.unit.get(tname, 1.0) ** (1 / max(1, len(rel)))
                tds.append(TensorDecl(tname, dims, shr=lv.shr.get(tname, 1)))
            stacks = []
            for d, f in lv.s.items():
                if f > 1:
                    shift = self.cum_factor(d, i) // lv.sf(d)
                    stacks.append(Stack({d: shift}, f))
            updates = []
            for d in reversed(lv.order):     # inner -> outer
                if lv.tf(d) > 1:
                    step = self.cum_factor(d, i - 1) if i > 0 else 1
                    updates.append(Update({d: step}))
            out.append(LevelDirectives(level_names[i], tuple(tds),
                                       tuple(stacks), tuple(updates)))
        return out

    # -- factor-table export --------------------------------------------------
    def factor_rows(self, dims: Sequence[str], tensor_names: Sequence[str],
                    order_packer) -> Tuple[List[List[int]], List[List[int]],
                                           List[List[int]], List[List[bool]],
                                           List[List[int]]]:
        """Flatten this scheme into per-level integer rows for batched
        scoring: (t, s, order indices, order mask, shr) — one row per level.
        ``order_packer`` maps a loop-order tuple to (dim indices, mask) of
        length ``len(dims)`` (see ``cost_batch.pack_order``)."""
        t_rows, s_rows, o_rows, m_rows, shr_rows = [], [], [], [], []
        for lv in self.levels:
            t_rows.append([lv.tf(d) for d in dims])
            s_rows.append([lv.sf(d) for d in dims])
            idx, mask = order_packer(lv.order)
            o_rows.append(list(idx))
            m_rows.append(list(mask))
            shr_rows.append([int(lv.shr.get(t, 1)) for t in tensor_names])
        return t_rows, s_rows, o_rows, m_rows, shr_rows

    # -- JSON (de)serialization ----------------------------------------------
    def to_json(self) -> Dict:
        """Stable serializable form: the layer spec plus one blocking dict
        per level (inner -> outer).  Round-trips via ``from_json`` with
        bit-identical cost-model scores (see tests/test_lowering.py)."""
        return {"layer": self.layer.to_json_dict(),
                "levels": [lv.to_json_dict() for lv in self.levels]}

    @staticmethod
    def from_json(d: Mapping, layer: Optional[LayerSpec] = None
                  ) -> "LayerScheme":
        """Rebuild a scheme; pass ``layer`` to re-bind to an existing graph's
        spec instead of reconstructing one from the embedded JSON."""
        lay = layer if layer is not None \
            else LayerSpec.from_json_dict(d["layer"])
        return LayerScheme(lay, [LevelBlocking.from_json_dict(lv)
                                 for lv in d["levels"]])

    def top_level_granularity(self) -> Dict[str, int]:
        """Tile sizes of the output tensor at the outermost on-chip level —
        used to check inter-layer forwarding compatibility (matched tensor
        sizes + matched update steps)."""
        top = len(self.levels) - 2           # outermost on-chip level
        rel = self.layer.tensors["O"]
        return {d: self.cum_factor(d, top) for d in sorted(rel)}

    def forward_bytes(self, granule_frac: float = 1.0) -> float:
        """Bytes of the output-fmap granule a pipelined consumer receives
        on-chip (§III-A fine-grained forwarding): the per-segment footprint
        accounting hook the network lowering tier validates against the
        segment's node-region alloc share.  Callers apply their own
        double-buffering factor (cf. ``estimate.min_buffer_requirement_bytes``)."""
        return self.layer.ofmap_size() * granule_frac \
            * self.layer.bytes_per_elem


# ---------------------------------------------------------------------------
# small utilities shared by solvers
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _divisors_cached(n: int) -> Tuple[int, ...]:
    out = []
    i = 1
    while i * i <= n:
        if n % i == 0:
            out.append(i)
            if i != n // i:
                out.append(n // i)
        i += 1
    return tuple(sorted(out))


def divisors(n: int) -> List[int]:
    """Sorted divisors of ``n`` (memoized; a fresh list is returned so
    callers may mutate it)."""
    return list(_divisors_cached(n))


@functools.lru_cache(maxsize=None)
def smallest_prime_factor(n: int) -> int:
    if n <= 1:
        return 1
    i = 2
    while i * i <= n:
        if n % i == 0:
            return i
        i += 1
    return n


@functools.lru_cache(maxsize=1)
def _canonical_orders_cached() -> Tuple[Tuple[str, ...], ...]:
    orders = []
    for perm in itertools.permutations(("C", "K", "N")):
        order: List[str] = []
        for p in perm:
            if p == "N":
                order.extend(("N", "X", "Y"))
            else:
                order.append(p)
        orders.append(tuple(order))
    return tuple(orders)


def canonical_orders() -> List[Tuple[str, ...]]:
    """Loop orders that matter: permutations of which tensor class is
    outermost; X, Y travel with N (fmap dims)."""
    return list(_canonical_orders_cached())
