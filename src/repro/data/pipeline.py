"""Deterministic synthetic token pipeline, host-sharded, double-buffered.

At pod scale every host feeds only its local devices; the pipeline is
keyed on (seed, step, host_index) so restarts and elastic re-shards
reproduce the exact global batch without coordination.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    vocab_limit: Optional[int] = None     # sample below this id
    host_index: int = 0
    host_count: int = 1


def _host_slice(global_batch: int, dc: DataConfig):
    per = global_batch // dc.host_count
    lo = per * dc.host_index
    return lo, per


def synth_batch(cfg: ModelConfig, shape: ShapeConfig, step: int,
                dc: DataConfig = DataConfig()) -> Dict[str, np.ndarray]:
    """The global batch for ``step``, restricted to this host's rows."""
    lo, per = _host_slice(shape.global_batch, dc)
    vocab = dc.vocab_limit or min(cfg.vocab_size, 32000)
    rows = []
    tgts = []
    for r in range(lo, lo + per):
        rng = np.random.default_rng(
            np.random.SeedSequence([dc.seed, step, r]))
        seq = rng.integers(1, vocab, size=shape.seq_len + 1, dtype=np.int32)
        rows.append(seq[:-1])
        tgts.append(seq[1:])
    tokens = np.stack(rows)
    targets = np.stack(tgts)
    if cfg.frontend == "embed":
        # modality stub: precomputed frame/patch embeddings
        rng = np.random.default_rng(
            np.random.SeedSequence([dc.seed, step, 10 ** 6 + lo]))
        inputs = rng.standard_normal(
            (per, shape.seq_len, cfg.d_model)).astype(np.float32) * 0.02
        return {"inputs": inputs, "targets": targets}
    return {"inputs": tokens, "targets": targets}


class Prefetcher:
    """Double-buffered background prefetch (overlap host data generation
    with device compute)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 dc: DataConfig = DataConfig(), start_step: int = 0,
                 depth: int = 2):
        self.cfg, self.shape, self.dc = cfg, shape, dc
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            batch = synth_batch(self.cfg, self.shape, self._step, self.dc)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self.q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
