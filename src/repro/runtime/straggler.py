"""Straggler mitigation.

SPMD steps are gang-scheduled: one slow host stalls the whole pod.  Two
mitigations, both host-side (no device code changes):

* ``StragglerDetector`` — EWMA of step latencies with an outlier threshold;
  flags hosts whose recent steps exceed ``factor`` x the fleet median so the
  controller can drain/replace them before they become failures.
* ``BackupDispatcher`` — duplicate-dispatch of *input pipeline* work (the
  common non-SPMD straggler source): issue each host's batch generation to
  a backup worker after a deadline, take whichever finishes first
  (deterministic: both produce identical bytes by construction).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import Callable, Dict, List, Optional

from ..obs import metrics

_m_task_seconds = metrics.histogram(
    "straggler_task_seconds",
    "per-host task latencies fed to the straggler detector", ("host",))


@dataclasses.dataclass
class StragglerDetector:
    factor: float = 1.8
    alpha: float = 0.2                  # EWMA smoothing
    warmup: int = 5

    def __post_init__(self):
        self._ewma: Dict[str, float] = {}
        self._count: Dict[str, int] = {}

    def record(self, host: str, seconds: float) -> None:
        _m_task_seconds.observe(seconds, host=host)
        prev = self._ewma.get(host)
        self._ewma[host] = seconds if prev is None else \
            (1 - self.alpha) * prev + self.alpha * seconds
        self._count[host] = self._count.get(host, 0) + 1

    def fleet_median(self) -> Optional[float]:
        vals = [v for h, v in self._ewma.items()
                if self._count.get(h, 0) >= self.warmup]
        return statistics.median(vals) if vals else None

    def stragglers(self) -> List[str]:
        med = self.fleet_median()
        if med is None or med <= 0:
            return []
        return [h for h, v in self._ewma.items()
                if self._count.get(h, 0) >= self.warmup
                and v > self.factor * med]

    def forget(self, host: str) -> None:
        """Drop a drained/replaced host's history so its (typically
        inflated) EWMA stops poisoning the fleet median."""
        self._ewma.pop(host, None)
        self._count.pop(host, None)

    def stats(self) -> Dict:
        return {"hosts": dict(self._ewma),
                "counts": dict(self._count),
                "fleet_median": self.fleet_median(),
                "stragglers": self.stragglers()}


class BackupDispatcher:
    """Speculative duplicate execution with a deadline.

    A context manager (the pool is real OS threads; relying on GC to
    reap it leaks workers): ``with BackupDispatcher(0.5) as bd: ...``.
    ``run`` races primary against a deadline-launched backup, returns the
    first *successful* result, and cancels the loser (a not-yet-started
    loser is dropped; a running one finishes but its result is ignored).
    A worker that raises is not a winner — the race falls through to the
    other worker, and only when both raise does ``run`` re-raise the
    primary's error.
    """

    def __init__(self, deadline_seconds: float, workers: int = 2):
        self.deadline = deadline_seconds
        self.pool = ThreadPoolExecutor(max_workers=workers)
        self.cancelled_losers = 0
        self.failovers = 0

    def __enter__(self) -> "BackupDispatcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _finish(self, winner, loser) -> object:
        if loser is not None and loser.cancel():
            self.cancelled_losers += 1
        return winner.result()

    def run(self, primary: Callable[[], object],
            backup: Callable[[], object]) -> object:
        f1 = self.pool.submit(primary)
        done, _ = wait([f1], timeout=self.deadline,
                       return_when=FIRST_COMPLETED)
        if done and f1.exception() is None:
            return f1.result()
        if done:                        # primary raised before the deadline
            self.failovers += 1
            f2 = self.pool.submit(backup)
            return self._finish(f2, None)
        f2 = self.pool.submit(backup)
        pending = {f1, f2}
        while pending:
            done, pending = wait(pending, return_when=FIRST_COMPLETED)
            winners = [f for f in done if f.exception() is None]
            if winners:
                if not pending and len(winners) == len(done) == 2:
                    # both finished between waits: keep the primary
                    return self._finish(f1, f2)
                loser = pending.pop() if pending else None
                if winners[0] is f2:
                    self.failovers += 1
                return self._finish(winners[0], loser)
            # everything done so far raised; fall through to the rest
        # both raised: surface the primary's error
        return f1.result()

    def close(self):
        self.pool.shutdown(wait=False, cancel_futures=True)
