"""Fault tolerance for pod-scale runs.

At thousands of nodes, failures are routine.  The framework's contract:

1. every step is restartable from the last atomic checkpoint
   (checkpoint/ckpt.py);
2. a failure raises through ``run_with_recovery`` which restores and
   retries with bounded backoff;
3. on *permanent* capacity loss, ``ElasticPlanner`` re-solves the mesh for
   the surviving device count and the autoshard planner produces fresh
   shardings — checkpoints are mesh-agnostic (host npz + respec on load).

This container has one real device, so the multi-host behaviours are
exercised with simulated failure injectors in tests/test_runtime.py.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Callable, List, Optional, Sequence, Tuple


class NodeFailure(RuntimeError):
    """Raised by the step runner when a device/host is lost."""

    def __init__(self, msg: str, lost_devices: int = 1,
                 permanent: bool = False):
        super().__init__(msg)
        self.lost_devices = lost_devices
        self.permanent = permanent


@dataclasses.dataclass
class RecoveryPolicy:
    max_retries: int = 5
    backoff_seconds: float = 1.0
    backoff_factor: float = 2.0
    max_backoff: float = 60.0


@dataclasses.dataclass
class RecoveryStats:
    restarts: int = 0
    last_error: Optional[str] = None
    reshards: int = 0


def run_with_recovery(step_fn: Callable[[int], None], start_step: int,
                      num_steps: int,
                      restore_fn: Callable[[], int],
                      policy: Optional[RecoveryPolicy] = None,
                      on_permanent_loss: Optional[Callable[[int], None]]
                      = None,
                      sleep=time.sleep) -> RecoveryStats:
    """Drive ``step_fn(step)`` for ``num_steps``, restoring via
    ``restore_fn() -> resume_step`` after transient failures."""
    # default constructed per call: a shared module-level instance would
    # leak one caller's tweaks into every later call
    policy = policy if policy is not None else RecoveryPolicy()
    stats = RecoveryStats()
    step = start_step
    retries = 0
    backoff = policy.backoff_seconds
    while step < start_step + num_steps:
        try:
            step_fn(step)
            step += 1
            retries = 0
            backoff = policy.backoff_seconds
        except NodeFailure as e:
            stats.last_error = str(e)
            if e.permanent and on_permanent_loss is not None:
                on_permanent_loss(e.lost_devices)
                stats.reshards += 1
            retries += 1
            if retries > policy.max_retries:
                raise
            sleep(min(backoff, policy.max_backoff))
            backoff *= policy.backoff_factor
            step = restore_fn()
            stats.restarts += 1
    return stats


@dataclasses.dataclass
class ElasticPlanner:
    """Choose a new (pods, data, model) mesh after capacity change.

    Keeps the model axis intact (tensor-parallel groups must be complete;
    losing one chip of a TP group kills the group) and shrinks the data
    axis — the same conservative validity logic the KAPLA inter-layer
    pruner uses: never produce a mesh the model cannot run on.
    """

    model_axis: int = 16
    min_data: int = 1

    def plan(self, surviving_chips: int) -> Tuple[int, int]:
        """-> (data_axis, model_axis); raises if nothing valid remains."""
        groups = surviving_chips // self.model_axis
        if groups < self.min_data:
            raise NodeFailure(
                f"only {surviving_chips} chips left; cannot form a "
                f"model-parallel group of {self.model_axis}",
                permanent=True)
        # largest power-of-two data axis <= surviving groups keeps global
        # batch divisibility and collective trees balanced
        data = 2 ** int(math.log2(groups))
        return data, self.model_axis

    def plan_nodes(self, surviving_nodes: int) -> int:
        """Node-mesh variant of ``plan``: segment-chain parts tolerate
        any node count (no collective trees to balance), so every
        survivor stays in service — but below ``min_data`` nodes the
        mesh cannot serve at all and the caller must fall back."""
        if surviving_nodes < self.min_data:
            raise NodeFailure(
                f"only {surviving_nodes} node(s) left; mesh needs at "
                f"least {self.min_data}", permanent=True)
        return surviving_nodes

    def batch_for(self, global_batch: int, data_axis: int,
                  old_data_axis: int) -> int:
        """Rescale the global batch proportionally (keeps per-replica
        microbatch — and therefore convergence behaviour — unchanged)."""
        per_replica = global_batch // old_data_axis
        return per_replica * data_axis


class CircuitBreaker:
    """Consecutive-failure circuit breaker (closed -> open -> half-open).

    ``threshold`` consecutive failures open the circuit; while open,
    ``allow()`` is False so callers skip the protected dependency (the
    schedule service degrades to solve-without-caching when the store
    trips it).  After ``cooldown_s`` one probe call is allowed
    (half-open); its success closes the circuit, its failure re-opens.
    Thread-safe — the server touches it from executor threads.
    """

    def __init__(self, threshold: int = 3, cooldown_s: float = 5.0,
                 clock=time.monotonic):
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self.clock = clock
        self._failures = 0
        self._opened_at: Optional[float] = None
        self._probing = False
        self._lock = threading.Lock()
        self.opens = 0

    @property
    def state(self) -> str:
        with self._lock:
            if self._opened_at is None:
                return "closed"
            if self.clock() - self._opened_at >= self.cooldown_s:
                return "half-open"
            return "open"

    def allow(self) -> bool:
        with self._lock:
            if self._opened_at is None:
                return True
            if self.clock() - self._opened_at < self.cooldown_s:
                return False
            if self._probing:                   # one probe at a time
                return False
            self._probing = True
            return True

    def record_success(self) -> None:
        with self._lock:
            self._failures = 0
            self._opened_at = None
            self._probing = False

    def record_failure(self) -> None:
        with self._lock:
            self._failures += 1
            self._probing = False
            if self._failures >= self.threshold:
                if self._opened_at is None:
                    self.opens += 1
                self._opened_at = self.clock()

    def stats(self) -> dict:
        return {"state": self.state, "opens": self.opens,
                "consecutive_failures": self._failures}


class StepHeartbeat:
    """Deadline monitor: a step that exceeds ``deadline_seconds`` is
    declared failed (hung collective / dead host) so recovery kicks in."""

    def __init__(self, deadline_seconds: float, clock=time.monotonic):
        self.deadline = deadline_seconds
        self.clock = clock
        self._armed_at: Optional[float] = None

    def arm(self):
        self._armed_at = self.clock()

    def check(self):
        if self._armed_at is None:
            return
        dt = self.clock() - self._armed_at
        if dt > self.deadline:
            raise NodeFailure(
                f"step heartbeat expired after {dt:.1f}s "
                f"(deadline {self.deadline}s)", permanent=False)

    def disarm(self):
        self._armed_at = None
