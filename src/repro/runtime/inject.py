"""Deterministic, seeded fault injection across the schedule service.

The chaos harness for the solve -> store -> autotune path: a
``FaultPlan`` names per-site fault specs (rate, kind, delay) and a seed;
a ``FaultInjector`` turns the plan into a *replayable* fault schedule.
Decisions are keyed, not sequenced: whether occurrence ``n`` of
``(site, key)`` faults depends only on ``(seed, site, key, n)``, so the
same plan produces the same schedule regardless of thread interleaving
(the solver's segment pool and the server's executor hops reorder calls
freely between runs).

Sites instrumented in the production code:

    store.read        ScheduleStore record reads  (kinds: error, corrupt)
    store.write       ScheduleStore.put           (kinds: error, corrupt)
    store.index       index.jsonl appends         (kinds: error, corrupt)
    solve.segment     kapla.solve_segment         (kinds: error, slow)
    autotune.measure  autotune candidate runs     (kinds: error, slow, nan)
    node.crash        meshexec worker nodes       (kinds: error -> the node
                      dies permanently, NodeFailure)
    node.hang         meshexec worker nodes       (kinds: slow -> the task
                      blocks ``delay_s``, tripping the hang deadline)
    node.slow         meshexec worker nodes       (kinds: slow -> the task
                      stretches to ``factor`` x its real runtime)

Node-site keys are ``"node<id>"``, so ``FaultSpec.match`` pins a fault
to one node and ``FaultSpec.after`` fires it only from occurrence
``after`` on — together they script "kill node 1 on its 3rd task"
deterministically.

``corrupt`` on reads truncates the on-disk record *before* the read, so
the store's real checksum/quarantine machinery is exercised, not mocked;
``corrupt`` on writes leaves a torn record/index tail, simulating a
writer killed mid-``put``.  ``error`` raises ``InjectedFault`` (transient
by construction: a retry draws fresh randomness).  ``slow`` sleeps
``delay_s`` at the site.  ``nan`` asks the call site to poison its
measurement.

Activation is a process-global context manager (``inject``), so worker
threads spawned inside the scope see the injector; call sites pay one
global read + ``None`` check when no injector is active.
"""
from __future__ import annotations

import dataclasses
import os
import random
import threading
import time
from contextlib import contextmanager
from typing import Dict, List, Mapping, Optional, Tuple

from ..obs import metrics, trace

_m_faults = metrics.counter("faults_injected_total",
                            "chaos faults fired, by site", ("site",))

#: sites the production code instruments (``FaultPlan`` rejects others)
SITES = ("store.read", "store.write", "store.index",
         "solve.segment", "autotune.measure",
         "node.crash", "node.hang", "node.slow")

KINDS = ("error", "corrupt", "slow", "nan")


class InjectedFault(RuntimeError):
    """A fault produced by the injection harness.  Transient by
    construction — retrying the operation draws fresh randomness."""

    def __init__(self, site: str, key: str = "", occurrence: int = 0):
        super().__init__(f"injected fault at {site} "
                         f"(key={key!r}, occurrence={occurrence})")
        self.site = site
        self.key = key
        self.occurrence = occurrence


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One site's fault behaviour: ``rate`` is the per-occurrence fault
    probability; ``delay_s`` is the sleep for ``slow`` faults.

    Scripting filters (both deterministic, for chaos scenarios that
    target a specific victim at a specific point):

    * ``match``  — fault only keys starting with this prefix (e.g.
      ``"node1"``); non-matching keys still advance their occurrence
      counters, so the schedule for other keys is unchanged;
    * ``after``  — fault only from occurrence ``after`` on (0-based:
      ``after=2`` spares the first two occurrences);
    * ``factor`` — multiplicative slowdown for sites that implement
      proportional ``slow`` faults (``node.slow`` stretches a task to
      ``factor`` x its measured runtime; 0 means site default).
    """

    rate: float
    kind: str = "error"
    delay_s: float = 0.0
    after: int = 0
    match: str = ""
    factor: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate {self.rate} outside [0, 1]")
        if self.after < 0:
            raise ValueError(f"after {self.after} must be >= 0")
        if self.factor < 0:
            raise ValueError(f"factor {self.factor} must be >= 0")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded schedule of per-site faults (``{site: FaultSpec}``)."""

    seed: int = 0
    specs: Tuple[Tuple[str, FaultSpec], ...] = ()

    @staticmethod
    def make(seed: int = 0,
             specs: Optional[Mapping[str, FaultSpec]] = None) -> "FaultPlan":
        specs = dict(specs or {})
        for site in specs:
            if site not in SITES:
                raise ValueError(f"unknown fault site {site!r}; "
                                 f"one of {SITES}")
        return FaultPlan(seed, tuple(sorted(specs.items())))

    def spec(self, site: str) -> Optional[FaultSpec]:
        for s, spec in self.specs:
            if s == site:
                return spec
        return None


class FaultInjector:
    """Executes a ``FaultPlan``: deterministic per-(site, key, occurrence)
    decisions, a fired-fault log for replay assertions, and per-site
    counters.  Thread-safe; decisions do not depend on call order."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._counts: Dict[Tuple[str, str], int] = {}
        self._lock = threading.Lock()
        #: (site, key, occurrence, kind) for every fault that fired
        self.log: List[Tuple[str, str, int, str]] = []
        self.fired: Dict[str, int] = {}
        self.checked: Dict[str, int] = {}

    def decide(self, site: str, key: str = "") -> Optional[FaultSpec]:
        """The spec if occurrence ``n`` of ``(site, key)`` faults, else
        None.  Advances the per-key occurrence counter either way."""
        spec = self.plan.spec(site)
        with self._lock:
            self.checked[site] = self.checked.get(site, 0) + 1
            n = self._counts.get((site, key), 0)
            self._counts[(site, key)] = n + 1
        if spec is None or spec.rate <= 0.0:
            return None
        if spec.match and not key.startswith(spec.match):
            return None
        if n < spec.after:
            return None
        rng = random.Random(f"{self.plan.seed}:{site}:{key}:{n}")
        if rng.random() >= spec.rate:
            return None
        with self._lock:
            self.fired[site] = self.fired.get(site, 0) + 1
            self.log.append((site, key, n, spec.kind))
        _m_faults.inc(site=site)
        trace.instant("fault.injected", site=site, key=key,
                      occurrence=n, kind=spec.kind)
        return spec

    def fault(self, site: str, key: str = "") -> Optional[FaultSpec]:
        """Decide and act: raise ``InjectedFault`` for ``error``, sleep
        for ``slow``.  ``corrupt``/``nan`` specs are returned for the
        call site to implement (they need site-specific state)."""
        spec = self.decide(site, key)
        if spec is None:
            return None
        if spec.kind == "slow":
            time.sleep(spec.delay_s)
            return spec
        if spec.kind == "error":
            n = self._counts.get((site, key), 1) - 1
            raise InjectedFault(site, key, n)
        return spec

    def summary(self) -> Dict:
        return {"seed": self.plan.seed,
                "checked": dict(self.checked),
                "fired": dict(self.fired),
                "n_faults": len(self.log)}


# -- activation --------------------------------------------------------------
# process-global (not a contextvar): the solver's ThreadPoolExecutor
# workers must see the injector installed by the test/bench main thread.
_active: Optional[FaultInjector] = None


def active() -> Optional[FaultInjector]:
    return _active


@contextmanager
def inject(plan: FaultPlan):
    """Install an injector for ``plan``; yields it for log inspection."""
    global _active
    inj = FaultInjector(plan)
    prev = _active
    _active = inj
    try:
        yield inj
    finally:
        _active = prev


def maybe_fault(site: str, key: str = "") -> Optional[FaultSpec]:
    """No-op unless an injector is active (the production-code hook)."""
    inj = _active
    if inj is None:
        return None
    return inj.fault(site, key)


def truncate_file(path: str, keep_frac: float = 0.5) -> None:
    """Corrupt an on-disk file the way a torn write does: keep a prefix.
    Used by the ``corrupt`` fault kinds; silent on missing files."""
    try:
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(1, int(size * keep_frac)))
    except OSError:
        pass


__all__ = ["SITES", "KINDS", "InjectedFault", "FaultSpec", "FaultPlan",
           "FaultInjector", "inject", "active", "maybe_fault",
           "truncate_file"]
