"""Optimizers: AdamW and Adafactor (factored second moment), built in JAX.

Adafactor exists because AdamW's 16 B/param state cannot fit the pod for the
1T-param Kimi-K2 config (512 x 16 GB HBM < 16 TB); factored second moments
cut optimizer state to ~4 B/param + O(rows+cols).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree]]
    # update(grads, state, params) -> (new_params, new_state)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        if clip_norm > 0:
            grads = clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / bc1
            vh = v / bc2
            new_p = p.astype(jnp.float32) - lr * (
                mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(
                    jnp.float32))
            return new_p.astype(p.dtype), m, v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_params, {"m": new_m, "v": new_v, "step": step}

    return Optimizer("adamw", init, update)


def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              clip_norm: float = 1.0, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        def state_for(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree_util.tree_map(state_for, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        if clip_norm > 0:
            grads = clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                   eps)
                prec = (vr[..., None] / rfac[..., None]) * vc[..., None, :]
                u = g / jnp.sqrt(jnp.maximum(prec, eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            # relative-scale update clipping (Adafactor's d=1.0)
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u)
            new_p = p.astype(jnp.float32) - lr * u \
                - lr * weight_decay * p.astype(jnp.float32)
            return new_p.astype(p.dtype), new_s

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["f"])
        out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_f = treedef.unflatten([o[1] for o in out])
        return new_params, {"f": new_f, "step": step}

    return Optimizer("adafactor", init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(f"unknown optimizer {name!r}")
