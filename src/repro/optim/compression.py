"""Gradient compression with error feedback (int8 quantization).

For cross-pod (DCI) gradient reduction the wire bytes dominate; int8 with
per-tensor scale cuts them 4x vs f32 (2x vs bf16).  Error feedback keeps
the quantization noise from biasing convergence: the residual of each
round is added back before the next quantization (Seide et al. / EF-SGD).

``compress -> (payload, scale)`` / ``decompress`` are pure functions so
they slot into any collective path (e.g. quantize, psum int32, dequantize).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def compress(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jnp.ndarray, scale: jnp.ndarray,
               dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda g: compress(g), grads)


def ef_round(grads: PyTree, error: PyTree) -> Tuple[PyTree, PyTree]:
    """One error-feedback round: (compensated-compressed grads, new error).

    Returns the dequantized gradients (what the optimizer consumes after
    the wire trip) and the residual to carry into the next step.
    """
    def one(g, e):
        comp = g.astype(jnp.float32) + e
        q, s = compress(comp)
        deq = decompress(q, s)
        return deq.astype(g.dtype), comp - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def init_error(grads_template: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_template)


def wire_bytes_saved(grads: PyTree) -> Tuple[int, int]:
    """(bf16 wire bytes, int8 wire bytes) for reporting."""
    n = sum(x.size for x in jax.tree_util.tree_leaves(grads))
    return 2 * n, n + 4 * len(jax.tree_util.tree_leaves(grads))
