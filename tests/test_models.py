"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs; decode == forward in f32."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models.api import build_model
from repro.optim.optimizers import make_optimizer
from repro.launch.steps import build_train_step


def reduced(cfg):
    over = dict(num_layers=4, d_model=64, d_ff=128, vocab_size=512,
                head_dim=16)
    if cfg.num_heads:
        over.update(num_heads=4,
                    num_kv_heads=2 if cfg.num_kv_heads < cfg.num_heads
                    else 4)
    if cfg.family == "moe":
        over.update(num_experts=8, top_k=2, moe_d_ff=32,
                    num_shared_experts=min(1, cfg.num_shared_experts),
                    first_dense_layers=min(1, cfg.first_dense_layers),
                    capacity_factor=8.0)
    if cfg.family in ("ssm", "hybrid"):
        over.update(ssm_state=16, ssm_head_dim=16)
    if cfg.local_window:
        over.update(local_window=8)
    if cfg.attn_every:
        over.update(attn_every=2, num_layers=5)
    return dataclasses.replace(cfg, **over)


ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    B, S = 2, 16
    if cfg.frontend == "embed":
        inputs = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
    else:
        inputs = jax.random.randint(key, (B, S), 0, 100)
    logits = jax.jit(api.forward)(params, inputs)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    # one full train step
    opt = make_optimizer(cfg.optimizer, lr=1e-3)
    opt_state = opt.init(params)
    batch = {"inputs": inputs,
             "targets": jax.random.randint(key, (B, S), 0, 100)}
    step = jax.jit(build_train_step(api, opt))
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[1]
    l2 = jax.tree_util.tree_leaves(params2)[1]
    assert l0.shape == l2.shape


@pytest.mark.parametrize("arch", ["internlm2-20b", "gemma2-2b",
                                  "qwen2-moe-a2.7b", "mamba2-1.3b",
                                  "zamba2-1.2b"])
def test_decode_matches_forward_f32(arch):
    cfg = reduced(get_config(arch))
    api = build_model(cfg, dtype=jnp.float32)
    key = jax.random.PRNGKey(42)
    params = api.init(key)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, 100)
    full = np.asarray(jax.jit(api.forward)(params, toks), np.float32)
    cache = api.init_cache(B, 16)
    step = jax.jit(api.decode_step)
    dec = []
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t: t + 1], jnp.asarray(t))
        dec.append(np.asarray(lg, np.float32))
    dec = np.concatenate(dec, axis=1)
    rel = np.max(np.abs(full - dec)) / (np.abs(full).max() + 1e-9)
    assert rel < 1e-4, f"decode/forward mismatch rel={rel}"


def test_prefill_cache_matches_decode_path():
    cfg = reduced(get_config("internlm2-20b"))
    api = build_model(cfg, dtype=jnp.float32)
    key = jax.random.PRNGKey(7)
    params = api.init(key)
    B, S, G = 2, 8, 4
    toks = jax.random.randint(key, (B, S + G), 0, 100)
    # path A: prefill then decode
    logits_a, cache = jax.jit(lambda p, x: api.prefill(p, x, S + G))(
        params, toks[:, :S])
    outs_a = [np.asarray(logits_a, np.float32)]
    step = jax.jit(api.decode_step)
    for t in range(S, S + G - 1):
        lg, cache = step(params, cache, toks[:, t: t + 1], jnp.asarray(t))
        outs_a.append(np.asarray(lg, np.float32))
    # path B: full forward
    full = np.asarray(api.forward(params, toks[:, : S + G - 1]), np.float32)
    got = np.concatenate(outs_a, axis=1)
    want = full[:, S - 1:]
    rel = np.max(np.abs(got - want)) / (np.abs(want).max() + 1e-9)
    assert rel < 1e-4


def test_gemma2_local_global_alternation_matters():
    cfg = reduced(get_config("gemma2-2b"))
    api = build_model(cfg, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    toks = jax.random.randint(key, (1, 16), 0, 100)
    base = np.asarray(api.forward(params, toks))
    cfg2 = dataclasses.replace(cfg, local_window=2)
    api2 = build_model(cfg2, dtype=jnp.float32)
    out2 = np.asarray(api2.forward(params, toks))
    assert not np.allclose(base, out2)   # window size changes results


def test_moe_capacity_drops_are_bounded():
    """With a generous capacity factor no tokens should be dropped:
    routed output must differ from zero for (almost) all tokens."""
    cfg = reduced(get_config("qwen2-moe-a2.7b"))
    api = build_model(cfg, dtype=jnp.float32)
    params = api.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 100)
    logits = api.forward(params, toks)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_int8_kv_cache_decode_close_to_bf16():
    """Quantized decode cache: halves HBM stream, bounded accuracy loss."""
    import dataclasses as dc
    cfg = reduced(get_config("internlm2-20b"))
    api32 = build_model(cfg, dtype=jnp.float32)
    key = jax.random.PRNGKey(3)
    params = api32.init(key)
    B, S = 2, 10
    toks = jax.random.randint(key, (B, S), 0, 100)
    full = np.asarray(api32.forward(params, toks), np.float32)

    cfg8 = dc.replace(cfg, kv_cache_dtype="int8")
    api8 = build_model(cfg8, dtype=jnp.float32)
    cache = api8.init_cache(B, 16)
    assert cache["k"].dtype == jnp.int8
    step = jax.jit(api8.decode_step)
    dec = []
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t: t + 1], jnp.asarray(t))
        dec.append(np.asarray(lg, np.float32))
    dec = np.concatenate(dec, axis=1)
    rel = np.max(np.abs(full - dec)) / (np.abs(full).max() + 1e-9)
    assert rel < 0.05, f"int8 cache drift rel={rel}"
    # int8 path must actually differ from exact (sanity that it's active)
    assert rel > 1e-7
