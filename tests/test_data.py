"""Data pipeline: determinism, host sharding, prefetch."""
import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, Prefetcher, synth_batch


CFG = get_config("yi-6b")
SHAPE = ShapeConfig("t", 32, 8, "train")


def test_deterministic():
    a = synth_batch(CFG, SHAPE, 5)
    b = synth_batch(CFG, SHAPE, 5)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    c = synth_batch(CFG, SHAPE, 6)
    assert not np.array_equal(a["inputs"], c["inputs"])


def test_targets_are_shifted_inputs():
    b = synth_batch(CFG, SHAPE, 0)
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["targets"][:, :-1])


def test_host_sharding_partitions_global_batch():
    full = synth_batch(CFG, SHAPE, 3, DataConfig(host_count=1))
    h0 = synth_batch(CFG, SHAPE, 3, DataConfig(host_count=2, host_index=0))
    h1 = synth_batch(CFG, SHAPE, 3, DataConfig(host_count=2, host_index=1))
    np.testing.assert_array_equal(full["inputs"][:4], h0["inputs"])
    np.testing.assert_array_equal(full["inputs"][4:], h1["inputs"])


def test_embed_frontend_stub():
    cfg = get_config("musicgen-large")
    b = synth_batch(cfg, SHAPE, 0)
    assert b["inputs"].shape == (8, 32, cfg.d_model)
    assert b["inputs"].dtype == np.float32


def test_prefetcher_yields_in_order():
    pf = Prefetcher(CFG, SHAPE, start_step=10)
    first = next(pf)
    second = next(pf)
    pf.close()
    want1 = synth_batch(CFG, SHAPE, 10)
    want2 = synth_batch(CFG, SHAPE, 11)
    np.testing.assert_array_equal(first["inputs"], want1["inputs"])
    np.testing.assert_array_equal(second["inputs"], want2["inputs"])
