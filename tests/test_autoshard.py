"""Autoshard planner: spec validity (divisibility), ZeRO, HBM accounting."""
import math

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.core.autoshard import plan_sharding
from repro.launch.mesh import make_local_mesh
from repro.models.api import build_model
from repro.optim.optimizers import make_optimizer


class FakeMesh:
    """Shape-only stand-in for a 16x16 production mesh (no devices)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _plan(arch, shape_name, mesh=MESH):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    api = build_model(cfg)         # mesh=None: shapes only, no shard_map
    param_sds = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    if shape.mode == "train":
        opt = make_optimizer(cfg.optimizer)
        opt_sds = jax.eval_shape(opt.init, param_sds)
    else:
        opt_sds = {}
    cache_sds = None
    if shape.mode == "decode":
        cache_sds = jax.eval_shape(
            lambda: api.init_cache(shape.global_batch, shape.seq_len))
    return cfg, plan_sharding(cfg, shape, mesh, param_sds, opt_sds,
                              cache_shapes=cache_sds), param_sds


def _check_divisible(spec_tree, shape_tree, mesh):
    flat_s = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, P))[0]
    flat_l = jax.tree_util.tree_flatten(shape_tree)[0]
    for spec, leaf in zip(flat_s, flat_l):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = math.prod(mesh.shape[a] for a in axes)
            assert dim % size == 0, (spec, leaf.shape)


@pytest.mark.parametrize("arch", ["internlm2-20b", "gemma2-2b",
                                  "qwen2.5-3b", "qwen2-moe-a2.7b",
                                  "kimi-k2-1t-a32b", "mamba2-1.3b",
                                  "zamba2-1.2b"])
def test_param_specs_divisible(arch):
    cfg, plan, param_sds = _plan(arch, "train_4k")
    _check_divisible(plan.param_specs, param_sds, MESH)


def test_gemma2_heads_force_replicated_attention():
    cfg, plan, _ = _plan("gemma2-2b", "train_4k")
    assert not plan.attn_sharded          # 8 heads % 16 != 0


def test_internlm_heads_shardable():
    cfg, plan, _ = _plan("internlm2-20b", "train_4k")
    assert plan.attn_sharded


def test_kimi_fits_hbm_only_with_adafactor():
    cfg, plan, _ = _plan("kimi-k2-1t-a32b", "train_4k", MESH3)
    assert cfg.optimizer == "adafactor"
    assert plan.hbm_gb_per_chip < 16.0    # the validity check passes
    assert plan.zero_opt                  # ZeRO is required to fit


def test_zero_shards_optimizer_state_over_data():
    cfg, plan, _ = _plan("yi-6b", "train_4k")
    if not plan.zero_opt:
        pytest.skip("planner chose non-zero plan")
    found_data = False
    flat = jax.tree_util.tree_flatten(
        plan.opt_specs, is_leaf=lambda x: isinstance(x, P))[0]
    for spec in flat:
        for entry in tuple(spec):
            axes = entry if isinstance(entry, tuple) else (entry,)
            if "data" in axes:
                found_data = True
    assert found_data


def test_decode_cache_never_replicated_large():
    cfg, plan, _ = _plan("gemma2-2b", "decode_32k")
    k_spec = plan.cache_specs["k"]
    assert "model" in jax.tree_util.tree_leaves(
        [e for e in tuple(k_spec)], is_leaf=lambda x: True) or \
        any(e == "model" or (isinstance(e, tuple) and "model" in e)
            for e in tuple(k_spec))


def test_plan_notes_record_candidates():
    _, plan, _ = _plan("yi-6b", "train_4k")
    assert len(plan.notes) >= 2           # >1 candidate was considered
    assert any("zero=True" in n for n in plan.notes)
    assert any("zero=False" in n for n in plan.notes)


def test_cache_spec_uses_real_mesh_shape():
    # regression: _cache_spec once hardcoded {"pod": 2, "data": 16} for
    # the dp axis sizes and ignored the caller's mesh — on a smaller
    # data axis the decode cache lost its batch sharding (batch >= the
    # REAL dp size) and gained a bogus "data" sequence shard instead
    from repro.configs.base import ShapeConfig
    cfg = get_config("gemma2-2b")
    shape = ShapeConfig("decode_small", 1024, 8, "decode")
    mesh = FakeMesh({"data": 4, "model": 16})
    api = build_model(cfg)
    param_sds = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    cache_sds = jax.eval_shape(lambda: api.init_cache(8, 1024))
    plan = plan_sharding(cfg, shape, mesh, param_sds, {},
                         cache_shapes=cache_sds)
    k_spec = tuple(plan.cache_specs["k"])
    assert k_spec[1] == "data", k_spec     # batch 8 >= dp_size 4
    assert "data" not in k_spec[2:], k_spec
