"""Checkpoint roundtrip, atomicity, retention, resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:     # degrade: property tests skip, rest run
    from _hypothesis_stub import given, settings, strategies as st

from repro.checkpoint import ckpt


def _tree(key):
    ks = jax.random.split(key, 3)
    return {"layer": {"w": jax.random.normal(ks[0], (8, 4)),
                      "b": jax.random.normal(ks[1], (4,))},
            "head": jax.random.normal(ks[2], (4, 16)).astype(jnp.bfloat16)}


def test_roundtrip(tmp_path):
    params = _tree(jax.random.PRNGKey(0))
    opt = {"m": jax.tree_util.tree_map(jnp.zeros_like, params),
           "step": jnp.asarray(7)}
    path = ckpt.save(str(tmp_path), 7, params, opt, extra={"loss": 1.5})
    assert os.path.exists(os.path.join(path, "manifest.json"))
    p2, o2, man = ckpt.restore(str(tmp_path), params, opt)
    assert man["step"] == 7 and man["extra"]["loss"] == 1.5
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_retention_gc(tmp_path):
    params = _tree(jax.random.PRNGKey(1))
    for s in range(5):
        ckpt.save(str(tmp_path), s, params, {"step": jnp.asarray(s)},
                  keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2
    assert ckpt.latest_step(str(tmp_path)) == 4


def test_no_tmp_left_behind_on_failure(tmp_path):
    params = _tree(jax.random.PRNGKey(2))

    class Boom:
        def __iter__(self):
            raise RuntimeError("disk full")
    with pytest.raises(Exception):
        ckpt.save(str(tmp_path), 0, params, Boom())
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]


def test_shape_mismatch_rejected(tmp_path):
    params = _tree(jax.random.PRNGKey(3))
    ckpt.save(str(tmp_path), 1, params, {"step": jnp.asarray(1)})
    bad_template = {"layer": {"w": jnp.zeros((9, 4)), "b": jnp.zeros((4,))},
                    "head": jnp.zeros((4, 16), jnp.bfloat16)}
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), bad_template, {"step": jnp.asarray(0)})


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4), st.integers(1, 6))
def test_property_roundtrip_random_trees(depth, width):
    import tempfile
    tmpd = tempfile.mkdtemp(prefix="ckpt_prop_")
    tmp = tmpd
    rng = np.random.default_rng(depth * 10 + width)
    tree = {f"k{i}": np.asarray(rng.standard_normal((width, depth)),
                                np.float32)
            for i in range(depth)}
    ckpt.save(str(tmp), 0, tree, {"s": np.asarray(0)})
    t2, _, _ = ckpt.restore(str(tmp), tree, {"s": np.asarray(0)})
    for k in tree:
        np.testing.assert_array_equal(tree[k], t2[k])
