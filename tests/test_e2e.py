"""End-to-end: tiny training run (loss falls), failure injection + resume,
batched serving."""
import numpy as np
import pytest

from repro.launch.serve import serve
from repro.launch.train import train


def test_tiny_training_loss_decreases(tmp_path):
    losses, stats = train("qwen2.5-3b", steps=40, batch=4, seq=32,
                          tiny=True, ckpt_dir=str(tmp_path), ckpt_every=16)
    assert len(losses) == 40
    # synthetic uniform tokens: loss should head toward ln(vocab)
    assert np.mean(losses[-5:]) < np.mean(losses[:3])
    assert stats.restarts == 0


def test_training_recovers_from_injected_failure(tmp_path):
    losses, stats = train("qwen2.5-3b", steps=16, batch=4, seq=32,
                          tiny=True, ckpt_dir=str(tmp_path), ckpt_every=4,
                          fail_at=9)
    assert stats.restarts == 1
    assert np.isfinite(losses).all()


def test_resume_from_checkpoint(tmp_path):
    train("mamba2-1.3b", steps=10, batch=2, seq=32, tiny=True,
          ckpt_dir=str(tmp_path), ckpt_every=5)
    losses, _ = train("mamba2-1.3b", steps=14, batch=2, seq=32, tiny=True,
                      ckpt_dir=str(tmp_path), resume=True)
    assert len(losses) >= 4               # only steps 10..13 run


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-1.3b",
                                  "musicgen-large"])
def test_serving_generates(arch):
    toks = serve(arch, requests=2, prompt_len=8, gen=4, tiny=True)
    assert toks.shape == (2, 4)
    assert np.issubdtype(toks.dtype, np.integer)
