"""Fallback stand-in for ``hypothesis`` so the suite degrades instead of
erroring when the package is not installed: property-based tests are skipped
while plain tests in the same module still run.

Usage in a test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hypothesis_stub import given, settings, strategies as st
"""
import pytest


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn
    return deco


def given(*_args, **_kwargs):
    def deco(fn):
        return pytest.mark.skip(reason="hypothesis not installed")(fn)
    return deco


class _Strategies:
    """Any strategy constructor resolves to an inert placeholder."""

    def __getattr__(self, _name):
        def strategy(*_args, **_kwargs):
            return None
        return strategy


strategies = _Strategies()
