"""Schedule-service tests: signatures, the content-addressed store,
warm-start seeding, the coalescing server, top-k + autotune, and the
store-read round-trip/re-scoring parity gates."""
import asyncio
import dataclasses
import json
import os

import pytest

from repro.core.solver import (NetworkSchedule, memo, seed_chains_from,
                               solve, solve_many, solve_topk)
from repro.hw.presets import eyeriss_multinode
from repro.service import (LocalClient, ScheduleStore, SolveRequest,
                           SolveServer, family_signature,
                           schedule_signature, serve_batch, solver_options)
from repro.workloads.layers import LayerGraph, fc
from repro.workloads.nets import get_net

HW = eyeriss_multinode()


def _branchy(name="twin", batch=8, flip=False):
    """Two independent input layers joined by one consumer; ``flip``
    permutes the (topologically legal) insertion order of the inputs."""
    a = fc("a", batch, 256, 128)
    b = fc("b", batch, 512, 128)
    first, second = (b, a) if flip else (a, b)
    join = fc("join", batch, 128, 64, src=[first.name])
    return LayerGraph(name, [first, second, join])


# ---------------------------------------------------------------------------
# signatures
# ---------------------------------------------------------------------------

def test_signature_stable_across_processually_identical_graphs():
    s1 = schedule_signature(get_net("mlp", batch=8), HW)
    s2 = schedule_signature(get_net("mlp", batch=8), HW)
    assert s1 == s2


def test_signature_insensitive_to_layer_names():
    g1 = get_net("mlp", batch=8)
    renamed = [dataclasses.replace(
        l, name=f"L{i}", src=tuple(f"L{j}" for j in range(i)
                                   if g1.layers[j].name in l.src))
        for i, l in enumerate(g1.layers)]
    g2 = LayerGraph("mlp", renamed)
    assert schedule_signature(g1, HW) == schedule_signature(g2, HW)
    assert family_signature(g1, HW) == family_signature(g2, HW)


def test_signature_sensitive_to_insertion_order():
    # the DP walks the topological list, so order is solver-visible
    assert schedule_signature(_branchy(), HW) != \
        schedule_signature(_branchy(flip=True), HW)


def test_signature_sensitive_to_batch_but_family_is_not():
    g8, g16 = get_net("mlp", batch=8), get_net("mlp", batch=16)
    assert schedule_signature(g8, HW) != schedule_signature(g16, HW)
    assert family_signature(g8, HW) == family_signature(g16, HW)


def test_signature_sensitive_to_hw_and_options():
    g = get_net("mlp", batch=8)
    assert schedule_signature(g, HW) != \
        schedule_signature(g, HW.with_(mac_energy_pj=HW.mac_energy_pj * 2))
    assert schedule_signature(g, HW) != \
        schedule_signature(g, HW, {"objective": "latency"})
    with pytest.raises(ValueError):
        solver_options(bogus=1)


# ---------------------------------------------------------------------------
# store
# ---------------------------------------------------------------------------

def test_store_put_get_roundtrip(tmp_path):
    store = ScheduleStore(str(tmp_path))
    net = get_net("mlp", batch=8)
    sched = solve(net, HW)
    rec = store.put(sched, net, HW)
    assert store.has(rec.signature) and len(store) == 1
    back = store.get(rec.signature, get_net("mlp", batch=8))
    assert back is not None
    assert back.total_energy_pj == sched.total_energy_pj
    assert back.total_latency_cycles == sched.total_latency_cycles
    assert store.stats()["hits"] == 1
    assert store.get("0" * 64) is None
    assert store.stats()["misses"] == 1


def test_store_loaded_schedule_rescores_bit_identically(tmp_path):
    # the satellite parity gate: store read -> rescore == original solve
    store = ScheduleStore(str(tmp_path))
    for name, batch in (("mlp", 8), ("lstm", 8), ("alexnet", 4)):
        net = get_net(name, batch=batch)
        sched = solve(net, HW)
        sig = store.put(sched, net, HW).signature
        loaded = store.get(sig, get_net(name, batch=batch))
        e, lat, costs = loaded.rescore(get_net(name, batch=batch), HW)
        assert e == sched.total_energy_pj
        assert lat == sched.total_latency_cycles
        for n, c in sched.layer_costs.items():
            assert costs[n].energy_pj == c.energy_pj
            assert costs[n].latency_cycles == c.latency_cycles


def test_from_json_roundtrip_without_live_graph():
    net = get_net("mlp", batch=8)
    sched = solve(net, HW)
    blob = json.dumps(sched.to_json())
    back = NetworkSchedule.from_json(json.loads(blob))     # no graph
    # embedded specs rebuild the graph; rescoring needs no original object
    g = back.to_graph()
    assert [l.name for l in g.layers] == [l.name for l in net.layers]
    e, lat, _ = back.rescore(hw=HW)
    assert e == sched.total_energy_pj
    assert lat == sched.total_latency_cycles
    # chain metadata (est_cost + pipelined flags) survives the round-trip
    assert back.chain.est_cost == sched.chain.est_cost
    assert back.seg_pipelined == sched.seg_pipelined
    assert json.dumps(back.to_json()) == blob


def test_store_positional_rebind_for_renamed_layers(tmp_path):
    store = ScheduleStore(str(tmp_path))
    g1 = get_net("mlp", batch=8)
    sig = store.put(solve(g1, HW), g1, HW).signature
    renamed = [dataclasses.replace(
        l, name=f"L{i}", src=(f"L{i - 1}",) if i else ())
        for i, l in enumerate(g1.layers)]
    g2 = LayerGraph("mlp-renamed", renamed)
    assert schedule_signature(g2, HW) == sig       # names never enter
    back = store.get(sig, g2)
    assert set(back.layer_schemes) == {l.name for l in g2.layers}
    for l in g2.layers:
        assert back.layer_schemes[l.name].layer is l


def test_store_eviction_and_stats(tmp_path):
    store = ScheduleStore(str(tmp_path), max_entries=2)
    for batch in (2, 4, 8):
        net = get_net("mlp", batch=batch)
        store.put(solve(net, HW), net, HW)
    assert len(store) == 2
    assert store.stats()["evictions"] == 1
    # the family map drops evicted signatures too
    fam = family_signature(get_net("mlp", batch=2), HW)
    assert all(store.has(s) for s in store._family[fam])


def test_store_atomic_record_files(tmp_path):
    store = ScheduleStore(str(tmp_path))
    net = get_net("mlp", batch=4)
    store.put(solve(net, HW), net, HW)
    assert not [n for n in os.listdir(store.records_dir)
                if n.endswith(".tmp")]
    # a second store over the same dir replays the index
    store2 = ScheduleStore(str(tmp_path))
    fam = family_signature(net, HW)
    assert store2.warm_records(fam)


# ---------------------------------------------------------------------------
# warm-start seeding
# ---------------------------------------------------------------------------

def test_seed_chains_from_rebatches_granules():
    net8 = get_net("lstm", batch=8)
    sched = solve(net8, HW)
    net32 = get_net("lstm", batch=32)
    seeds = seed_chains_from(sched, net32)
    assert len(seeds) == 1
    segs = seeds[0].segments
    assert [(s.start, s.stop) for s in segs] == \
        [(s.start, s.stop) for s in sched.chain.segments]
    for s in segs:
        assert s.granule_frac == 1.0 or s.granule_frac == pytest.approx(
            1.0 / net32.layers[s.start].dim("N"))
    warm = solve(net32, HW, seed_chains=seeds, use_dp=False)
    assert warm.valid


def test_client_cold_cached_warm(tmp_path):
    client = LocalClient(ScheduleStore(str(tmp_path)))
    r1 = client.solve(get_net("mlp", batch=8), HW)
    assert r1.source == "cold" and r1.schedule.valid
    r2 = client.solve(get_net("mlp", batch=8), HW)
    assert r2.source == "cached"
    assert r2.schedule.total_energy_pj == r1.schedule.total_energy_pj
    r3 = client.solve(get_net("mlp", batch=16), HW)
    assert r3.source == "warm" and r3.schedule.valid
    st = client.stats()
    assert st["entries"] == 2 and st["warm_hits"] >= 1


def test_client_batch_dedupes_and_pools(tmp_path):
    client = LocalClient(ScheduleStore(str(tmp_path)))
    reqs = [SolveRequest.make(get_net("mlp", batch=8), HW),
            SolveRequest.make(get_net("mlp", batch=8), HW),
            SolveRequest.make(get_net("lstm", batch=8), HW)]
    res = client.solve_batch(reqs)
    assert [r.source for r in res] == ["cold", "cold", "cold"]
    assert res[0].signature == res[1].signature
    assert res[0].schedule.total_energy_pj == \
        res[1].schedule.total_energy_pj
    # identical results to independent solves
    assert res[2].schedule.total_energy_pj == \
        solve(get_net("lstm", batch=8), HW).total_energy_pj
    res2 = client.solve_batch(reqs)
    assert [r.source for r in res2] == ["cached"] * 3


def test_solve_many_matches_individual_solves():
    items = [(get_net("mlp", batch=8), HW), (get_net("lstm", batch=8), HW)]
    batched = solve_many(items)
    for (g, hw), sched in zip(items, batched):
        ref = solve(g, hw)
        assert sched.total_energy_pj == ref.total_energy_pj
        assert sched.total_latency_cycles == ref.total_latency_cycles


# ---------------------------------------------------------------------------
# async server
# ---------------------------------------------------------------------------

def test_server_coalesces_and_caches(tmp_path):
    server = SolveServer(ScheduleStore(str(tmp_path)))
    reqs = [SolveRequest.make(get_net("mlp", batch=8), HW),
            SolveRequest.make(get_net("mlp", batch=8), HW),
            SolveRequest.make(get_net("mlp", batch=16), HW)]
    res = asyncio.run(serve_batch(server, reqs))
    assert all(r.schedule.valid for r in res)
    assert res[0].schedule.total_energy_pj == \
        res[1].schedule.total_energy_pj
    st = server.stats()
    assert st["requests"] == 3 and st["coalesced"] >= 1
    assert st["solved"] <= 2            # the duplicate never solved twice
    res2 = asyncio.run(serve_batch(server, reqs))
    assert [r.source for r in res2] == ["cached"] * 3


def test_server_submit_after_stop_raises(tmp_path):
    server = SolveServer(ScheduleStore(str(tmp_path)))
    req = SolveRequest.make(get_net("mlp", batch=8), HW)

    async def run():
        task = asyncio.ensure_future(server.serve_forever())
        await server.stop()
        await task
        with pytest.raises(RuntimeError):
            await server.submit(req)
    asyncio.run(run())


# ---------------------------------------------------------------------------
# top-k + autotune
# ---------------------------------------------------------------------------

def test_solve_topk_ordering_and_argmin_parity():
    net = get_net("lstm", batch=8)
    cands = solve_topk(net, HW, k=3)
    assert 1 <= len(cands) <= 3
    ref = solve(get_net("lstm", batch=8), HW)
    assert cands[0].total_energy_pj == ref.total_energy_pj
    energies = [c.total_energy_pj for c in cands]
    assert energies == sorted(energies)
    # distinct chains, all valid, all rescorable
    keys = {tuple((s.start, s.stop, s.alloc, s.granule_frac)
                  for s in c.chain.segments) for c in cands}
    assert len(keys) == len(cands)
    for c in cands:
        e, lat, _ = c.rescore(get_net("lstm", batch=8), HW)
        assert e == c.total_energy_pj and lat == c.total_latency_cycles


def test_autotune_executes_and_promotes(tmp_path):
    from repro.lower.calibrate import default_hw
    from repro.service import autotune_network
    store = ScheduleStore(str(tmp_path))
    hw = default_hw()
    net = get_net("mlp", batch=2)
    report = autotune_network(net, hw, store=store, k=2, iters=1)
    assert report["n_executed"] >= 1
    best = min(e["measured_seconds"] for e in report["candidates"])
    assert report["promoted_measured_seconds"] == best
    if any(e["rank"] == 0 for e in report["candidates"]):
        assert report["promoted_measured_seconds"] <= \
            report["argmin_measured_seconds"]
    rec = store.get_record(report["signature"])
    assert rec is not None and rec.measured is not None
    assert rec.measured["measured_seconds"] == best
    # the promoted schedule still lowers straight from the store
    from repro.lower import lower_cached
    nplan = lower_cached(store.get(report["signature"]), hw)
    assert nplan.executable


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_solve_get_stats(tmp_path, capsys):
    from repro.service.__main__ import main
    root = str(tmp_path / "store")
    assert main(["solve", "--net", "mlp", "--batch", "8",
                 "--store-dir", root]) == 0
    out1 = capsys.readouterr().out
    assert "source=cold" in out1
    assert main(["solve", "--net", "mlp", "--batch", "8",
                 "--store-dir", root]) == 0
    assert "source=cached" in capsys.readouterr().out
    assert main(["warm", "--net", "mlp", "--batch", "16",
                 "--store-dir", root]) == 0
    assert "seeding from mlp/b8" in capsys.readouterr().out
    assert main(["get", "--net", "mlp", "--batch", "8",
                 "--store-dir", root]) == 0
    assert "HIT" in capsys.readouterr().out
    assert main(["stats", "--store-dir", root]) == 0
    assert json.loads(capsys.readouterr().out)["entries"] == 2
    assert main(["get", "--net", "mlp", "--batch", "4",
                 "--store-dir", root]) == 1


# ---------------------------------------------------------------------------
# resilience satellites
# ---------------------------------------------------------------------------

def test_server_isolates_poisoned_request_in_batch(tmp_path, monkeypatch):
    """Regression: an exception inside a coalesced batch solve must fail
    only the poisoned request — its neighbour still gets a result."""
    import repro.service.client as client_mod
    import repro.service.server as server_mod
    from repro.service import ServiceError, serve_batch_settled

    real_solve = client_mod.solve
    real_greedy = client_mod.solve_greedy

    def boom_many(*a, **k):
        raise ValueError("batch poisoned")

    def picky_solve(graph, hw, **k):
        if graph.name == "poison":
            raise ValueError("poisoned request")
        return real_solve(graph, hw, **k)

    def picky_greedy(graph, hw, **k):
        if graph.name == "poison":
            raise ValueError("poisoned request")
        return real_greedy(graph, hw, **k)

    monkeypatch.setattr(server_mod, "solve_many", boom_many)
    monkeypatch.setattr(client_mod, "solve", picky_solve)
    monkeypatch.setattr(client_mod, "solve_greedy", picky_greedy)

    poison = LayerGraph("poison", get_net("mlp", batch=16).layers)
    server = SolveServer(ScheduleStore(str(tmp_path)),
                         batch_window_s=0.05)
    reqs = [SolveRequest.make(get_net("mlp", batch=8), HW),
            SolveRequest.make(poison, HW)]
    ok, err = asyncio.run(serve_batch_settled(server, reqs))
    assert ok.schedule.valid and not ok.degraded
    assert isinstance(err, ServiceError)
    assert err.signature == reqs[1].signature()
    assert "poisoned" in err.reason
    st = server.stats()
    assert st["batch_faults"] >= 1
    assert st["isolated"] == 2 and st["errors"] == 1
    assert st["inflight"] == 0


def test_stats_surface_resilience_counters(tmp_path):
    store = ScheduleStore(str(tmp_path))
    for k in ("corrupt", "quarantined", "io_errors", "rebuilds"):
        assert store.stats()[k] == 0
    for st in (LocalClient(store).stats(), SolveServer(store).stats()):
        for k in ("corrupt", "quarantined", "degraded", "errors",
                  "store_errors", "store_skipped", "breaker"):
            assert k in st
    assert "batch_faults" in SolveServer(store).stats()


def test_cli_stats_and_repair_surface_resilience(tmp_path, capsys):
    from repro.service.__main__ import main
    root = str(tmp_path / "store")
    assert main(["solve", "--net", "mlp", "--batch", "8",
                 "--store-dir", root]) == 0
    capsys.readouterr()
    assert main(["stats", "--store-dir", root]) == 0
    st = json.loads(capsys.readouterr().out)
    for k in ("corrupt", "quarantined", "io_errors", "rebuilds"):
        assert st[k] == 0
    assert main(["repair", "--store-dir", root]) == 0
    assert "rebuilt index: 1 records" in capsys.readouterr().out
