"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import attention_ref, ssd_decode_ref, ssd_ref
from repro.kernels.ssd_scan import ssd_intra_chunk


def _rel_err(a, b):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    return np.max(np.abs(a - b)) / (np.abs(b).max() + 1e-9)


ATTN_SWEEP = [
    # B, H, KV, S, D, causal, window, softcap, dtype
    (1, 2, 1, 128, 32, True, 0, 0.0, jnp.float32),
    (2, 4, 2, 256, 64, True, 0, 0.0, jnp.float32),
    (1, 8, 4, 128, 64, True, 0, 50.0, jnp.float32),
    (1, 4, 4, 256, 32, True, 64, 0.0, jnp.float32),
    (2, 2, 1, 256, 128, False, 0, 0.0, jnp.float32),
    (1, 4, 2, 128, 64, True, 32, 30.0, jnp.float32),
    (1, 2, 2, 128, 32, True, 0, 0.0, jnp.bfloat16),
]


@pytest.mark.parametrize("B,H,KV,S,D,causal,win,cap,dtype", ATTN_SWEEP)
def test_flash_attention_interpret_sweep(B, H, KV, S, D, causal, win, cap,
                                         dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, D), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, D), dtype)
    ref = attention_ref(q, k, v, causal=causal, window=win,
                        logit_softcap=cap)
    out = flash_attention(q, k, v, causal=causal, window=win,
                          logit_softcap=cap, block_q=64, block_k=64,
                          interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert _rel_err(out, ref) < tol


@pytest.mark.parametrize("B,H,KV,S,D,causal,win,cap,dtype", ATTN_SWEEP[:5])
def test_chunked_jnp_attention_sweep(B, H, KV, S, D, causal, win, cap,
                                     dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, D), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, D), dtype)
    ref = attention_ref(q, k, v, causal=causal, window=win,
                        logit_softcap=cap)
    out = ops.attention(q, k, v, causal=causal, window=win,
                        logit_softcap=cap, impl="jnp")
    assert _rel_err(out, ref) < 2e-5


def test_decode_attention_matches_ref():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, H, KV, S, D = 2, 4, 2, 32, 32
    q = jax.random.normal(ks[0], (B, H, 1, D))
    kc = jnp.zeros((B, KV, 64, D)).at[:, :, :S].set(
        jax.random.normal(ks[1], (B, KV, S, D)))
    vc = jnp.zeros((B, KV, 64, D)).at[:, :, :S].set(
        jax.random.normal(ks[2], (B, KV, S, D)))
    out = ops.decode_attention(q, kc, vc, jnp.asarray(S))
    ref = attention_ref(q, kc[:, :, :S], vc[:, :, :S], causal=True)
    assert _rel_err(out, ref) < 1e-5


SSD_SWEEP = [
    # B, S, H, P, N, chunk
    (1, 128, 2, 8, 4, 32),
    (2, 256, 4, 16, 8, 64),
    (1, 64, 8, 32, 16, 64),
    (2, 128, 4, 16, 8, 128),
]


@pytest.mark.parametrize("B,S,H,P,N,chunk", SSD_SWEEP)
@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_ssd_sweep(B, S, H, P, N, chunk, impl):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.5
    b = jax.random.normal(ks[3], (B, S, N))
    c = jax.random.normal(ks[4], (B, S, N))
    ref = ssd_ref(x, dt, a_log, b, c)
    y, _ = ops.ssd(x, dt, a_log, b, c, chunk=chunk, impl=impl)
    assert _rel_err(y, ref) < 1e-4


def test_ssd_final_state_feeds_decode():
    """Chunked final state must continue the sequence exactly."""
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    B, S, H, P, N = 1, 64, 2, 8, 4
    x = jax.random.normal(ks[0], (B, S + 1, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S + 1, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.5
    b = jax.random.normal(ks[3], (B, S + 1, N))
    c = jax.random.normal(ks[4], (B, S + 1, N))
    full = ssd_ref(x, dt, a_log, b, c)
    _, state = ops.ssd(x[:, :S], dt[:, :S], a_log, b[:, :S], c[:, :S],
                       chunk=32, impl="jnp")
    _, y_last = ssd_decode_ref(state, x[:, S].transpose(0, 1, 2),
                               dt[:, S], a_log, b[:, S], c[:, S])
    assert _rel_err(y_last, full[:, S]) < 1e-4


def test_gqa_grouping_in_kernel():
    """q-head h must attend with kv head h // (H/KV)."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    B, H, KV, S, D = 1, 4, 2, 64, 16
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, KV, S, D))
    v = jax.random.normal(ks[2], (B, KV, S, D))
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    # heads 0,1 share kv0; heads 2,3 share kv1 — check vs per-head ref
    ref01 = attention_ref(q[:, :2], k[:, :1], v[:, :1])
    ref23 = attention_ref(q[:, 2:], k[:, 1:], v[:, 1:])
    assert _rel_err(out[:, :2], ref01) < 1e-5
    assert _rel_err(out[:, 2:], ref23) < 1e-5
