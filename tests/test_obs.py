"""Observability layer: trace/metrics primitives, the instrumented
degradation + mesh-fault ladders (every rung must emit a structured
event with a reason), re-homed stats views, drift histogram, CLIs."""
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.core.solver import memo, solve
from repro.core.solver.multinode import NodeMesh, plan_multinode
from repro.lower.calibrate import default_hw
from repro.lower.meshexec import MeshExecutor, SegmentTask
from repro.lower.netexec import record_latency_drift
from repro.obs import metrics, trace
from repro.obs.metrics import REGISTRY, CounterGroup, Registry
from repro.runtime.inject import FaultPlan, FaultSpec, inject
from repro.runtime.straggler import StragglerDetector
from repro.service import LocalClient, ScheduleStore
from repro.workloads.nets import get_net

HW = default_hw()


@pytest.fixture(autouse=True)
def _obs_clean():
    """Every test starts and ends in the production default: metrics on,
    tracing off (a leaked tracer would couple tests)."""
    trace.disable()
    obs.on()
    yield
    trace.disable()
    obs.on()


@pytest.fixture(scope="module")
def solved():
    net = get_net("mlp", batch=4)
    sched = solve(net, HW, max_seg_len=2)
    assert sched.valid
    return net, sched


# ---------------------------------------------------------------------------
# trace primitives
# ---------------------------------------------------------------------------

def test_span_disabled_is_shared_noop():
    assert not trace.enabled()
    sp = trace.span("x.y", a=1)
    assert sp is trace.NOOP_SPAN        # no allocation while disabled
    with sp as s:
        s.set(b=2)                      # swallowed
    trace.instant("x.z", c=3)           # no-op, no error


def test_span_records_timing_thread_and_attrs():
    t = trace.enable()
    try:
        with trace.span("unit.op", fixed="yes") as sp:
            sp.set(late=7)
        trace.instant("unit.mark", why="because")
    finally:
        trace.disable()
    (ev,) = t.find("unit.op")
    assert ev["ph"] == "X" and ev["dur"] >= 0
    assert ev["args"] == {"fixed": "yes", "late": 7}
    assert ev["tid"] == threading.get_ident()
    (mark,) = t.find("unit.mark")
    assert mark["ph"] == "i" and mark["args"]["why"] == "because"
    assert t.counts() == {"unit.op": 1, "unit.mark": 1}


def test_span_annotates_exceptions_and_still_records():
    t = trace.enable()
    try:
        with pytest.raises(ValueError):
            with trace.span("unit.boom"):
                raise ValueError("nope")
    finally:
        trace.disable()
    (ev,) = t.find("unit.boom")
    assert ev["args"]["error"] == "ValueError"


def test_tracing_scope_exports_chrome_json(tmp_path):
    path = str(tmp_path / "t.json")
    with trace.tracing(path):
        with trace.span("a.b", k="v"):
            pass
        trace.instant("a.mark")
    assert not trace.enabled()          # scope closed the tracer
    events = trace.load_events(path)
    phases = {e["ph"] for e in events}
    assert phases == {"X", "i", "M"}    # spans, instants, thread names
    x = next(e for e in events if e["ph"] == "X")
    assert x["name"] == "a.b" and x["cat"] == "a"
    assert x["ts"] >= 0 and "dur" in x  # µs fields Perfetto needs
    summ = trace.summarize_events(events)
    assert summ["spans"]["a.b"]["count"] == 1
    assert summ["instants"]["a.mark"] == 1
    assert summ["threads"]


def test_tracing_scope_exports_even_on_error(tmp_path):
    path = str(tmp_path / "crash.json")
    with pytest.raises(RuntimeError):
        with trace.tracing(path):
            with trace.span("a.b"):
                pass
            raise RuntimeError("chaos")
    assert trace.summarize_events(
        trace.load_events(path))["spans"]["a.b"]["count"] == 1


def test_tracer_drops_past_max_events():
    t = trace.Tracer()
    t.max_events = 3
    for i in range(5):
        t.instant(f"e{i}")
    assert len(t.events) == 3 and t.dropped == 2


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_semantics():
    r = Registry()
    c = r.counter("c_total", "c", ("kind",))
    c.inc(kind="a")
    c.inc(2, kind="a")
    c.inc(kind="b")
    assert c.value(kind="a") == 3 and c.value(kind="b") == 1
    g = r.gauge("g", "g")
    g.set(5)
    g.dec(2)
    assert g.value() == 3
    h = r.histogram("h_seconds", "h", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    (s,) = h.series()
    assert s["count"] == 3 and s["sum"] == pytest.approx(5.55)
    assert s["buckets"] == {"0.1": 1, "1.0": 2, "+Inf": 3}  # cumulative


def test_metric_label_mismatch_raises():
    r = Registry()
    c = r.counter("c_total", "c", ("kind",))
    with pytest.raises(ValueError):
        c.inc()                         # missing declared label
    with pytest.raises(ValueError):
        c.inc(kind="a", extra="b")


def test_registry_idempotent_but_redeclare_raises():
    r = Registry()
    assert r.counter("x_total", "", ("a",)) is \
        r.counter("x_total", "", ("a",))
    with pytest.raises(ValueError):
        r.gauge("x_total")              # same name, different kind
    with pytest.raises(ValueError):
        r.counter("x_total", "", ("b",))    # different labelset
    snap = r.snapshot()
    assert snap["x_total"]["kind"] == "counter"


def test_prometheus_exposition_format():
    r = Registry()
    r.counter("req_total", "requests", ("source",)).inc(source="cold")
    r.histogram("lat_seconds", "latency", buckets=(1.0,)).observe(0.5)
    text = r.exposition()
    assert "# TYPE req_total counter" in text
    assert 'req_total{source="cold"} 1.0' in text
    assert 'lat_seconds_bucket{le="1.0"} 1' in text
    assert 'lat_seconds_bucket{le="+Inf"} 1' in text
    assert "lat_seconds_count 1" in text


def test_off_switch_skips_all_updates():
    r = Registry()
    c = r.counter("c_total")
    h = r.histogram("h_seconds")
    metrics.set_off(True)
    try:
        c.inc()
        h.observe(1.0)
    finally:
        metrics.set_off(False)
    assert c.value() == 0 and h.value() == 0
    c.inc()
    assert c.value() == 1               # back on


def test_counter_group_mirrors_into_shared_counter():
    r = Registry()
    g1 = CounterGroup("unit", ("hits", "misses"), registry=r)
    g2 = CounterGroup("unit", ("hits", "misses"), registry=r)
    g1.inc("hits")
    g1.inc("misses", 2)
    g2.inc("hits", 3)
    assert g1["hits"] == 1 and g2["hits"] == 3      # per-instance views
    assert g1.view() == {"hits": 1, "misses": 2}
    shared = r.get("unit_events_total")             # union across instances
    assert shared.value(event="hits") == 4
    assert shared.value(event="misses") == 2
    with pytest.raises(KeyError):
        g1.inc("undeclared")


# ---------------------------------------------------------------------------
# degradation ladder: every rung emits service.resolved with a reason
# ---------------------------------------------------------------------------

def _resolved(t, source):
    evs = [e for e in t.find("service.resolved")
           if e["args"]["source"] == source]
    assert evs, f"no service.resolved event for rung {source!r}: " \
        f"{[e['args'] for e in t.find('service.resolved')]}"
    return evs[-1]["args"]


def test_ladder_rungs_emit_resolved_events(tmp_path):
    client = LocalClient(ScheduleStore(str(tmp_path)))
    t = trace.enable()
    try:
        client.solve(get_net("mlp", batch=8), HW)           # cold
        client.solve(get_net("mlp", batch=8), HW)           # cached
        client.solve(get_net("mlp", batch=16), HW)          # warm
        r = client.solve(get_net("mlp", batch=32), HW,
                         deadline_s=0.0)                    # greedy floor
    finally:
        trace.disable()
    assert r.source == "greedy"
    for rung, why in (("cold", "full solve"), ("cached", "store hit"),
                      ("warm", "family near-miss seed")):
        args = _resolved(t, rung)
        assert args["reason"] == why and not args["degraded"]
        assert args["sig"]                  # request-identifying prefix
    greedy = _resolved(t, "greedy")
    assert greedy["degraded"]
    # the drop itself is a separate structured event with the cause
    drops = [e["args"] for e in t.find("service.degrade")
             if e["args"]["rung"] == "greedy"]
    assert drops and "deadline" in drops[-1]["reason"]
    # every request span resolved its source attribute
    spans = t.find("service.request")
    assert {s["args"]["source"] for s in spans} == \
        {"cold", "cached", "warm", "greedy"}


def test_ladder_retry_and_exhaustion_events(tmp_path):
    from repro.runtime.fault import RecoveryPolicy
    from repro.service import ServiceError
    plan = FaultPlan.make(
        7, {"solve.segment": FaultSpec(rate=1.0, kind="error")})
    client = LocalClient(
        ScheduleStore(str(tmp_path)),
        retry_policy=RecoveryPolicy(max_retries=2, backoff_seconds=0.0,
                                    max_backoff=0.0))
    t = trace.enable()
    try:
        with inject(plan):
            with pytest.raises(ServiceError):
                client.solve(get_net("mlp", batch=8), HW)
    finally:
        trace.disable()
    assert t.find("fault.injected")         # chaos annotated into trace
    retries = [e["args"] for e in t.find("service.degrade")
               if e["args"]["rung"] == "retry"]
    assert retries and "InjectedFault" in retries[0]["reason"]
    err = _resolved(t, "error")
    assert err["degraded"] and "InjectedFault" in err["reason"]


def test_ladder_rung_counters_accumulate(tmp_path):
    c = metrics.counter("service_requests_total",
                        "requests answered, by resolved ladder rung",
                        ("source",))
    before = {s: c.value(source=s) for s in ("cold", "cached")}
    client = LocalClient(ScheduleStore(str(tmp_path)))
    client.solve(get_net("mlp", batch=8), HW)
    client.solve(get_net("mlp", batch=8), HW)
    assert c.value(source="cold") == before["cold"] + 1
    assert c.value(source="cached") == before["cached"] + 1


# ---------------------------------------------------------------------------
# mesh fault ladder: every rung emits a reasoned event
# ---------------------------------------------------------------------------

def _synth_tasks(n, seconds_by_node=()):
    tasks = []
    for i in range(n):
        def run(state, i=i):
            name = threading.current_thread().name
            for prefix, sec in seconds_by_node:
                if name.startswith(prefix):
                    import time
                    time.sleep(sec)
            return {f"t{i}": np.asarray(state.get(f"t{i-1}", 0) + i + 1)}
        tasks.append(SegmentTask(i, (f"t{i-1}",) if i else (),
                                 (f"t{i}",), run))
    return tasks


def test_mesh_straggler_and_backup_events():
    from repro.core.solver.multinode import MultiNodePlan, NodeAssignment
    plan = MultiNodePlan(
        graph_name="synth", mesh=NodeMesh(nodes=2),
        parts=(NodeAssignment(part=0, seg_start=0, seg_stop=1,
                              node_ids=(0,), compute_cycles=1.0,
                              energy_pj=1.0, inbound_bytes=0.0,
                              inbound_hops=0, link_cycles=0.0,
                              onchip_staged=True),
               NodeAssignment(part=1, seg_start=1, seg_stop=2,
                              node_ids=(1,), compute_cycles=1.0,
                              energy_pj=1.0, inbound_bytes=0.0,
                              inbound_hops=0, link_cycles=0.0,
                              onchip_staged=True)),
        bottleneck_cycles=1.0, latency_cycles=1.0, total_energy_pj=1.0,
        link_bytes=0.0, est_cost=1.0)
    det = StragglerDetector(factor=1.5, warmup=1)
    for _ in range(3):
        det.record("node1", 0.5)
        det.record("node0", 0.01)
    tasks = _synth_tasks(2, seconds_by_node=(("node1", 0.4),))
    t = trace.enable()
    try:
        with MeshExecutor(plan, tasks, detector=det,
                          min_backup_deadline_s=0.05) as ex:
            r = ex.run({}, "r0")
    finally:
        trace.disable()
    assert r.backups >= 1
    (flag,) = t.find("mesh.straggler")
    assert flag["args"]["node"] == 1 and "fleet median" in \
        flag["args"]["reason"]
    (race,) = t.find("mesh.backup_dispatch")
    assert race["args"]["primary"] == 1 and race["args"]["backup"] == 0
    assert race["args"]["winner"] == 0          # healthy peer won
    assert "straggler" in race["args"]["reason"]


@pytest.mark.chaos
def test_mesh_crash_emits_kill_and_repartition_events(solved):
    net, sched = solved
    plan = plan_multinode(sched, net, HW, NodeMesh(nodes=4))
    victim = plan.parts[0].node_ids[0]
    faults = FaultPlan.make(1, {"node.crash": FaultSpec(
        rate=1.0, match=f"node{victim}")})
    t = trace.enable()
    try:
        with MeshExecutor(plan, _synth_tasks(plan.n_segments),
                          schedule=sched, graph=net, hw=HW) as ex:
            with inject(faults):
                r = ex.run({}, "r0")
    finally:
        trace.disable()
    assert not r.degraded and r.replays >= 1
    kills = t.find("mesh.node_killed")
    assert any(e["args"]["node"] == victim for e in kills)
    (rep,) = t.find("mesh.repartition")
    assert rep["args"]["dead"] == victim
    assert rep["args"]["dirty_segments"] >= 1
    assert rep["args"]["survivors"] == 3
    assert rep["args"]["reason"]            # the NodeFailure text
    assert t.find("fault.injected")
    # the request span carries the recovery telemetry
    (req,) = t.find("mesh.request")
    assert req["args"]["replays"] >= 1 and not req["args"]["degraded"]


def test_mesh_fallback_event_without_repartition_context():
    from repro.core.solver.multinode import MultiNodePlan, NodeAssignment
    plan = MultiNodePlan(
        graph_name="synth", mesh=NodeMesh(nodes=2),
        parts=(NodeAssignment(part=0, seg_start=0, seg_stop=1,
                              node_ids=(0,), compute_cycles=1.0,
                              energy_pj=1.0, inbound_bytes=0.0,
                              inbound_hops=0, link_cycles=0.0,
                              onchip_staged=True),
               NodeAssignment(part=1, seg_start=1, seg_stop=2,
                              node_ids=(1,), compute_cycles=1.0,
                              energy_pj=1.0, inbound_bytes=0.0,
                              inbound_hops=0, link_cycles=0.0,
                              onchip_staged=True)),
        bottleneck_cycles=1.0, latency_cycles=1.0, total_energy_pj=1.0,
        link_bytes=0.0, est_cost=1.0)
    t = trace.enable()
    try:
        with MeshExecutor(plan, _synth_tasks(2)) as ex:
            ex.pool.kill(1, "chaos: manual kill")
            r = ex.run({}, "r0")
    finally:
        trace.disable()
    assert r.degraded
    (kill,) = t.find("mesh.node_killed")
    assert kill["args"] == {"node": 1, "reason": "chaos: manual kill"}
    (fb,) = t.find("mesh.fallback")
    assert "no re-partition context" in fb["args"]["reason"]
    # the last rung runs inline on the driver, visible as its own row
    assert any(e["args"]["node"] == "driver" for e in t.find("mesh.task"))


# ---------------------------------------------------------------------------
# re-homed stats() views + solver counters
# ---------------------------------------------------------------------------

def test_store_stats_rehomed_on_registry(tmp_path):
    store = ScheduleStore(str(tmp_path))
    shared = REGISTRY.get("store_events_total")
    before = shared.value(event="misses")
    client = LocalClient(store)
    client.solve(get_net("mlp", batch=8), HW)
    client.solve(get_net("mlp", batch=8), HW)
    st = store.stats()
    assert st["hits"] >= 1 and st["misses"] >= 1    # legacy shape intact
    assert store.hits == st["hits"]                 # thin property view
    assert shared.value(event="misses") > before    # mirrored globally


def test_solver_counters_and_spans(tmp_path):
    seg = metrics.counter("solver_segments_total",
                          "segment solves, by outcome", ("outcome",))
    cand = metrics.counter("solver_candidates_total",
                           "DP chain candidates, by pruning stage",
                           ("stage",))
    memo.clear_all()
    b_seg = sum(s["value"] for s in seg.series())
    b_enum = cand.value(stage="enumerated")
    t = trace.enable()
    try:
        sched = solve(get_net("mlp", batch=8), HW)
    finally:
        trace.disable()
    assert sched.valid
    assert sum(s["value"] for s in seg.series()) > b_seg
    assert cand.value(stage="enumerated") > b_enum
    assert cand.value(stage="enumerated") >= cand.value(stage="valid") \
        >= cand.value(stage="kept")         # pruning funnel is monotone
    counts = t.counts()
    assert counts.get("solve.segment", 0) >= 1
    assert counts.get("solve.dp", 0) >= 1
    assert counts.get("dp.enumerate", 0) >= 1
    memo_metric = REGISTRY.get("solver_memo_total")
    assert memo_metric.value(cache="intra", outcome="miss") >= 1


# ---------------------------------------------------------------------------
# predicted-vs-measured drift
# ---------------------------------------------------------------------------

def test_latency_drift_histogram_and_event():
    h = REGISTRY.get("latency_drift_ratio")
    before = h.value(source="unit", backend="interpret")
    t = trace.enable()
    try:
        ratio = record_latency_drift(0.010, 0.012, source="unit")
    finally:
        trace.disable()
    assert ratio == pytest.approx(1.2)
    assert h.value(source="unit", backend="interpret") == before + 1
    (ev,) = t.find("netexec.latency_drift")
    assert ev["args"]["source"] == "unit"
    assert ev["args"]["backend"] == "interpret"
    assert ev["args"]["ratio"] == pytest.approx(1.2, abs=1e-3)
    # the exec backend is a first-class drift dimension: compiled-tier
    # observations land in their own series
    b_compiled = h.value(source="unit", backend="compiled")
    record_latency_drift(0.010, 0.011, source="unit", backend="compiled")
    assert h.value(source="unit", backend="compiled") == b_compiled + 1
    assert h.value(source="unit", backend="interpret") == before + 1
    # degenerate inputs are refused, not observed
    assert record_latency_drift(0.0, 1.0, source="unit") is None
    assert record_latency_drift(1.0, float("nan"), source="unit") is None
    assert h.value(source="unit", backend="interpret") == before + 1


# ---------------------------------------------------------------------------
# CLIs
# ---------------------------------------------------------------------------

def test_obs_cli_summarize_and_metrics(tmp_path, capsys):
    from repro.obs.__main__ import main
    path = str(tmp_path / "t.json")
    with trace.tracing(path):
        with trace.span("a.b"):
            pass
        trace.instant("a.mark", reason="x")
    assert main(["summarize", path]) == 0
    out = capsys.readouterr().out
    assert "a.b" in out and "a.mark" in out and "Perfetto" in out
    assert main(["summarize", path, "--json"]) == 0
    summ = json.loads(capsys.readouterr().out)
    assert summ["spans"]["a.b"]["count"] == 1
    metrics.counter("unit_cli_total").inc()
    assert main(["metrics"]) == 0
    assert "unit_cli_total" in capsys.readouterr().out
    assert main(["metrics", "--prom"]) == 0
    assert "unit_cli_total 1.0" in capsys.readouterr().out


def test_service_cli_stats_json_and_prom(tmp_path, capsys):
    from repro.service.__main__ import main
    root = str(tmp_path / "store")
    assert main(["solve", "--net", "mlp", "--batch", "8",
                 "--store-dir", root]) == 0
    capsys.readouterr()
    assert main(["stats", "--store-dir", root, "--json"]) == 0
    d = json.loads(capsys.readouterr().out)
    assert d["store"]["entries"] == 1
    assert "service_requests_total" in d["metrics"]
    assert "store_events_total" in d["metrics"]
    assert main(["stats", "--store-dir", root, "--prom"]) == 0
    text = capsys.readouterr().out
    assert "# TYPE service_requests_total counter" in text
    assert "service_request_seconds_bucket" in text


# ---------------------------------------------------------------------------
# flight recorder: explain records
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def explained():
    net = get_net("mlp", batch=4)
    memo.clear_all()
    sched = solve(net, HW, max_seg_len=2, explain=True)
    assert sched.valid and sched.explain is not None
    return net, sched


def test_explain_funnel_matches_prune_stats(explained):
    from repro.core.solver.interlayer import PruneStats, segment_pool
    net, sched = explained
    stats = PruneStats()
    segment_pool(net, HW, range(len(net.layers)), max_len=2,
                 stats=stats)
    tot = sched.explain["funnel"]["totals"]
    assert tot["enumerated"] == stats.total
    assert tot["after_validity"] == stats.after_validity
    assert tot["after_pareto"] == stats.after_pareto
    # per-group counts sum to the totals
    groups = sched.explain["funnel"]["groups"]
    assert sum(g["enumerated"] for g in groups) == tot["enumerated"]
    assert sum(g["valid"] for g in groups) == tot["after_validity"]
    assert sum(g["kept"] for g in groups) == tot["after_pareto"]
    # the winner's segment groups are a subset of all groups
    win = sched.explain["funnel"]["winner_groups"]
    chain = {(s.start, s.stop) for s in sched.chain.segments}
    assert {(g["start"], g["stop"]) for g in win} == chain


def test_explain_attribution_sums_to_energy(explained):
    from repro.obs.explain import TERM_ORDER
    _, sched = explained
    winner = sched.explain["winner"]
    attrib = winner["attribution"]
    total = sum(attrib[t] for t in TERM_ORDER)
    assert total == pytest.approx(sched.total_energy_pj, rel=1e-6)
    assert winner["energy_pj"] == pytest.approx(sched.total_energy_pj)
    # per-segment attributions also sum to the whole
    seg_total = sum(sum(s["attribution"][t] for t in TERM_ORDER)
                    for s in winner["segments"])
    assert seg_total == pytest.approx(sched.total_energy_pj, rel=1e-6)


def test_explain_runners_up_are_ranked(explained):
    _, sched = explained
    runners = sched.explain["runners_up"]
    assert runners, "top-k solve should leave runners-up"
    deltas = [r["delta"] for r in runners]
    assert all(d >= 0 for d in deltas)
    assert deltas == sorted(deltas)
    assert [r["rank"] for r in runners] == \
        list(range(2, 2 + len(runners)))


def test_explain_round_trips_through_store(tmp_path, explained):
    from repro.core.solver.kapla import NetworkSchedule
    net, sched = explained
    back = NetworkSchedule.from_json(sched.to_json(), net)
    assert back.explain == sched.explain
    store = ScheduleStore(str(tmp_path))
    rec = store.put(sched, net, HW, {"max_seg_len": 2})
    got = store.get_record(rec.signature)
    assert got.schedule["explain"] == sched.explain


def test_explain_disabled_by_default(solved):
    _, sched = solved
    assert sched.explain is None
    assert "explain" in sched.to_json()     # field persists (as null)


def test_multinode_explain_funnel(explained):
    from repro.obs.explain import ExplainSink, render
    net, sched = explained
    sink = ExplainSink()
    plan = plan_multinode(sched, net, HW, NodeMesh(nodes=4),
                          explain=sink)
    mn = sink.to_json()["multinode"]
    assert mn["funnel"]["total"] >= mn["funnel"]["after_validity"] \
        >= mn["funnel"]["kept"] > 0
    assert mn["winner"]["cost"] == pytest.approx(plan.est_cost)
    # the winning parts cover every segment exactly once, in order
    spans = [(p[0], p[1]) for p in mn["winner"]["parts"]]
    assert spans[0][0] == 0 and spans[-1][1] == plan.n_segments
    assert all(a[1] == b[0] for a, b in zip(spans, spans[1:]))
    assert "multinode:" in render(sink.to_json())


def test_explain_cli_renders_stored_record(tmp_path, capsys, explained):
    from repro.obs.__main__ import main
    net, sched = explained
    store = ScheduleStore(str(tmp_path))
    rec = store.put(sched, net, HW, {"max_seg_len": 2})
    assert main(["explain", rec.signature,
                 "--store-dir", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "candidate funnel" in out and "cost attribution" in out
    # lookup by net name hits the same record
    assert main(["explain", net.name, "--store-dir", str(tmp_path),
                 "--json"]) == 0
    d = json.loads(capsys.readouterr().out)
    assert d == sched.explain


# ---------------------------------------------------------------------------
# histogram quantiles
# ---------------------------------------------------------------------------

def test_histogram_quantiles_interpolate():
    from repro.obs.metrics import series_quantiles
    r = Registry()
    h = r.histogram("q_seconds", "q", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 1.5, 3.0, 3.5):
        h.observe(v)
    # p50: target 2.5 of 5 lands in (1, 2] with cum 1 -> 3
    assert h.quantile(0.5) == pytest.approx(1.75)
    assert h.quantile(0.95) == pytest.approx(3.75)
    # observations past the top bucket clamp to the highest finite bound
    h.observe(100.0)
    assert h.quantile(0.999) == pytest.approx(4.0)
    # the snapshot-series helper agrees with the live one
    (s,) = h.series()
    q = series_quantiles(s)
    assert q["p50"] == pytest.approx(h.quantile(0.5))
    assert q["p95"] == pytest.approx(h.quantile(0.95))
    # empty series
    assert np.isnan(r.histogram("empty_seconds").quantile(0.5))


def test_cli_summarize_surfaces_quantiles(tmp_path, capsys):
    from repro.obs.__main__ import main
    r = Registry()
    h = r.histogram("lat_seconds", "lat", ("source",),
                    buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 1.5, 3.0, 3.5):
        h.observe(v, source="unit")
    path = str(tmp_path / "snap.json")
    with open(path, "w") as f:
        json.dump(r.snapshot(), f)
    assert main(["summarize", path]) == 0
    out = capsys.readouterr().out
    assert "p50=1.75" in out and "p95=3.75" in out
    assert main(["metrics", path]) == 0
    assert "p50=1.75" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# Prometheus text-exposition conformance
# ---------------------------------------------------------------------------

def test_prometheus_label_values_escaped():
    r = Registry()
    r.counter("odd_total", "odd", ("path",)).inc(
        path='a"b\\c\nd')
    text = r.exposition()
    assert 'odd_total{path="a\\"b\\\\c\\nd"} 1.0' in text
    # the raw specials never appear unescaped inside the value
    assert '\n' not in text.split('odd_total{path="', 1)[1] \
        .split('"}')[0]


def test_prometheus_counter_total_suffix():
    r = Registry()
    r.counter("req", "requests").inc()
    r.counter("done_total", "done").inc()
    text = r.exposition()
    # unsuffixed counters gain _total on exposition (sample + metadata)
    assert "# TYPE req_total counter" in text
    assert "req_total 1.0" in text
    assert "req 1.0" not in text.replace("req_total", "")
    # already-suffixed names are not doubled
    assert "done_total_total" not in text
    assert "done_total 1.0" in text


def test_prometheus_histogram_le_ordering_and_inf():
    r = Registry()
    h = r.histogram("lat_seconds", "lat", buckets=(4.0, 1.0, 2.0))
    for v in (0.5, 1.5, 3.0, 9.0):
        h.observe(v)
    text = r.exposition()
    les = [line.split('le="')[1].split('"')[0]
           for line in text.splitlines() if "_bucket" in line]
    assert les == ["1.0", "2.0", "4.0", "+Inf"]     # sorted, +Inf last
    counts = [float(line.rsplit(" ", 1)[1])
              for line in text.splitlines() if "_bucket" in line]
    assert counts == sorted(counts)                 # cumulative
    inf_count = counts[-1]
    (count_line,) = [line for line in text.splitlines()
                     if line.startswith("lat_seconds_count")]
    assert float(count_line.rsplit(" ", 1)[1]) == inf_count == 4


# ---------------------------------------------------------------------------
# trace analytics: self time + critical path
# ---------------------------------------------------------------------------

def _x(name, ts, dur, tid=1):
    return {"name": name, "ph": "X", "pid": 1, "tid": tid,
            "ts": ts, "dur": dur, "args": {}}


def test_self_times_subtract_children():
    events = [_x("root", 0.0, 100.0), _x("child", 10.0, 60.0),
              _x("leaf", 20.0, 30.0), _x("other", 0.0, 5.0, tid=2)]
    st = trace.self_times(events)
    assert st["root"]["self_us"] == pytest.approx(40.0)
    assert st["child"]["self_us"] == pytest.approx(30.0)
    assert st["leaf"]["self_us"] == pytest.approx(30.0)
    assert st["other"]["self_us"] == pytest.approx(5.0)


def test_critical_path_descends_longest_children():
    events = [_x("root", 0.0, 100.0),
              _x("small", 5.0, 10.0), _x("big", 20.0, 70.0),
              _x("deep", 25.0, 40.0)]
    cp = trace.critical_path(events)
    assert [s["name"] for s in cp] == ["root", "big", "deep"]
    assert cp[0]["frac_of_root"] == pytest.approx(1.0)
    assert cp[1]["frac_of_root"] == pytest.approx(0.7)
    assert trace.critical_path([]) == []


def test_cli_summarize_critical_path(tmp_path, capsys):
    from repro.obs.__main__ import main
    path = str(tmp_path / "t.json")
    with trace.tracing(path):
        with trace.span("outer.op"):
            with trace.span("inner.op"):
                pass
    assert main(["summarize", path, "--critical-path"]) == 0
    out = capsys.readouterr().out
    assert "critical path" in out and "self time" in out
    assert main(["summarize", path, "--critical-path", "--json"]) == 0
    d = json.loads(capsys.readouterr().out)
    assert [s["name"] for s in d["critical_path"]] == \
        ["outer.op", "inner.op"]
    assert "outer.op" in d["self_times"]


# ---------------------------------------------------------------------------
# drift watchdog
# ---------------------------------------------------------------------------

def _synthetic_calibration(n=12):
    """A healthy calibration record: measurements that ARE the affine
    model (plus deterministic jitter), with matching coefficients."""
    from repro.obs.watch import rank_correlation
    a_c, a_d, a_g, a_s, b = 2e-8, 1e-8, 5e-8, 1e-4, -0.002
    pairs = []
    for i in range(1, n + 1):
        cyc_c, cyc_d, cyc_g = 1e5 * i, 4e4 * i * i, 2e4 * i
        steps = 10 * i
        measured = (a_c * cyc_c + a_d * cyc_d + a_g * cyc_g
                    + a_s * steps + b) * (1.0 + 0.01 * ((i % 3) - 1))
        pairs.append({"cyc_compute": cyc_c, "cyc_dram": cyc_d,
                      "cyc_gbuf": cyc_g, "grid_steps": steps,
                      "measured_seconds": measured})
    cal = {"a_compute": a_c, "a_dram": a_d, "a_gbuf": a_g,
           "a_step": a_s, "intercept": b, "backend": "interpret"}
    pred = [a_c * p["cyc_compute"] + a_d * p["cyc_dram"]
            + a_g * p["cyc_gbuf"] + a_s * p["grid_steps"] + b
            for p in pairs]
    return {"backend": "interpret", "pairs": pairs, "calibration": cal,
            "spearman_calibrated": rank_correlation(
                pred, [p["measured_seconds"] for p in pairs])}


def test_watch_passes_healthy_calibration():
    from repro.obs import watch
    findings = []
    out = watch.check_calibration_record(_synthetic_calibration(),
                                         "healthy", findings)
    assert out["ok"] and not findings
    assert out["r2"] > 0.9 and out["rank_corr"] > 0.9


def test_watch_flags_seeded_corrupted_calibration(tmp_path, capsys):
    from repro.obs import watch
    from repro.obs.__main__ import main
    # seeded fault: corrupt one fitted coefficient by 100x — every
    # field still "looks" plausible, only the fit quality betrays it
    bad = _synthetic_calibration()
    bad["calibration"]["a_dram"] *= 100.0
    findings = []
    out = watch.check_calibration_record(bad, "corrupt", findings)
    assert not out["ok"]
    assert any(f["severity"] == "error" for f in findings)
    # ...and through the CLI, --gate turns that into a non-zero exit
    good_p = str(tmp_path / "good.json")
    bad_p = str(tmp_path / "bad.json")
    with open(good_p, "w") as f:
        json.dump(_synthetic_calibration(), f)
    with open(bad_p, "w") as f:
        json.dump(bad, f)
    assert main(["watch", "--calibration", good_p, "--gate"]) == 0
    capsys.readouterr()
    drift_out = str(tmp_path / "BENCH_drift.json")
    assert main(["watch", "--calibration", bad_p, "--gate",
                 "--out", drift_out]) == 1
    assert "FAILING" in capsys.readouterr().out
    with open(drift_out) as f:
        report = json.load(f)
    assert not report["ok"] and report["n_errors"] >= 1


def test_watch_flags_stale_calibration_record():
    from repro.obs import watch
    rec = _synthetic_calibration()
    rec["spearman_calibrated"] = 0.2    # stored fit != its own pairs
    findings = []
    watch.check_calibration_record(rec, "stale", findings)
    assert any("stale" in f["message"] for f in findings)


def test_watch_bench_regression_quality_vs_timing():
    from repro.obs import watch
    base = {"spearman_network": 0.95, "cold_seconds": 0.5,
            "nested": {"availability": 1.0}}
    # quality drop -> error; timing growth -> warning
    cur = {"spearman_network": 0.4, "cold_seconds": 2.0,
           "nested": {"availability": 1.0}}
    findings = []
    out = watch.check_bench_regression("b", cur, base, findings)
    assert not out["ok"]
    sev = {f["message"].split(":")[0]: f["severity"] for f in findings}
    assert sev["spearman_network"] == "error"
    assert sev["cold_seconds"] == "warn"
    # within tolerance -> clean
    findings = []
    out = watch.check_bench_regression("b", dict(base), base, findings)
    assert out["ok"] and not findings


def test_watch_drift_quantiles_and_rolling_baseline():
    from repro.obs import watch
    reg = Registry()
    h = reg.histogram("latency_drift_ratio", "drift",
                      ("source", "backend"),
                      buckets=metrics.DRIFT_BUCKETS)
    for r in (0.95, 1.0, 1.05, 1.1):
        h.observe(r, source="unit", backend="interpret")
    drift = watch.drift_from_snapshot(reg.snapshot())
    key = "unit|interpret"
    assert drift[key]["count"] == 4
    assert 0.8 < drift[key]["p50"] < 1.2
    # first pass seeds the baseline, a 3x shift on the next flags it
    state = {"baselines": {}}
    findings = []
    watch.update_baselines(state, drift, findings)
    assert not findings
    shifted = {key: {"count": 4, "p50": drift[key]["p50"] * 3.0,
                     "p95": 3.0, "p99": 3.0}}
    watch.update_baselines(state, shifted, findings)
    assert findings and findings[0]["check"] == "drift"
    assert state["baselines"][key]["n"] == 2


def test_watch_sample_ring_feeds_from_netexec():
    from repro.obs import watch
    watch.clear_samples()
    record_latency_drift(0.010, 0.012, source="ring", backend="unit")
    record_latency_drift(0.010, 0.014, source="ring", backend="unit")
    rep = watch.samples_report()
    assert rep["ring|unit"]["count"] == 2
    assert rep["ring|unit"]["median_ratio"] == pytest.approx(1.3)
    watch.clear_samples()
    assert watch.samples_report() == {}
