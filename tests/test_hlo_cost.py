"""HLO cost model: while-loop trip accounting, dot flops, collectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import HloCostModel, analyze_hlo
from repro.launch.roofline import collective_bytes


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_vs_unrolled_flops_agree():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)

    def scanned(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, ws)[0]

    def unrolled(x, ws):
        h = x
        for i in range(8):
            h = jnp.tanh(h @ ws[i])
        return h

    fs = analyze_hlo(_compile(scanned, x, ws).as_text()).flops
    fu = analyze_hlo(_compile(unrolled, x, ws).as_text()).flops
    expected = 2 * 128 * 256 * 256 * 8
    assert abs(fs - expected) / expected < 0.02
    assert abs(fs - fu) / fu < 0.02


def test_xla_cost_analysis_undercounts_scan():
    """Documents WHY hlo_cost exists."""
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)

    def scanned(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, ws)[0]

    c = _compile(scanned, x, ws)
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):     # older jax returns [dict]
        ca = ca[0] if ca else {}
    xla_flops = ca.get("flops", 0)
    ours = analyze_hlo(c.as_text()).flops
    assert ours > 5 * xla_flops           # 8 trips vs 1


def test_dot_flops_exact_single():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = _compile(lambda a, b: a @ b, a, b)
    flops = analyze_hlo(c.as_text()).flops
    assert flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_nested_scans_multiply():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def nested(x):
        def outer(h, _):
            def inner(h2, _):
                return jnp.tanh(h2 @ h2), None
            h, _ = jax.lax.scan(inner, h, None, length=4)
            return h, None
        return jax.lax.scan(outer, x, None, length=3)[0]

    flops = analyze_hlo(_compile(nested, x).as_text()).flops
    expected = 2 * 32 * 32 * 32 * 12      # 3 x 4 dots
    assert abs(flops - expected) / expected < 0.05


def test_bytes_reasonable_for_elementwise():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _compile(lambda x: x + 1.0, x)
    b = analyze_hlo(c.as_text()).bytes
    # read + write = 8 MiB; allow generous slack for copies
    assert 4e6 < b < 4e7
