"""Detailed cost model invariants."""
import pytest

from repro.core.cost_model import combine_segment, evaluate_layer
from repro.core.directives import LayerScheme, LevelBlocking
from repro.core.solver import Constraints, solve_intra_layer
from repro.hw.presets import eyeriss_multinode, tpu_like_edge
from repro.workloads.layers import conv, fc


HW = eyeriss_multinode()


def test_capacity_violation_detected():
    layer = fc("f", 64, 4096, 4096)
    lvls = [LevelBlocking(t={"C": 4096, "K": 4096}), LevelBlocking(),
            LevelBlocking(t={"N": 64})]
    cost = evaluate_layer(LayerScheme(layer, lvls), HW)
    assert not cost.valid
    assert "overflow" in cost.reason


def test_factor_mismatch_detected():
    layer = fc("f", 64, 128, 128)
    lvls = [LevelBlocking(), LevelBlocking(), LevelBlocking(t={"N": 32})]
    cost = evaluate_layer(LayerScheme(layer, lvls), HW)
    assert not cost.valid


def test_solved_scheme_valid_and_positive():
    layer = conv("c", 64, 96, 256, 27, 27, 5, 5)
    sch, cost = solve_intra_layer(layer, HW)
    assert cost.valid
    assert cost.energy_pj > 0 and cost.latency_cycles > 0
    assert cost.pes_used <= HW.num_pes_per_node
    assert cost.nodes_used <= HW.num_nodes
    # energy components sum to the total
    total = (cost.mac_energy + cost.regf_energy + cost.gbuf_energy +
             cost.noc_energy + cost.dram_energy)
    assert cost.energy_pj == pytest.approx(total)


def test_more_nodes_never_hurts_latency():
    layer = conv("c", 64, 96, 256, 27, 27, 5, 5)
    _, c_small = solve_intra_layer(layer, HW, Constraints(nodes=(4, 4)))
    _, c_big = solve_intra_layer(layer, HW, Constraints(nodes=(16, 16)))
    assert c_big.latency_cycles <= c_small.latency_cycles * 1.05


def test_onchip_forwarding_saves_dram():
    layer = conv("c", 64, 96, 256, 27, 27, 5, 5)
    sch, _ = solve_intra_layer(layer, HW)
    off = evaluate_layer(sch, HW)
    on = evaluate_layer(sch, HW, src_onchip=True, dst_onchip=True)
    assert on.dram_traffic_bytes < off.dram_traffic_bytes
    assert on.dram_energy < off.dram_energy


def test_combine_segment_pipeline_fill():
    layer = fc("f", 64, 512, 512)
    sch, cost = solve_intra_layer(layer, HW, Constraints(nodes=(16, 8)))
    seg2 = combine_segment([cost, cost], granules=64)
    assert seg2.energy_pj == pytest.approx(2 * cost.energy_pj)
    # pipelined latency < serial sum, > single layer
    assert cost.latency_cycles < seg2.latency_cycles
    assert seg2.latency_cycles < 2 * cost.latency_cycles


def test_edge_hw_template():
    layer = conv("c", 1, 64, 128, 28, 28, 3, 3)
    sch, cost = solve_intra_layer(layer, tpu_like_edge(),
                                  Constraints(nodes=(1, 1)))
    assert cost.valid
    assert cost.pes_used <= 256
