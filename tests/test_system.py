"""System-level behaviour: the paper's pipeline end to end."""
import pytest

from repro.core.solver import solve
from repro.core.solver import random_search
from repro.hw.presets import eyeriss_multinode
from repro.workloads.nets import NETS, get_net


def test_full_suite_schedules_under_a_minute_each():
    hw = eyeriss_multinode()
    for name in NETS:
        net = get_net(name, batch=64)
        res = solve(net, hw)
        assert res.valid, name
        assert res.solve_seconds < 60, (name, res.solve_seconds)


def test_directive_dump_for_best_scheme():
    hw = eyeriss_multinode()
    net = get_net("alexnet", batch=64)
    res = solve(net, hw)
    sch = res.layer_schemes["conv2"]
    dirs = sch.to_directives(["REGF", "GBUF", "DRAM"])
    text = "\n".join(str(d) for d in dirs)
    # the three directive kinds all appear (paper Listing 1 structure)
    assert "tensor{" in text
    assert "stack(" in text
    assert "update(" in text


def test_energy_ordering_kapla_vs_random():
    hw = eyeriss_multinode()
    net = get_net("lstm", batch=64)
    k = solve(net, hw)
    r = random_search.solve(net, hw, samples=300, seed=3)
    assert k.total_energy_pj <= r.total_energy_pj * 1.001
