"""Multi-node tier: mesh partitioning, the resilient mesh executor, the
node-level fault sites and the service's ``nodes=N`` path."""
import threading
import time

import numpy as np
import pytest

from repro.core.solver import solve
from repro.core.solver.kapla import NetworkSchedule
from repro.core.solver.multinode import (MultiNodePlan, NodeAssignment,
                                         NodeMesh, cross_segment_bytes,
                                         plan_multinode, repartition,
                                         segment_costs)
from repro.lower.calibrate import default_hw
from repro.lower.meshexec import (MeshExecutor, NodePool, SegmentTask,
                                  build_segment_tasks)
from repro.lower.netexec import execute_network, make_network_inputs
from repro.runtime.fault import ElasticPlanner, NodeFailure
from repro.runtime.inject import (SITES, FaultInjector, FaultPlan,
                                  FaultSpec, inject)
from repro.runtime.straggler import StragglerDetector
from repro.workloads.nets import get_net

HW = default_hw()


@pytest.fixture(scope="module")
def solved():
    net = get_net("mlp", batch=4)
    sched = solve(net, HW, max_seg_len=2)
    assert sched.valid
    return net, sched


@pytest.fixture(scope="module")
def solved_b3():
    net = get_net("mlp", batch=3)
    sched = solve(net, HW, max_seg_len=1)
    assert sched.valid
    return net, sched


# ---------------------------------------------------------------------------
# the mesh + solver tier
# ---------------------------------------------------------------------------

def test_mesh_hops_by_topology():
    ring = NodeMesh(nodes=6, topology="ring")
    assert ring.hops(0, 0) == 0
    assert ring.hops(0, 5) == 1            # wraps around
    assert ring.hops(0, 3) == 3
    chain = NodeMesh(nodes=6, topology="chain")
    assert chain.hops(0, 5) == 5
    full = NodeMesh(nodes=6, topology="full")
    assert full.hops(0, 5) == 1
    with pytest.raises(ValueError):
        NodeMesh(nodes=0)
    with pytest.raises(ValueError):
        NodeMesh(topology="torus")


def test_plan_covers_chain_contiguously(solved):
    net, sched = solved
    mesh = NodeMesh(nodes=4)
    plan = plan_multinode(sched, net, HW, mesh)
    S = len(sched.chain.segments)
    assert plan.n_segments == S
    covered = []
    for p in plan.parts:
        covered.extend(range(p.seg_start, p.seg_stop))
        assert p.node_ids and all(0 <= n < 4 for n in p.node_ids)
    assert covered == list(range(S))       # contiguous, complete
    assert plan.nodes_used <= mesh.nodes
    assert plan.prune.total >= plan.prune.after_validity > 0
    for s in range(S):
        assert plan.part_of_segment(s).seg_start <= s
    with pytest.raises(KeyError):
        plan.part_of_segment(S)


def test_replicate_width_divides_batch(solved_b3):
    net, sched = solved_b3
    plan = plan_multinode(sched, net, HW, NodeMesh(nodes=4))
    for p in plan.parts:
        assert 3 % p.width == 0            # batch 3: widths in {1, 3}
    # invalid widths were enumerated but pruned by validity
    assert plan.prune.after_validity < plan.prune.total


def test_link_bandwidth_and_hops_are_cost_terms(solved_b3):
    net, sched = solved_b3
    fat = plan_multinode(sched, net, HW,
                         NodeMesh(nodes=4, link_bandwidth_bytes_per_cycle=1e9))
    thin = plan_multinode(sched, net, HW,
                          NodeMesh(nodes=4,
                                   link_bandwidth_bytes_per_cycle=1e-3))
    # same partitioning question, slower links: never a better answer
    assert thin.est_cost >= fat.est_cost
    # with free links the pipeline splits across nodes
    assert len(fat.parts) > 1
    # the thin plan either collapses parts or pays visible link cycles
    assert len(thin.parts) < len(fat.parts) \
        or any(p.link_cycles > 0 for p in thin.parts)
    ranges = [(c.start, c.stop) for c in segment_costs(sched, net)]
    flows = cross_segment_bytes(net, ranges)
    assert flows                            # mlp chains segment to segment
    assert all(b > 0 for b in flows.values())


def test_objectives(solved):
    net, sched = solved
    lat = plan_multinode(sched, net, HW, NodeMesh(nodes=4),
                         objective="latency")
    thr = plan_multinode(sched, net, HW, NodeMesh(nodes=4),
                         objective="throughput")
    assert lat.latency_cycles <= thr.latency_cycles + 1e-9
    assert thr.bottleneck_cycles <= lat.bottleneck_cycles + 1e-9
    with pytest.raises(ValueError):
        plan_multinode(sched, net, HW, objective="speed")


def test_plan_without_chain_uses_singleton_segments(solved):
    import dataclasses
    net, sched = solved
    # schedules without a chain (e.g. greedy per-layer answers) fall back
    # to one segment per layer (netplan's rule, mirrored so indices align)
    bare = dataclasses.replace(sched, chain=None)
    plan = plan_multinode(bare, net, HW, NodeMesh(nodes=4))
    assert plan.n_segments == len(net.layers)


def test_plan_to_json(solved):
    net, sched = solved
    plan = plan_multinode(sched, net, HW, NodeMesh(nodes=4))
    d = plan.to_json()
    assert d["mesh"]["nodes"] == 4
    assert len(d["parts"]) == len(plan.parts)
    assert d["nodes_used"] == plan.nodes_used


def test_repartition_is_incremental(solved_b3):
    net, sched = solved_b3
    plan = plan_multinode(sched, net, HW, NodeMesh(nodes=4))
    assert len(plan.parts) > 1
    victim_part = plan.parts[-1]
    victim = victim_part.node_ids[0]
    survivors = [n for n in range(4) if n != victim]
    new_plan, dirty = repartition(plan, sched, net, HW, survivors)
    # only the victim's segments are dirty
    assert dirty == list(range(victim_part.seg_start,
                               victim_part.seg_stop))
    # untouched parts keep their node assignments verbatim
    for old, new in zip(plan.parts, new_plan.parts):
        if victim not in old.node_ids:
            assert new.node_ids == old.node_ids
        else:
            assert victim not in new.node_ids
            assert set(new.node_ids) <= set(survivors)
    assert new_plan.n_segments == plan.n_segments


def test_repartition_no_survivors_raises(solved):
    net, sched = solved
    plan = plan_multinode(sched, net, HW, NodeMesh(nodes=4))
    with pytest.raises(NodeFailure) as ei:
        repartition(plan, sched, net, HW, survivors=[])
    assert ei.value.permanent
    with pytest.raises(ValueError):
        repartition(plan, sched, net, HW, survivors=[7])


# ---------------------------------------------------------------------------
# node-level fault sites
# ---------------------------------------------------------------------------

def test_node_sites_registered():
    for site in ("node.crash", "node.hang", "node.slow"):
        assert site in SITES
        FaultPlan.make(0, {site: FaultSpec(rate=1.0)})   # accepted


def test_fault_spec_after_and_match_are_deterministic():
    plan = FaultPlan.make(3, {"node.crash": FaultSpec(rate=1.0, after=2,
                                                      match="node1")})
    inj = FaultInjector(plan)
    got = [(key, inj.decide("node.crash", key) is not None)
           for key in ["node0", "node1", "node1", "node0", "node1",
                       "node1"]]
    # node0 never matches; node1 spared until occurrence 2 (0-based)
    assert got == [("node0", False), ("node1", False), ("node1", False),
                   ("node0", False), ("node1", True), ("node1", True)]
    # same plan, same schedule (replayable)
    inj2 = FaultInjector(plan)
    assert [inj2.decide("node.crash", k) is not None
            for k, _ in got] == [f for _, f in got]


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(rate=1.0, after=-1)
    with pytest.raises(ValueError):
        FaultSpec(rate=1.0, factor=-0.5)
    s = FaultSpec(rate=1.0, kind="slow", factor=5.0, match="node2")
    assert s.factor == 5.0


# ---------------------------------------------------------------------------
# the resilient executor (synthetic tasks: fast, no jax)
# ---------------------------------------------------------------------------

def synth_plan(parts_spec, nodes=4):
    parts = []
    seg = 0
    for pi, (nseg, node_ids) in enumerate(parts_spec):
        parts.append(NodeAssignment(
            part=pi, seg_start=seg, seg_stop=seg + nseg,
            node_ids=tuple(node_ids), compute_cycles=100.0, energy_pj=1.0,
            inbound_bytes=0.0, inbound_hops=0, link_cycles=0.0,
            onchip_staged=True))
        seg += nseg
    return MultiNodePlan(
        graph_name="synth", mesh=NodeMesh(nodes=nodes),
        parts=tuple(parts), bottleneck_cycles=100.0, latency_cycles=100.0,
        total_energy_pj=1.0, link_bytes=0.0, est_cost=100.0)


def synth_tasks(n, log=None, seconds=0.0):
    tasks = []
    for i in range(n):
        def run(state, i=i):
            if log is not None:
                log.append((i, threading.current_thread().name))
            if seconds:
                time.sleep(seconds)
            return {f"t{i}": np.asarray(state.get(f"t{i-1}", 0) + i + 1)}
        tasks.append(SegmentTask(i, (f"t{i-1}",) if i else (),
                                 (f"t{i}",), run))
    return tasks


def test_executor_fault_free_runs_on_assigned_nodes():
    log = []
    plan = synth_plan([(1, (0,)), (1, (1,)), (1, (2,))])
    with MeshExecutor(plan, synth_tasks(3, log)) as ex:
        r = ex.run({}, "r0")
    assert int(r.outputs["t2"]) == 1 + 2 + 3
    assert not r.degraded and r.replays == 0 and r.backups == 0
    threads = {i: t for i, t in log}
    assert threads[0].startswith("node0")
    assert threads[1].startswith("node1")
    assert threads[2].startswith("node2")


def test_executor_replicated_part_round_robins_requests():
    log = []
    plan = synth_plan([(2, (0, 1, 2, 3))])
    with MeshExecutor(plan, synth_tasks(2, log)) as ex:
        for i in range(4):
            ex.run({}, f"r{i}")
    # each request sticks to one replica; requests spread across the group
    assert len({t.split("_")[0] for _, t in log}) > 1


def test_executor_dead_assignment_falls_back_without_context():
    # no schedule/graph/hw: the repartition rung is unavailable, so a
    # lost node drops straight to the single-node fallback — degraded,
    # but the request still completes with correct outputs
    plan = synth_plan([(1, (0,)), (1, (1,))])
    with MeshExecutor(plan, synth_tasks(2)) as ex:
        ex.pool.kill(1, "test")
        r = ex.run({}, "r0")
    assert int(r.outputs["t1"]) == 3
    assert r.degraded and ex.fallback
    assert ex.stats()["degraded_requests"] == 1


@pytest.mark.chaos
def test_executor_repartitions_on_injected_crash(solved):
    net, sched = solved
    plan = plan_multinode(sched, net, HW, NodeMesh(nodes=4))
    victim = plan.parts[0].node_ids[0]
    S = plan.n_segments
    faults = FaultPlan.make(1, {"node.crash": FaultSpec(
        rate=1.0, match=f"node{victim}")})
    with MeshExecutor(plan, synth_tasks(S), schedule=sched, graph=net,
                      hw=HW) as ex:
        with inject(faults) as inj:
            r = ex.run({}, "r0")
        assert int(r.outputs[f"t{S-1}"]) == sum(range(1, S + 1))
        assert not r.degraded              # survivors absorbed the loss
        assert r.replays >= 1              # replayed from the boundary
        st = ex.stats()
        assert st["failures"] >= 1
        assert st["repartitions"] >= 1
        assert st["resolved_segments"] >= 1
        assert victim not in st["alive_nodes"]
        # the drained node's straggler history was forgotten
        assert f"node{victim}" not in st["straggler"]["hosts"]
        assert inj.fired.get("node.crash", 0) >= 1
        # repartitioned plan no longer references the dead node
        assert all(victim not in p.node_ids for p in ex.plan.parts)


@pytest.mark.chaos
def test_executor_hang_drains_node(solved):
    net, sched = solved
    plan = plan_multinode(sched, net, HW, NodeMesh(nodes=4))
    victim = plan.parts[0].node_ids[0]
    S = plan.n_segments
    faults = FaultPlan.make(1, {"node.hang": FaultSpec(
        rate=1.0, kind="slow", delay_s=5.0, match=f"node{victim}")})
    with MeshExecutor(plan, synth_tasks(S), schedule=sched, graph=net,
                      hw=HW, task_timeout_s=0.3) as ex:
        with inject(faults):
            r = ex.run({}, "r0")
    assert int(r.outputs[f"t{S-1}"]) == sum(range(1, S + 1))
    assert not r.degraded
    assert ex.pool.is_dead(victim)         # hung -> drained
    assert ex.stats()["repartitions"] >= 1


def test_executor_straggler_feeds_backup_dispatch():
    plan = synth_plan([(1, (0,)), (1, (1,))])
    slow = {"nid": 1}

    def run(state, _slow=slow):
        if threading.current_thread().name.startswith(
                f"node{_slow['nid']}"):
            time.sleep(0.4)
        return {"t1": np.asarray(7)}

    tasks = [synth_tasks(1)[0],
             SegmentTask(1, ("t0",), ("t1",), run)]
    det = StragglerDetector(factor=1.5, warmup=1)
    for _ in range(3):
        det.record("node1", 0.5)           # node1 is already notorious
        det.record("node0", 0.01)
    with MeshExecutor(plan, tasks, detector=det,
                      min_backup_deadline_s=0.05) as ex:
        r = ex.run({}, "r0")
    assert int(r.outputs["t1"]) == 7
    assert r.backups >= 1                  # the healthy peer won the race
    assert not r.degraded
    assert not ex.pool.is_dead(1)          # slow, not dead: never killed


@pytest.mark.chaos
def test_executor_all_nodes_lost_single_node_fallback(solved):
    net, sched = solved
    plan = plan_multinode(sched, net, HW, NodeMesh(nodes=4))
    S = plan.n_segments
    faults = FaultPlan.make(1, {"node.crash": FaultSpec(rate=1.0)})
    planner = ElasticPlanner(model_axis=1, min_data=2)
    with MeshExecutor(plan, synth_tasks(S), schedule=sched, graph=net,
                      hw=HW, planner=planner) as ex:
        with inject(faults):               # every dispatch crashes a node
            r = ex.run({}, "r0")
    assert int(r.outputs[f"t{S-1}"]) == sum(range(1, S + 1))
    assert r.degraded and ex.fallback      # below min_nodes: last rung
    assert ex.stats()["recovery_seconds"] >= 0.0


def test_node_pool_contract():
    with NodePool(2) as pool:
        assert pool.alive() == [0, 1]
        fut = pool.submit(0, lambda: 42)
        assert fut.result() == 42
        pool.kill(0, "test")
        pool.kill(0, "again")              # idempotent
        assert pool.alive() == [1]
        with pytest.raises(NodeFailure) as ei:
            pool.submit(0, lambda: 0)
        assert ei.value.permanent
        pool.set_slow(1, 3.0)
        assert pool.slow_factor(1) == 3.0
    with pytest.raises(ValueError):
        NodePool(0)


# ---------------------------------------------------------------------------
# jax-backed integration: bit-identical under node churn
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lowered(solved):
    net, sched = solved
    nplan = sched.lower(net, HW)
    inputs = make_network_inputs(nplan, seed=0)
    weights = {k: v for k, v in inputs.items() if k.endswith(".W")}
    ext = {k: np.asarray(v) for k, v in inputs.items()
           if k.endswith(".I")}
    tasks = build_segment_tasks(nplan, weights)
    return nplan, inputs, weights, ext, tasks


def test_segment_tasks_match_network_execution(lowered, solved):
    net, sched = solved
    nplan, inputs, _, ext, tasks = lowered
    plan = plan_multinode(sched, net, HW, NodeMesh(nodes=4))
    with MeshExecutor(plan, tasks, schedule=sched, graph=net,
                      hw=HW) as ex:
        r = ex.run(ext, "r0")
    ref = execute_network(nplan, inputs)
    assert r.outputs
    for k, v in r.outputs.items():
        assert np.array_equal(v, np.asarray(ref.outputs[k])), k


@pytest.mark.chaos
def test_mesh_chaos_kill_keeps_results_bit_identical(lowered, solved):
    net, sched = solved
    nplan, _, _, ext, tasks = lowered
    plan0 = plan_multinode(sched, net, HW, NodeMesh(nodes=4))
    with MeshExecutor(plan0, tasks, schedule=sched, graph=net,
                      hw=HW) as ex:
        baseline = {k: np.asarray(v)
                    for k, v in ex.run(ext, "r0").outputs.items()}
    victim = plan0.parts[0].node_ids[0]
    faults = FaultPlan.make(5, {"node.crash": FaultSpec(
        rate=1.0, match=f"node{victim}", after=1)})
    plan1 = plan_multinode(sched, net, HW, NodeMesh(nodes=4))
    with MeshExecutor(plan1, tasks, schedule=sched, graph=net,
                      hw=HW) as ex:
        with inject(faults):
            runs = [ex.run(ext, f"r{i}") for i in range(4)]
        st = ex.stats()
    assert all(not r.degraded for r in runs)
    for r in runs:                         # availability + bit-identity
        for k, v in r.outputs.items():
            assert np.array_equal(np.asarray(v), baseline[k]), k
    assert st["failures"] >= 1
    assert st["repartitions"] >= 1
    # incremental: the re-partition re-placed at most the whole chain
    assert 1 <= st["resolved_segments"] <= st["repartitions"] * len(tasks)


# ---------------------------------------------------------------------------
# the service's nodes=N path
# ---------------------------------------------------------------------------

def test_local_client_nodes_path(tmp_path, solved):
    from repro.service import LocalClient, ScheduleStore
    net, _ = solved
    client = LocalClient(ScheduleStore(tmp_path / "store"))
    res = client.solve(net, HW, nodes=4, max_seg_len=2)
    assert res.mesh_plan is not None
    assert res.nodes == 4
    assert not res.degraded
    assert res.mesh_plan.nodes_used <= 4
    # the signature is node-count-agnostic: a single-node request hits
    # the cache the multi-node request populated
    res1 = client.solve(net, HW, nodes=1, max_seg_len=2)
    assert res1.source == "cached" and res1.mesh_plan is None
    # and a cached multi-node answer still gets its placement attached
    res4 = client.solve(net, HW, nodes=4, max_seg_len=2)
    assert res4.source == "cached" and res4.mesh_plan is not None


def test_nodes_path_falls_back_single_node_degraded(tmp_path, solved,
                                                    monkeypatch):
    import repro.core.solver.multinode as mn
    from repro.service import LocalClient, ScheduleStore
    net, _ = solved

    def boom(*a, **k):
        raise NodeFailure("mesh exploded", permanent=True)

    monkeypatch.setattr(mn, "plan_multinode", boom)
    client = LocalClient(ScheduleStore(tmp_path / "store"))
    res = client.solve(net, HW, nodes=4, max_seg_len=2)
    # one rung down: single-node answer, flagged degraded, never an error
    assert res.schedule.valid
    assert res.mesh_plan is None and res.nodes == 1
    assert res.degraded and "fallback" in res.error


def test_server_nodes_path(tmp_path, solved):
    import asyncio

    from repro.service import (ScheduleStore, SolveRequest, SolveServer,
                               serve_batch)
    net, _ = solved
    server = SolveServer(ScheduleStore(tmp_path / "store"))
    reqs = [SolveRequest.make(net, HW, nodes=4, max_seg_len=2),
            SolveRequest.make(net, HW, max_seg_len=2)]
    r4, r1 = asyncio.run(serve_batch(server, reqs))
    assert r4.mesh_plan is not None and r4.nodes == 4
    assert r1.mesh_plan is None and r1.nodes == 1
