"""Chaos suite: liveness, bounded latency, graceful degradation and
store self-healing invariants under seeded fault schedules
(``repro.runtime.inject``)."""
import asyncio
import json
import os
import time

import pytest

from repro.core.solver import solve
from repro.hw.presets import eyeriss_multinode
from repro.runtime.fault import CircuitBreaker, RecoveryPolicy
from repro.runtime.inject import (FaultInjector, FaultPlan, FaultSpec,
                                  InjectedFault, inject)
from repro.service import (LocalClient, ScheduleStore, ServiceError,
                           ServiceResult, SolveRequest, SolveServer,
                           serve_batch_settled)
from repro.workloads.nets import get_net

pytestmark = pytest.mark.chaos

HW = eyeriss_multinode()
#: zero-backoff retries: chaos tests should not sleep
FAST = RecoveryPolicy(max_retries=3, backoff_seconds=0.0, max_backoff=0.0)


def _plan(seed=7, **sites):
    return FaultPlan.make(seed, sites)


# ---------------------------------------------------------------------------
# injector determinism
# ---------------------------------------------------------------------------

def test_injector_schedule_is_deterministic():
    plan = _plan(seed=42, **{"store.read": FaultSpec(rate=0.5)})
    keys = [f"k{i % 5}" for i in range(40)]
    runs = []
    for _ in range(2):
        inj = FaultInjector(plan)
        fired = []
        for k in keys:
            fired.append(inj.decide("store.read", k) is not None)
        runs.append(fired)
    assert runs[0] == runs[1]
    assert any(runs[0]) and not all(runs[0])    # rate 0.5 really mixes
    # decisions are keyed, not sequenced: reversing call order must not
    # change any per-(key, occurrence) outcome
    inj = FaultInjector(plan)
    rev = {}
    for k in reversed(keys):
        n = sum(1 for kk in rev if kk[0] == k)
        rev[(k, n)] = inj.decide("store.read", k) is not None
    fwd = {}
    for i, (k, f) in enumerate(zip(keys, runs[0])):
        n = sum(1 for j in range(i) if keys[j] == k)
        fwd[(k, n)] = f
    assert fwd == rev


def test_injector_plan_validation():
    with pytest.raises(ValueError):
        FaultPlan.make(0, {"bogus.site": FaultSpec(rate=0.1)})
    with pytest.raises(ValueError):
        FaultSpec(rate=1.5)
    with pytest.raises(ValueError):
        FaultSpec(rate=0.1, kind="explode")


# ---------------------------------------------------------------------------
# liveness under the acceptance fault schedule
# ---------------------------------------------------------------------------

def test_server_liveness_under_store_faults_and_slow_solves(tmp_path):
    """Every request gets a result or a typed error — zero hangs — under
    injected store faults + slow solves; degraded answers are flagged."""
    plan = _plan(
        seed=1234,
        **{"store.read": FaultSpec(rate=0.3, kind="error"),
           "store.write": FaultSpec(rate=0.3, kind="error"),
           "solve.segment": FaultSpec(rate=0.2, kind="slow",
                                      delay_s=0.002)})
    server = SolveServer(ScheduleStore(str(tmp_path)),
                         retry_policy=FAST, batch_window_s=0.001)
    reqs = []
    for i in range(12):
        name, batch = [("mlp", 8), ("mlp", 16), ("lstm", 8)][i % 3]
        reqs.append(SolveRequest.make(get_net(name, batch=batch), HW))

    async def run():
        return await asyncio.wait_for(
            serve_batch_settled(server, reqs), timeout=120)

    with inject(plan) as inj:
        out = asyncio.run(run())
    assert len(out) == len(reqs)
    for r in out:
        assert isinstance(r, (ServiceResult, ServiceError)), r
        if isinstance(r, ServiceResult):
            assert r.schedule.valid
            assert r.degraded == (r.source == "greedy")
    assert inj.fired                    # the schedule really injected
    st = server.stats()
    assert st["requests"] == len(reqs)
    assert st["inflight"] == 0          # liveness: nothing stranded


def test_typed_error_when_every_solve_faults(tmp_path):
    """rate-1.0 solve faults exhaust the whole ladder: the answer is the
    typed ServiceError, never a raw InjectedFault or a hang."""
    plan = _plan(**{"solve.segment": FaultSpec(rate=1.0, kind="error")})
    client = LocalClient(ScheduleStore(str(tmp_path)), retry_policy=FAST)
    with inject(plan):
        with pytest.raises(ServiceError) as ei:
            client.solve(get_net("mlp", batch=8), HW)
    assert "InjectedFault" in ei.value.reason
    assert ei.value.attempts >= 1
    # after the chaos clears, the same client answers normally
    res = client.solve(get_net("mlp", batch=8), HW)
    assert res.source == "cold" and res.schedule.valid


def test_transient_solve_fault_is_retried(tmp_path):
    """A sub-1.0 fault rate means a retry draws fresh randomness: the
    request lands without degradation well within the retry budget."""
    plan = _plan(seed=3,
                 **{"solve.segment": FaultSpec(rate=0.15, kind="error")})
    client = LocalClient(ScheduleStore(str(tmp_path)),
                         retry_policy=RecoveryPolicy(
                             max_retries=8, backoff_seconds=0.0,
                             max_backoff=0.0))
    with inject(plan) as inj:
        res = client.solve(get_net("mlp", batch=8), HW)
    assert res.schedule.valid
    assert inj.fired.get("solve.segment", 0) >= 0   # schedule-dependent
    assert res.source in ("cold", "warm", "greedy")


# ---------------------------------------------------------------------------
# deadlines + degradation ladder
# ---------------------------------------------------------------------------

def test_expired_deadline_degrades_to_greedy(tmp_path):
    client = LocalClient(ScheduleStore(str(tmp_path)))
    res = client.solve(get_net("mlp", batch=8), HW, deadline_s=0.0)
    assert res.source == "greedy" and res.degraded
    assert res.schedule.valid
    assert res.error is None            # deadline, not a fault
    # the greedy answer is NOT cached: a later relaxed request gets the
    # real solve
    res2 = client.solve(get_net("mlp", batch=8), HW)
    assert res2.source in ("cold", "warm")
    assert res2.schedule.total_energy_pj <= res.schedule.total_energy_pj


def test_server_deadline_degrades_to_greedy(tmp_path):
    server = SolveServer(ScheduleStore(str(tmp_path)), retry_policy=FAST,
                         batch_window_s=0.05)
    reqs = [SolveRequest.make(get_net("mlp", batch=8), HW),
            SolveRequest.make(get_net("mlp", batch=16), HW,
                              deadline_s=1e-4)]
    out = asyncio.run(serve_batch_settled(server, reqs))
    ok = [r for r in out if isinstance(r, ServiceResult)]
    assert len(ok) == 2
    by_sig = {r.signature: r for r in ok}
    relaxed = by_sig[reqs[0].signature()]
    rushed = by_sig[reqs[1].signature()]
    assert not relaxed.degraded
    assert rushed.source == "greedy" and rushed.degraded
    assert server.stats()["degraded"] == 1


# ---------------------------------------------------------------------------
# circuit breaker: broken store -> solve-without-caching
# ---------------------------------------------------------------------------

def test_breaker_degrades_to_solve_without_caching(tmp_path):
    plan = _plan(**{"store.read": FaultSpec(rate=1.0, kind="error"),
                    "store.write": FaultSpec(rate=1.0, kind="error")})
    client = LocalClient(
        ScheduleStore(str(tmp_path)), warm_start=False,
        breaker=CircuitBreaker(threshold=2, cooldown_s=60.0),
        retry_policy=FAST)
    with inject(plan):
        for name in ("mlp", "lstm", "mlp"):
            res = client.solve(get_net(name, batch=8), HW)
            assert res.schedule.valid           # served despite the store
            assert res.source == "cold"
            assert res.record is None           # nothing cached
    st = client.stats()
    assert st["store_errors"] >= 2
    assert st["breaker"]["state"] == "open"
    assert st["store_skipped"] >= 1             # open breaker skips I/O
    assert st["entries"] == 0


# ---------------------------------------------------------------------------
# store self-healing
# ---------------------------------------------------------------------------

def _put_one(root, name="mlp", batch=8):
    store = ScheduleStore(root)
    net = get_net(name, batch=batch)
    rec = store.put(solve(net, HW), net, HW)
    return store, rec


def test_corrupt_record_is_quarantined_and_recovers(tmp_path):
    store, rec = _put_one(str(tmp_path))
    path = store._rec_path(rec.signature)
    with open(path, "w") as f:
        f.write("{ this is not json")
    assert store.get(rec.signature) is None
    st = store.stats()
    assert st["corrupt"] == 1 and st["quarantined"] == 1
    assert os.path.exists(os.path.join(store.quarantine_dir,
                                       f"{rec.signature}.json"))
    assert not store.has(rec.signature)
    # the service transparently re-solves and re-populates
    client = LocalClient(store)
    res = client.solve(get_net("mlp", batch=8), HW)
    assert res.source == "cold" and store.has(rec.signature)
    assert store.get(rec.signature) is not None


def test_checksum_catches_silent_bitflip(tmp_path):
    store, rec = _put_one(str(tmp_path))
    path = store._rec_path(rec.signature)
    with open(path) as f:
        d = json.load(f)
    d["predicted_energy_pj"] = d["predicted_energy_pj"] + 1.0
    with open(path, "w") as f:
        json.dump(d, f)                 # valid JSON, wrong bytes
    assert store.get(rec.signature) is None
    assert store.stats()["corrupt"] == 1


def test_damaged_index_rebuilds_from_records(tmp_path):
    store, rec = _put_one(str(tmp_path), "mlp", 8)
    net16 = get_net("mlp", batch=16)
    store.put(solve(net16, HW), net16, HW)
    with open(store.index_path, "w") as f:
        f.write('{"sig": "torn-and-inval\x00')    # garbage index
    store2 = ScheduleStore(str(tmp_path))
    assert store2.stats()["rebuilds"] == 1
    assert len(store2) == 2
    fam = store2.family(get_net("mlp", batch=8), HW)
    assert len(store2.warm_records(fam)) == 2
    # rebuilt index parses clean end-to-end
    with open(store2.index_path) as f:
        assert all(json.loads(l) for l in f if l.strip())


def test_crash_mid_put_leaves_loadable_store(tmp_path):
    """A writer killed mid-put (torn record + torn index tail + strewn
    tmp file) must leave a store that loads clean and self-heals."""
    root = str(tmp_path)
    store, rec = _put_one(root, "mlp", 8)
    plan = _plan(**{"store.write": FaultSpec(rate=1.0, kind="corrupt"),
                    "store.index": FaultSpec(rate=1.0, kind="corrupt")})
    net = get_net("mlp", batch=16)
    with inject(plan):
        store.put(solve(net, HW), net, HW)      # torn on disk
    with open(os.path.join(store.records_dir, "killed.tmp"), "w") as f:
        f.write("partial")
    store2 = ScheduleStore(root)                # must not raise
    assert not [n for n in os.listdir(store2.records_dir)
                if n.endswith(".tmp")]
    # torn index line triggered a rebuild, which quarantined the torn
    # record; the healthy record survived intact
    assert store2.stats()["rebuilds"] == 1
    assert store2.get(rec.signature) is not None
    assert len(store2) == 1
    assert store2.stats()["quarantined"] == 1


def test_cli_repair_rebuilds(tmp_path, capsys):
    from repro.service.__main__ import main
    root = str(tmp_path / "store")
    store, rec = _put_one(root)
    with open(store.index_path, "a") as f:
        f.write("not json\n")
    assert main(["repair", "--store-dir", root]) == 0
    out = capsys.readouterr().out
    assert "rebuilt index: 1 records" in out


# ---------------------------------------------------------------------------
# autotune hardening
# ---------------------------------------------------------------------------

def _autotune(tmp_path, plan=None, timeout=None, k=2):
    from repro.lower.calibrate import default_hw
    from repro.service import autotune_network
    store = ScheduleStore(str(tmp_path))
    net = get_net("mlp", batch=2)
    if plan is None:
        return autotune_network(net, default_hw(), store=store, k=k,
                                iters=1, candidate_timeout_s=timeout)
    with inject(plan):
        return autotune_network(net, default_hw(), store=store, k=k,
                                iters=1, candidate_timeout_s=timeout)


def test_autotune_disqualifies_nan_candidates(tmp_path):
    plan = _plan(**{"autotune.measure": FaultSpec(rate=1.0, kind="nan")})
    report = _autotune(tmp_path, plan)
    assert report["n_executed"] == 0
    assert report["skipped"]
    assert all("non-finite" in s["reason"] for s in report["skipped"])
    assert "promoted" not in report or not report["promoted"]


def test_autotune_disqualifies_crashing_candidates(tmp_path):
    plan = _plan(**{"autotune.measure": FaultSpec(rate=1.0,
                                                  kind="error")})
    report = _autotune(tmp_path, plan)
    assert report["n_executed"] == 0
    assert all("InjectedFault" in s["reason"] for s in report["skipped"])


def test_autotune_disqualifies_hung_candidates(tmp_path):
    plan = _plan(**{"autotune.measure": FaultSpec(rate=1.0, kind="slow",
                                                  delay_s=1.0)})
    t0 = time.perf_counter()
    report = _autotune(tmp_path, plan, timeout=0.05, k=1)
    assert report["n_executed"] == 0
    assert all("timeout" in s["reason"] for s in report["skipped"])


def test_autotune_partial_fault_still_promotes(tmp_path):
    """Faults on one candidate must not abort the others: with a ~50%
    crash schedule the survivors still execute and promote."""
    plan = _plan(seed=11, **{"autotune.measure": FaultSpec(rate=0.5,
                                                           kind="error")})
    report = _autotune(tmp_path, plan, k=3)
    assert report["n_candidates"] >= 1
    assert report["n_executed"] + len(report["skipped"]) \
        == report["n_candidates"]
    if report["n_executed"]:
        assert report.get("promoted") is True
