"""Batched cost model parity with the scalar judge + memoization behavior."""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:     # degrade: property tests skip, rest run
    from _hypothesis_stub import given, settings, strategies as st

from repro.core.cost_batch import (FactorTable, evaluate_batch, pack_order,
                                   score_schemes)
from repro.core.cost_model import evaluate_layer
from repro.core.directives import (LayerScheme, LevelBlocking,
                                   canonical_orders, divisors)
from repro.core.solver import memo, solve
from repro.core.solver.exhaustive import solve_layer_exhaustive
from repro.core.solver.intralayer import Constraints, solve_intra_layer
from repro.core.solver.random_search import _random_scheme
from repro.hw.presets import eyeriss_multinode, tpu_like_edge
from repro.workloads.layers import backward_weight, conv, dwconv, fc
from repro.workloads.nets import get_net

HW = eyeriss_multinode()
RTOL = 1e-6

SCALAR_FIELDS = ("energy_pj", "latency_cycles", "mac_energy", "regf_energy",
                 "gbuf_energy", "noc_energy", "dram_energy",
                 "dram_traffic_bytes", "gbuf_traffic_bytes")


def assert_parity(schemes, hw, constr, src_onchip=False, dst_onchip=False):
    res = score_schemes(schemes, hw, nodes_assigned=constr.num_nodes,
                        src_onchip=src_onchip, dst_onchip=dst_onchip)
    n_valid = 0
    for i, sch in enumerate(schemes):
        ref = evaluate_layer(sch, hw, nodes_assigned=constr.num_nodes,
                             src_onchip=src_onchip, dst_onchip=dst_onchip)
        assert ref.valid == bool(res.valid[i]), (i, ref.reason)
        if not ref.valid:
            continue
        n_valid += 1
        for f in SCALAR_FIELDS:
            a, b = getattr(ref, f), float(getattr(res, f)[i])
            assert a == pytest.approx(b, rel=RTOL, abs=1e-9), (i, f)
        assert ref.pes_used == int(res.pes_used[i])
        assert ref.nodes_used == int(res.nodes_used[i])
    return n_valid


def shr_variants(schemes):
    """Toggle node-level sharing on a copy of each scheme where possible."""
    out = []
    for sch in schemes:
        for t in sch.layer.tensors:
            if sch.replication(t, 1) > 1:
                lv = [l.copy() for l in sch.levels]
                lv[1].shr = {t: sch.replication(t, 1)}
                out.append(LayerScheme(sch.layer, lv))
                break
    return out


LAYERS = [conv("c", 64, 96, 256, 27, 27, 5, 5),
          fc("f", 64, 4096, 1000),
          dwconv("d", 64, 128, 28, 28, 3, 3),
          backward_weight(conv("cb", 8, 32, 64, 14, 14, 3, 3))]


@pytest.mark.parametrize("layer", LAYERS, ids=lambda l: l.name)
@pytest.mark.parametrize("onchip", [(False, False), (True, True)])
def test_batch_matches_scalar_on_random_schemes(layer, onchip):
    rng = random.Random(42)
    constr = Constraints(nodes=HW.node_array)
    schemes = [_random_scheme(layer, HW, constr, rng) for _ in range(150)]
    schemes += shr_variants(schemes[:40])
    n_valid = assert_parity(schemes, HW, constr, *onchip)
    assert n_valid > 0, "sample produced no valid scheme to compare"


def test_batch_matches_scalar_on_edge_hw():
    edge = tpu_like_edge()
    rng = random.Random(7)
    constr = Constraints(nodes=(1, 1))
    layer = conv("c", 1, 64, 128, 28, 28, 3, 3)
    schemes = [_random_scheme(layer, edge, constr, rng) for _ in range(100)]
    assert assert_parity(schemes, edge, constr) > 0


def test_batch_matches_scalar_on_solver_orders():
    """The exact candidate family the intra-layer solver batches: shared
    factors, varying loop orders at GBUF/DRAM."""
    layer = conv("c", 64, 96, 256, 27, 27, 5, 5)
    constr = Constraints(nodes=HW.node_array)
    base, _ = solve_intra_layer(layer, HW, constr)
    schemes = []
    for o_top in canonical_orders():
        for o_mid in canonical_orders():
            lv = [l.copy() for l in base.levels]
            lv[-1].order = o_top
            lv[1].order = o_mid
            schemes.append(LayerScheme(layer, lv))
    n_valid = assert_parity(schemes, HW, constr)
    assert n_valid == len(schemes)


def test_invalid_flagged_consistently():
    layer = fc("f", 64, 4096, 4096)
    overflow = LayerScheme(layer, [LevelBlocking(t={"C": 4096, "K": 4096}),
                                   LevelBlocking(),
                                   LevelBlocking(t={"N": 64})])
    mismatch = LayerScheme(layer, [LevelBlocking(), LevelBlocking(),
                                   LevelBlocking(t={"N": 32})])
    res = score_schemes([overflow, mismatch], HW)
    assert not res.valid.any()
    assert res.energy_pj[0] == float("inf")
    assert res.best() == -1


def test_factor_table_roundtrip():
    rng = random.Random(3)
    layer = LAYERS[0]
    constr = Constraints(nodes=HW.node_array)
    schemes = [_random_scheme(layer, HW, constr, rng) for _ in range(20)]
    ft = FactorTable.from_schemes(schemes)
    for i, sch in enumerate(schemes):
        back = ft.scheme_at(i)
        for lv_a, lv_b in zip(sch.levels, back.levels):
            for d in "NCKXY":
                assert lv_a.tf(d) == lv_b.tf(d)
                assert lv_a.sf(d) == lv_b.sf(d)


def test_pack_order_pads_missing_dims():
    idx, mask = pack_order(("K", "C"))
    assert len(idx) == 5 and len(mask) == 5
    assert mask[:2] == (True, True) and not any(mask[2:])


@settings(max_examples=150, deadline=None)
@given(n=st.sampled_from([4, 8, 64]), c=st.sampled_from([4, 12, 96]),
       k=st.sampled_from([8, 256]), data=st.data())
def test_property_parity_random_blockings(n, c, k, data):
    """Batched == scalar across random layers, blockings, orders, shr."""
    layer = fc("f", n, c, k) if data.draw(st.booleans()) else \
        conv("c", n, c, k, 14, 14, 3, 3)

    def split(total):
        d0 = data.draw(st.sampled_from(divisors(total)))
        d1 = data.draw(st.sampled_from(divisors(total // d0)))
        return d0, d1, total // d0 // d1

    lvls = [LevelBlocking(), LevelBlocking(), LevelBlocking()]
    for d in ("N", "C", "K", "X", "Y"):
        f0, f1, f2 = split(layer.dim(d))
        spatial = data.draw(st.booleans())
        if spatial and f0 > 1:
            lvls[0].s[d] = f0
        elif f0 > 1:
            lvls[0].t[d] = f0
        if f1 > 1:
            lvls[1].t[d] = f1
        if f2 > 1:
            lvls[2].t[d] = f2
    orders = canonical_orders()
    lvls[1].order = data.draw(st.sampled_from(orders))
    lvls[2].order = data.draw(st.sampled_from(orders))
    sch = LayerScheme(layer, lvls)
    if data.draw(st.booleans()):
        for t in layer.tensors:
            if sch.replication(t, 1) > 1:
                lvls[1].shr = {t: sch.replication(t, 1)}
                break
    constr = Constraints(nodes=HW.node_array)
    assert_parity([sch], HW, constr,
                  src_onchip=data.draw(st.booleans()),
                  dst_onchip=data.draw(st.booleans()))


# ---------------------------------------------------------------------------
# memoization regressions
# ---------------------------------------------------------------------------


def test_layer_signature_cache_identical_to_cold_solve():
    layer = conv("c", 64, 96, 256, 27, 27, 5, 5)
    constr = Constraints(nodes=(8, 8))
    memo.clear_all()
    cold_sch, cold_cost = solve_intra_layer(layer, HW, constr)
    warm_sch, warm_cost = solve_intra_layer(layer, HW, constr)
    assert memo.intra_cache.hits >= 1
    assert warm_cost.energy_pj == cold_cost.energy_pj
    assert warm_cost.latency_cycles == cold_cost.latency_cycles
    names = ["REGF", "GBUF", "DRAM"]
    assert "\n".join(map(str, warm_sch.to_directives(names))) == \
        "\n".join(map(str, cold_sch.to_directives(names)))
    # cache entries are isolated: mutating a returned scheme or cost must
    # not corrupt later hits
    warm_sch.levels[0].t["N"] = 999
    warm_cost.energy_pj = -1.0
    again_sch, again_cost = solve_intra_layer(layer, HW, constr)
    assert again_cost.energy_pj == cold_cost.energy_pj
    assert again_sch.levels[0].tf("N") == cold_sch.levels[0].tf("N")


def test_same_shape_layers_share_cache_entry():
    """ResNet-style shape repetition: same shape under different names must
    hit the same signature entry and yield the same schedule."""
    memo.clear_all()
    a = conv("block1", 16, 64, 64, 14, 14, 3, 3)
    b = conv("block2", 16, 64, 64, 14, 14, 3, 3, src=["block1"])
    sch_a, cost_a = solve_intra_layer(a, HW)
    misses = memo.intra_cache.misses
    sch_b, cost_b = solve_intra_layer(b, HW)
    assert memo.intra_cache.misses == misses          # pure hit
    assert cost_b.energy_pj == cost_a.energy_pj
    assert sch_b.layer is b                           # re-bound to caller
    names = ["REGF", "GBUF", "DRAM"]
    assert "\n".join(map(str, sch_b.to_directives(names))) == \
        "\n".join(map(str, sch_a.to_directives(names)))


def test_exhaustive_solver_memoized_and_consistent():
    layer = fc("f", 64, 512, 512)
    constr = Constraints(nodes=HW.node_array)
    memo.clear_all()
    sch1, cost1 = solve_layer_exhaustive(layer, HW, constr, budget=200)
    sch2, cost2 = solve_layer_exhaustive(layer, HW, constr, budget=200)
    assert memo.exhaustive_cache.hits >= 1
    assert cost2.energy_pj == cost1.energy_pj
    cold_sch, cold_cost = solve_layer_exhaustive(layer, HW, constr,
                                                 budget=200, use_cache=False)
    assert cold_cost.energy_pj == cost1.energy_pj
    # the best cost reported must equal the scalar judge on the scheme
    ref = evaluate_layer(cold_sch, HW, nodes_assigned=constr.num_nodes)
    assert ref.valid
    assert ref.energy_pj == pytest.approx(cold_cost.energy_pj, rel=RTOL)


def test_net_solve_unaffected_by_warm_cache():
    net = get_net("mlp", batch=64)
    memo.clear_all()
    cold = solve(net, HW)
    warm = solve(net, HW)
    assert cold.valid and warm.valid
    assert warm.total_energy_pj == cold.total_energy_pj
    assert warm.total_latency_cycles == cold.total_latency_cycles
    assert set(warm.layer_schemes) == set(cold.layer_schemes)
