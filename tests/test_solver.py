"""KAPLA solver behaviour: validity, optimality vs exhaustive, pruning."""
import pytest

from repro.core.solver import (Constraints, dp_prioritize,
                               enumerate_segments, solve, solve_intra_layer)
from repro.core.solver import annealing, exhaustive, random_search
from repro.core.solver.interlayer import PruneStats
from repro.hw.presets import eyeriss_multinode, tpu_like_edge
from repro.workloads.nets import get_net
from repro.workloads.layers import conv, fc

HW = eyeriss_multinode()


def test_intra_layer_always_valid_by_construction():
    for layer in [conv("c", 64, 96, 256, 27, 27, 5, 5),
                  conv("d", 64, 3, 96, 55, 55, 11, 11, stride=4),
                  fc("f", 64, 4096, 1000)]:
        sch, cost = solve_intra_layer(layer, HW)
        assert cost.valid, (layer.name, cost.reason)
        for lvl in range(2):
            assert sch.level_footprint_bytes(lvl) <= \
                HW.levels[lvl].capacity_bytes + 1e-6


def test_kapla_close_to_exhaustive_on_mlp():
    """The paper's core claim: near-optimal energy, orders faster."""
    net = get_net("mlp", batch=64)
    k = solve(net, HW)
    s = exhaustive.solve(net, HW, budget_per_layer=800)
    assert k.valid and s.valid
    overhead = k.total_energy_pj / s.total_energy_pj - 1.0
    assert overhead < 0.10, f"KAPLA {overhead:.1%} over exhaustive"
    assert k.solve_seconds < s.solve_seconds


def test_kapla_beats_random_and_annealing_on_mlp():
    net = get_net("mlp", batch=64)
    k = solve(net, HW)
    r = random_search.solve(net, HW, samples=400)
    m = annealing.solve(net, HW, iters=8, batch=8)
    assert k.total_energy_pj <= r.total_energy_pj * 1.001
    assert k.total_energy_pj <= m.total_energy_pj * 1.001


@pytest.mark.parametrize("name", ["alexnet", "mlp", "lstm", "mobilenet"])
def test_kapla_solves_all_nets(name):
    net = get_net(name, batch=64)
    res = solve(net, HW)
    assert res.valid
    assert set(res.layer_schemes) == {l.name for l in net.layers}
    # every per-layer cost is individually valid
    for c in res.layer_costs.values():
        assert c.valid


def test_training_graph_solvable():
    net = get_net("alexnet", batch=64, training=True)
    assert len(net) > len(get_net("alexnet"))
    res = solve(net, HW)
    assert res.valid


def test_conservative_pruning_never_rejects_valid():
    """Every chain the DP produces must be solvable in detail (modulo the
    documented pipelining fallback)."""
    net = get_net("mlp", batch=64)
    stats = PruneStats()
    chains = dp_prioritize(net, HW, k_s=4, stats=stats)
    assert stats.total >= stats.after_validity >= 0
    assert chains, "no chains survived"
    res = solve(net, HW)
    assert res.valid


def test_pruning_stats_populated():
    net = get_net("alexnet", batch=64)
    res = solve(net, HW)
    st = res.prune_stats
    assert st.total > 0
    assert st.after_pareto <= st.after_validity <= st.total


def test_k_s_monotone_quality():
    net = get_net("lstm", batch=64)
    e = {}
    for ks in (1, 4):
        e[ks] = solve(net, HW, k_s=ks).total_energy_pj
    assert e[4] <= e[1] * 1.001   # more candidates never hurt


def test_edge_device_inference():
    edge = tpu_like_edge()
    net = get_net("alexnet", batch=1)
    res = solve(net, edge)
    assert res.valid
    for c in res.layer_costs.values():
        assert c.nodes_used == 1


def test_segment_alloc_covers_grid():
    net = get_net("mlp", batch=64)
    segs = enumerate_segments(net, HW, 0, max_len=4)
    H, W = HW.node_array
    for s in segs:
        assert len(s.alloc) == s.length
        # regions (column strips, row strips, 2-D blocks) must fit the grid
        assert sum(h * w for h, w in s.alloc) <= H * W
        assert all(1 <= h <= H and 1 <= w <= W for h, w in s.alloc)


def test_objective_perf_vs_energy():
    net = get_net("mlp", batch=64)
    e = solve(net, HW, objective="energy")
    p = solve(net, HW, objective="perf")
    assert p.total_latency_cycles <= e.total_latency_cycles * 1.05
