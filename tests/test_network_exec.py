"""Network-tier lowering/execution: NetworkPlan structure, the buffer
schedule (on-chip forwarding vs host round-trips), whole-graph numerics
vs the reference pass, adapters, and the network calibration record."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.solver import solve
from repro.core.solver.kapla import NetworkSchedule
from repro.lower import (execute_network, lower_network,
                         make_network_inputs, verify_network)
from repro.lower.calibrate import default_hw, run_network_calibration
from repro.lower.netexec import (adapt_tensor, _eltwise_operands,
                                 required_input_shape)
from repro.workloads.nets import get_net, transformer

HW = default_hw()


def _plan(net):
    sched = solve(net, HW)
    assert sched.valid
    return sched, sched.lower(net, HW)


# ---------------------------------------------------------------------------
# plan structure + buffer schedule
# ---------------------------------------------------------------------------

def test_network_plan_structure_mlp():
    net = get_net("mlp", batch=4)
    sched, nplan = _plan(net)
    assert nplan.executable, nplan.invalid_layers()
    assert nplan.order == tuple(l.name for l in net.layers)
    assert set(nplan.plans) == set(nplan.order) == set(nplan.placements)
    # segments mirror the solved chain exactly
    assert [(-s.start + s.stop) for s in nplan.segments] == \
        [seg.stop - seg.start for seg in sched.chain.segments]
    for seg in nplan.segments:
        assert seg.layer_names == nplan.order[seg.start:seg.stop]
    # every placement is self-consistent
    for name, p in nplan.placements.items():
        assert p.producer == name
        if p.forwarded:
            seg = nplan.segment_of(name)
            assert seg.length > 1
            assert all(c in seg.layer_names for c in p.consumers)
            assert p.granule_bytes <= p.spare_bytes
        else:
            assert p.reason
    assert nplan.predicted_latency_cycles == sched.total_latency_cycles


def test_forwarded_tensors_skip_host_roundtrip():
    net = get_net("mlp", batch=4)
    _, nplan = _plan(net)
    fwd = nplan.forwarded()
    assert fwd, "mlp chain should keep at least one tensor on-chip"
    ex = execute_network(nplan)
    assert set(ex.forwarded) == set(fwd)
    assert not set(ex.forwarded) & set(ex.roundtrips)
    assert set(ex.forwarded) | set(ex.roundtrips) == set(nplan.order)
    # on-chip handoffs stayed live jax arrays end to end
    for n in ex.forwarded:
        assert isinstance(ex.outputs[n], jnp.ndarray)


def test_network_plan_reports_unsupported_layers():
    net = get_net("mobilenet", batch=1)       # dwconv has no kernel yet
    sched = solve(net, HW)
    nplan = lower_network(sched, net, HW)
    bad = dict(nplan.invalid_layers())
    assert not nplan.executable
    assert any("dwconv" in r for r in bad.values())
    with pytest.raises(ValueError, match="mobilenet.*dw"):
        execute_network(nplan)


def test_mixed_external_sources_are_refused():
    # a layer fed by both an in-graph producer and an external name would
    # silently drop the external operand — the plan must refuse it loudly
    from repro.workloads.layers import LayerGraph, eltwise, fc
    net = LayerGraph("mixed", [
        fc("a", 4, 32, 32),
        eltwise("m", 4, 32, 1, 1, src=["a", "external"]),
    ])
    sched = solve(net, HW)
    nplan = lower_network(sched, net, HW)
    bad = dict(nplan.invalid_layers())
    assert "m" in bad and "external" in bad["m"]
    with pytest.raises(ValueError, match="mix of in-graph and external"):
        execute_network(nplan)


def test_lower_from_deserialized_schedule():
    net = get_net("mlp", batch=4)
    sched, nplan = _plan(net)
    back = NetworkSchedule.from_json(json.loads(json.dumps(sched.to_json())),
                                     graph=net)
    nplan2 = lower_network(back, net, HW)
    assert nplan2.executable
    assert [s.layer_names for s in nplan2.segments] == \
        [s.layer_names for s in nplan.segments]
    assert nplan2.forwarded() == nplan.forwarded()
    # without a chain, lowering degrades to singleton segments (no pipelining)
    back.chain = None
    nplan3 = lower_network(back, net, HW)
    assert nplan3.executable
    assert len(nplan3.segments) == len(net.layers)
    assert not nplan3.forwarded()


# ---------------------------------------------------------------------------
# end-to-end numerics vs the whole-graph reference pass
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda: get_net("mlp", batch=4),
    lambda: transformer(batch=8, layers=2),
    lambda: get_net("lstm", batch=8),
], ids=["mlp", "transformer2", "lstm"])
def test_network_executes_against_reference(make):
    net = make()
    _, nplan = _plan(net)
    assert nplan.executable, nplan.invalid_layers()
    ver = verify_network(nplan)
    assert ver.ok, f"{net.name}: {ver.worst_layer} err {ver.max_rel_err:.2e}"
    assert set(ver.errors) == set(nplan.order)
    assert ver.n_forwarded >= 1


def test_alexnet_executes_end_to_end():
    # the acceptance workload: conv + pool + fc through one pipeline, with
    # at least one multi-layer segment forwarding on-chip
    net = get_net("alexnet", batch=1)
    _, nplan = _plan(net)
    assert nplan.executable, nplan.invalid_layers()
    assert any(s.length > 1 for s in nplan.segments)
    ver = verify_network(nplan, tol=1e-3)
    assert ver.ok, f"{ver.worst_layer} err {ver.max_rel_err:.2e}"
    assert ver.n_forwarded >= 1


def test_measure_network_and_runner_reuse():
    from repro.lower import measure_network, network_runner
    net = get_net("mlp", batch=4)
    _, nplan = _plan(net)
    assert measure_network(nplan, iters=1) > 0
    # a pre-warmed runner is reused without re-compiling (warmup=0)
    inputs = make_network_inputs(nplan)
    run = network_runner(nplan, inputs)
    run()
    assert measure_network(nplan, iters=1, warmup=0, runner=run) > 0


def test_compiled_mode_applies_revisit_guard():
    # compiled Pallas cannot accumulate across non-consecutive output-block
    # revisits; the network runner must enforce the layer tier's guard
    from repro.core.solver.intralayer import Constraints, solve_intra_layer
    from repro.lower import lower_scheme, network_runner
    from repro.lower.netplan import NetworkPlan, SegmentPlan, TensorPlacement
    from repro.workloads.layers import fc
    layer = fc("g.fc", 128, 1024, 1024)
    scheme, cost = solve_intra_layer(layer, HW,
                                     Constraints(nodes=HW.node_array))
    assert scheme is not None and cost.valid
    scheme.levels[-1].order = ("C", "K", "N", "X", "Y")   # reduction outer
    plan = lower_scheme(scheme, HW)
    assert plan.valid and plan.grid[0].dim == "C" and len(plan.grid) > 1
    nplan = NetworkPlan(
        graph_name="g", order=("g.fc",), plans={"g.fc": plan},
        segments=(SegmentPlan(0, 0, 1, ("g.fc",), ((1, 1),), 1.0),),
        placements={"g.fc": TensorPlacement("g.fc", (), 0, False,
                                            reason="network output")},
        predicted_latency_cycles=0.0, predicted_energy_pj=0.0)
    inputs = make_network_inputs(nplan)
    assert network_runner(nplan, inputs, interpret=True) is not None
    with pytest.raises(ValueError, match="reduction grid axes innermost"):
        network_runner(nplan, inputs, interpret=False)


# ---------------------------------------------------------------------------
# the canonical adapter
# ---------------------------------------------------------------------------

def test_adapt_tensor_rules():
    x = jnp.arange(24, dtype=jnp.float32).reshape(2, 3, 2, 2)
    # rule 1: equal size -> reshape (flatten before FC)
    flat = adapt_tensor(x, (2, 12))
    assert flat.shape == (2, 12)
    np.testing.assert_array_equal(np.asarray(flat),
                                  np.asarray(x).reshape(2, 12))
    # rule 2: channel-matched spatial pad (conv halo) is centered zeros
    pad = adapt_tensor(x, (2, 3, 4, 4))
    assert pad.shape == (2, 3, 4, 4)
    np.testing.assert_array_equal(np.asarray(pad[:, :, 1:3, 1:3]),
                                  np.asarray(x))
    assert float(jnp.abs(pad[:, :, 0]).sum()) == 0.0
    # ... and crop inverts it
    np.testing.assert_array_equal(np.asarray(adapt_tensor(pad, x.shape)),
                                  np.asarray(x))
    # rule 3: divisible size -> fold-sum (LSTM gate merge)
    y = jnp.ones((2, 8), jnp.float32)
    fold = adapt_tensor(y, (2, 2, 1, 1))
    assert fold.shape == (2, 2, 1, 1)
    np.testing.assert_allclose(np.asarray(fold), 4.0)
    with pytest.raises(ValueError, match="cannot adapt"):
        adapt_tensor(jnp.ones((2, 5)), (2, 3))


def test_eltwise_concat_embedding():
    from repro.workloads.layers import eltwise
    layer = eltwise("cat", 2, 6, 4, 4, src=["a", "b"])
    a = jnp.ones((2, 2, 4, 4), jnp.float32)
    b = 2 * jnp.ones((2, 4, 4, 4), jnp.float32)
    ops = _eltwise_operands([a, b], layer)
    assert all(o.shape == required_input_shape(layer) for o in ops)
    total = np.asarray(sum(ops))
    np.testing.assert_allclose(total[:, :2], 1.0)   # a's channels
    np.testing.assert_allclose(total[:, 2:], 2.0)   # b's channels


# ---------------------------------------------------------------------------
# network calibration record
# ---------------------------------------------------------------------------

def test_network_calibration_skipped_numerics_stay_visible():
    # a net excluded from the timing record for numerics must still carry
    # its rel error, so the bench's --max-network-rel-err gate can fire
    rec = run_network_calibration(HW, quick=True, iters=1, tol=0.0,
                                  nets=[get_net("mlp", batch=4)])
    assert rec["n_nets"] == 0
    assert rec["skipped"] and all("max_rel_err" in s
                                  for s in rec["skipped"])


def test_network_calibration_record_quick():
    rec = run_network_calibration(HW, quick=True, iters=1)
    assert rec["n_nets"] >= 2, rec["skipped"]
    for e in rec["nets"]:
        assert e["max_rel_err"] < 1e-3
        assert e["measured_seconds"] > 0
        assert e["n_forwarded"] >= 1
        assert e["predicted_cycles"] > 0
    assert "spearman_network" in rec
    json.dumps(rec)                       # record is JSON-safe


# ---------------------------------------------------------------------------
# prune_stats JSON round-trip (regression: silently dropped before)
# ---------------------------------------------------------------------------

def test_network_schedule_json_preserves_prune_stats():
    net = get_net("mlp", batch=8)
    sched = solve(net, HW)
    assert sched.prune_stats is not None and sched.prune_stats.total > 0
    back = NetworkSchedule.from_json(json.loads(json.dumps(sched.to_json())),
                                     graph=net)
    assert back.prune_stats is not None
    assert back.prune_stats == sched.prune_stats
    # absent field (older records) still deserializes
    d = sched.to_json()
    del d["prune_stats"]
    assert NetworkSchedule.from_json(d, graph=net).prune_stats is None
