"""Lowering subsystem: plan numerics vs kernels/ref.py oracles, concrete
footprint validity, serialization round-trips, and the calibration fit."""
import json

import numpy as np
import pytest

from repro.core.cost_model import (Calibration, evaluate_layer,
                                   predicted_seconds, set_calibration)
from repro.core.directives import LayerScheme
from repro.core.solver import solve
from repro.core.solver.intralayer import Constraints, solve_intra_layer
from repro.core.solver.kapla import NetworkSchedule
from repro.lower import (execute_plan, lower_scheme, lower_schedule,
                         verify_plan)
from repro.lower.calibrate import (default_hw, run_calibration,
                                   scheme_variants, spearman)
from repro.workloads.layers import attention, conv, dwconv, eltwise, fc, pool
from repro.workloads.nets import get_net

# small node grid so realistic layers overflow on-chip capacity and the
# DRAM-level grid (the part lowering must get right) is non-trivial
HW = default_hw()


def _best_scheme(layer):
    scheme, cost = solve_intra_layer(layer, HW,
                                     Constraints(nodes=HW.node_array))
    assert scheme is not None and cost.valid
    return scheme


SWEEP = [
    fc("t.fc.s", 32, 64, 64),
    fc("t.fc.m", 64, 512, 512),           # multi-step grid, C reduction axis
    conv("t.conv.s", 2, 16, 32, 14, 14, 3, 3),
    conv("t.conv.m", 2, 64, 64, 28, 28, 3, 3),
    conv("t.conv.str2", 2, 32, 64, 28, 28, 3, 3, stride=2),
    attention("t.attn.s", 2, 2, 128, 64),
    attention("t.attn.m", 2, 4, 256, 64),
    pool("t.pool.s", 2, 16, 13, 13, 3, 3),
    pool("t.pool.str", 1, 96, 27, 27, 3, 3, stride=2),
    eltwise("t.elt.s", 2, 64, 14, 14),
    eltwise("t.elt.flat", 8, 512, 1, 1),
]


@pytest.mark.parametrize("layer", SWEEP, ids=lambda l: l.name)
def test_lowered_plan_matches_ref(layer):
    plan = lower_scheme(_best_scheme(layer), HW)
    assert plan.valid, plan.reason
    # the grid times the block exactly tiles every dim
    blocked = {ax.dim: ax.steps for ax in plan.grid}
    for d, blk in plan.block.items():
        assert blk * blocked.get(d, 1) == plan.layer.dim(d)
    ok, err = verify_plan(plan)
    assert ok, f"{plan.describe()}: rel err {err:.2e}"


def test_loop_order_variants_all_match_ref():
    # same factors, permuted DRAM nest -> different grid order, same output
    layer = fc("t.fc.orders", 128, 1024, 1024)   # DRAM-splits both C and K
    schemes = scheme_variants(layer, HW, n_variants=3)
    assert len(schemes) >= 2
    grids = set()
    for scheme in schemes:
        plan = lower_scheme(scheme, HW)
        assert plan.valid, plan.reason
        grids.add(tuple(ax.dim for ax in plan.grid))
        ok, err = verify_plan(plan)
        assert ok, f"{plan.describe()}: rel err {err:.2e}"
    assert len(grids) >= 2, "variants should produce distinct grid orders"


def test_footprint_validity_rejects_overflow():
    layer = fc("t.fc.big", 64, 1024, 1024)
    scheme = _best_scheme(layer)
    plan = lower_scheme(scheme, HW)
    assert plan.valid
    assert plan.level_footprints[1] <= HW.levels[1].capacity_bytes
    # hoist every DRAM factor on-chip: factors still multiply to the layer
    # dims, but the concrete GBUF block no longer fits
    bloated = LayerScheme(layer, [lv.copy() for lv in scheme.levels])
    top, gbuf = bloated.levels[-1], bloated.levels[-2]
    for d in list(top.t):
        gbuf.t[d] = gbuf.tf(d) * top.tf(d)
        top.t[d] = 1
    assert bloated.validate_factors()
    bad = lower_scheme(bloated, HW)
    assert not bad.valid
    assert "GBUF" in bad.reason


def test_attention_head_dim_split_is_repaired():
    layer = attention("t.attn.split", 2, 2, 128, 64)
    scheme = _best_scheme(layer)
    # force a head-dim split at the DRAM level
    split = LayerScheme(layer, [lv.copy() for lv in scheme.levels])
    gbuf, top = split.levels[-2], split.levels[-1]
    assert gbuf.tf("K") % 2 == 0, "test premise: K blocked on-chip"
    gbuf.t["K"] = gbuf.tf("K") // 2
    top.t["K"] = top.tf("K") * 2
    assert split.validate_factors()
    strict = lower_scheme(split, HW, repair=False)
    assert not strict.valid and "head-dim" in strict.reason
    repaired = lower_scheme(split, HW, repair=True)
    assert repaired.valid, repaired.reason
    assert repaired.scheme.levels[-1].tf("K") == 1
    ok, err = verify_plan(repaired)
    assert ok, f"repaired plan rel err {err:.2e}"


def test_unsupported_kind_is_invalid_not_crash():
    layer = dwconv("t.dw", 2, 8, 7, 7, 3, 3)
    scheme, cost = solve_intra_layer(layer, HW,
                                     Constraints(nodes=HW.node_array))
    assert scheme is not None and cost.valid
    plan = lower_scheme(scheme, HW)
    assert not plan.valid and "unsupported" in plan.reason
    assert plan.invalid_reason == plan.reason
    # the refusal names the layer AND carries the lowering-time reason
    with pytest.raises(ValueError, match=r"t\.dw.*unsupported"):
        execute_plan(plan)


def test_lower_schedule_covers_solved_network():
    net = get_net("alexnet", batch=1)
    sched = solve(net, HW)
    assert sched.valid
    plans = lower_schedule(sched, net, HW)
    assert set(plans) == set(sched.layer_schemes)
    # conv, fc AND pool are all supported now: alexnet lowers completely
    for name, plan in plans.items():
        assert plan.valid, f"{name}: {plan.reason}"
    # execute one lowered conv and one pool end to end against the oracles
    for name in ("conv3", "pool2"):
        ok, err = verify_plan(plans[name])
        assert ok, f"{name} rel err {err:.2e}"


def test_training_graph_lowers_without_crash():
    # backward-data / backward-weight layers have no kernels yet: they must
    # come back as invalid plans with a clear reason, never exceptions
    net = get_net("mlp", batch=8, training=True)
    sched = solve(net, HW)
    assert sched.valid
    plans = lower_schedule(sched, net, HW)
    assert set(plans) == set(sched.layer_schemes)
    kinds_seen = set()
    for name, plan in plans.items():
        kind = net.by_name[name].kind
        kinds_seen.add(kind)
        if kind == "fc":
            assert plan.valid, f"{name}: {plan.reason}"
        else:
            assert not plan.valid, name
            assert "unsupported" in plan.reason and kind in plan.reason
            with pytest.raises(ValueError, match=name.replace(".", r"\.")):
                execute_plan(plan)
    assert {"fc", "fc_bd", "fc_bw"} <= kinds_seen


# ---------------------------------------------------------------------------
# serialization round-trips
# ---------------------------------------------------------------------------

def test_layer_scheme_json_roundtrip_parity():
    for layer in (fc("t.rt.fc", 64, 512, 512),
                  conv("t.rt.conv", 2, 16, 32, 14, 14, 3, 3),
                  attention("t.rt.attn", 2, 2, 128, 64)):
        scheme = _best_scheme(layer)
        blob = json.dumps(scheme.to_json())
        back = LayerScheme.from_json(json.loads(blob))
        a = evaluate_layer(scheme, HW)
        b = evaluate_layer(back, HW)
        assert a.valid and b.valid
        assert a.energy_pj == b.energy_pj
        assert a.latency_cycles == b.latency_cycles
        # layer spec fields survive (incl. execution meta + frozensets)
        assert back.layer.meta == dict(layer.meta)
        assert back.layer.tensors == dict(layer.tensors)
        assert back.layer.reduction_dims == layer.reduction_dims
        # re-binding to the original spec object also works
        rebound = LayerScheme.from_json(json.loads(blob), layer=layer)
        assert rebound.layer is layer


def test_network_schedule_json_roundtrip():
    net = get_net("mlp", batch=8)
    sched = solve(net, HW)
    assert sched.valid
    blob = json.dumps(sched.to_json())
    back = NetworkSchedule.from_json(json.loads(blob), graph=net)
    assert back.graph_name == sched.graph_name
    assert back.total_energy_pj == sched.total_energy_pj
    assert back.total_latency_cycles == sched.total_latency_cycles
    assert set(back.layer_schemes) == set(sched.layer_schemes)
    assert [dataclasses_tuple(s) for s in back.chain.segments] == \
        [dataclasses_tuple(s) for s in sched.chain.segments]
    for name, scheme in back.layer_schemes.items():
        assert scheme.layer is net.by_name[name]
        a, b = sched.layer_costs[name], back.layer_costs[name]
        assert a.energy_pj == b.energy_pj
        # deserialized schemes re-score identically under the judge
        assert evaluate_layer(scheme, HW).energy_pj == \
            evaluate_layer(sched.layer_schemes[name], HW).energy_pj


def dataclasses_tuple(seg):
    return (seg.start, seg.stop, seg.alloc, seg.granule_frac)


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

def test_spearman_basics():
    assert spearman([1, 2, 3, 4], [10, 20, 30, 40]) == pytest.approx(1.0)
    assert spearman([1, 2, 3, 4], [40, 30, 20, 10]) == pytest.approx(-1.0)
    assert abs(spearman([1, 1, 2, 2], [1, 1, 2, 2])) > 0.9


def test_calibration_sweep_and_fit():
    # spread work over ~300x so measured ranks are stable despite the
    # short iters (the tighter >= 0.8 @ >= 20 pairs gate runs in
    # benchmarks/bench_solver_speed.py --calibrate with the full sweep)
    layers = [fc("t.cal.fc.s", 32, 64, 64),
              fc("t.cal.fc.m", 64, 512, 512),
              fc("t.cal.fc.l", 128, 1024, 1024),
              conv("t.cal.conv.s", 2, 16, 32, 14, 14, 3, 3),
              conv("t.cal.conv.m", 2, 64, 64, 28, 28, 3, 3),
              attention("t.cal.attn", 2, 4, 256, 64)]
    rec = run_calibration(HW, layers=layers, n_variants=1, iters=2,
                          verify=True)
    assert rec["n_pairs"] >= 6, rec["skipped"]
    for p in rec["pairs"]:
        assert p["rel_err"] < 1e-3
        assert p["measured_seconds"] > 0
    assert rec["spearman_raw"] > 0.6, rec["spearman_raw"]

    cal = Calibration.from_json_dict(rec["calibration"])
    assert cal.n_pairs == rec["n_pairs"]
    # optional loading into the cost model
    layer = layers[1]
    cb = evaluate_layer(_best_scheme(layer), HW)
    raw = predicted_seconds(cb, layer.total_macs(), HW)
    assert raw == pytest.approx(cb.latency_cycles / HW.freq_hz)
    try:
        set_calibration(cal)
        sec = predicted_seconds(cb, layer.total_macs(), HW)
        assert np.isfinite(sec) and sec != raw
    finally:
        set_calibration(None)


def test_predicted_seconds_keeps_invalid_at_inf():
    from repro.core.cost_model import invalid
    cal = Calibration(a_compute=1e-9, intercept=0.01)
    try:
        set_calibration(cal)
        assert predicted_seconds(invalid("x"), 1e6, HW) == float("inf")
    finally:
        set_calibration(None)
    assert predicted_seconds(invalid("x"), 1e6, HW) == float("inf")


def test_calibration_roundtrips_through_json():
    cal = Calibration(a_compute=1e-9, a_dram=2e-9, a_gbuf=3e-9,
                      a_step=1e-4, intercept=1e-3, spearman=0.9, n_pairs=21)
    back = Calibration.from_json_dict(json.loads(json.dumps(
        cal.to_json_dict())))
    assert back == cal
