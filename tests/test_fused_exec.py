"""Fused compiled segment execution (repro.lower.fuse): every fused
segment matches the interpret oracle, the whole-net executable matches
layer-by-layer interpret, the process-wide executable cache serves
repeat executions with zero retrace, donation never touches weights,
and invalid plans still fail with the offending layer's name."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.solver import solve
from repro.lower import (lower_network, make_network_inputs,
                         measure_network, network_runner)
from repro.lower.calibrate import default_hw
from repro.lower.fuse import (FusedNetwork, cache_stats, clear_cache,
                              compiled_plan_fn, fused_runner,
                              plan_signature)
from repro.obs.metrics import REGISTRY
from repro.workloads.nets import get_net, transformer

HW = default_hw()
TOL = 1e-5


def _plan(net):
    sched = solve(net, HW)
    assert sched.valid
    nplan = lower_network(sched, net, HW)
    assert nplan.executable, nplan.invalid_layers()
    return nplan


def _rel_err(a, b) -> float:
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return float(np.abs(a - b).max() / (np.abs(b).max() + 1e-12))


def _oracle(nplan, inputs):
    """Layer-by-layer interpret-mode outputs: the bit-accuracy oracle
    the fused tier is judged against."""
    return network_runner(nplan, inputs, jit=True,
                          backend="interpret")().outputs


# ---------------------------------------------------------------------------
# per-segment numerics vs the interpret oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda: get_net("mlp", batch=4),
    lambda: transformer(batch=8, layers=2),
    lambda: get_net("alexnet", batch=1),
], ids=["mlp", "transformer2", "alexnet"])
def test_fused_segments_match_interpret_oracle(make):
    net = make()
    nplan = _plan(net)
    inputs = make_network_inputs(nplan, seed=0)
    oracle = _oracle(nplan, inputs)
    fused = fused_runner(nplan, cache=False)
    for index, (consumes, produces) in enumerate(fused.segment_io):
        assert produces, f"segment {index} produces nothing"
        # feed the segment from oracle boundary values, so each segment
        # is judged on its own (errors don't accumulate across segments)
        feed = {s: inputs[s] if s in inputs else oracle[s]
                for s in consumes}
        out = fused.run_segment(index, feed)
        assert set(out) == set(produces)
        for name in produces:
            err = _rel_err(out[name], oracle[name])
            assert err < TOL, f"{net.name} segment {index} " \
                              f"layer {name}: rel err {err:.2e}"


def test_whole_network_fused_matches_oracle():
    nplan = _plan(get_net("mlp", batch=4))
    inputs = make_network_inputs(nplan, seed=0)
    oracle = _oracle(nplan, inputs)
    fused = fused_runner(nplan, cache=False)
    out = fused(inputs, keep="all")
    assert set(out) == set(nplan.order)
    for name in nplan.order:
        assert _rel_err(out[name], oracle[name]) < TOL, name
    # the serving variant returns only boundary/network outputs —
    # forwarded in-segment tensors never materialize
    boundary = fused(inputs, keep="boundary")
    assert set(boundary) < set(nplan.order)
    fwd = set(nplan.forwarded())
    kept_fwd = {n for s in fused.segment_io for n in s[1]} & fwd
    assert set(boundary) & fwd <= kept_fwd
    for name in boundary:
        assert _rel_err(boundary[name], oracle[name]) < TOL, name


def test_network_runner_compiled_backend():
    nplan = _plan(get_net("mlp", batch=4))
    inputs = make_network_inputs(nplan, seed=0)
    oracle = _oracle(nplan, inputs)
    ex = network_runner(nplan, inputs, jit=True, backend="compiled")()
    assert ex.backend == "compiled"
    assert set(ex.forwarded) == set(nplan.forwarded())
    for name, val in ex.outputs.items():
        assert _rel_err(val, oracle[name]) < TOL, name
    assert measure_network(nplan, inputs, iters=1, warmup=1,
                           backend="compiled") > 0


# ---------------------------------------------------------------------------
# the executable cache: hit on re-execution, zero retrace
# ---------------------------------------------------------------------------

def test_executable_cache_hits_with_zero_retrace():
    clear_cache()
    net = get_net("mlp", batch=4)
    nplan = _plan(net)
    inputs = make_network_inputs(nplan, seed=0)
    hits = REGISTRY.get("fused_cache_events_total")
    h0, m0 = hits.value(event="hit"), hits.value(event="miss")

    fused = fused_runner(nplan)
    assert cache_stats()["misses"] == 1
    assert hits.value(event="miss") == m0 + 1
    fused(inputs, keep="boundary")
    traces = fused.traces
    assert traces >= 1

    # a fresh lowering of the same schedule has the same signature:
    # the second "execution" of the plan reuses the traced executable
    nplan2 = _plan(net)
    assert plan_signature(nplan2) == plan_signature(nplan)
    fused2 = fused_runner(nplan2)
    assert fused2 is fused                    # same executable object
    assert hits.value(event="hit") == h0 + 1
    fused2(make_network_inputs(nplan2, seed=1), keep="boundary")
    assert fused2.traces == traces            # zero retrace on re-execution

    # a different plan (different batch -> different shapes) is a miss
    other = _plan(get_net("mlp", batch=8))
    assert plan_signature(other) != plan_signature(nplan)
    assert fused_runner(other) is not fused
    assert cache_stats()["misses"] == 2
    clear_cache()
    assert cache_stats() == {"size": 0, "hits": 0, "misses": 0,
                             "evictions": 0}


# ---------------------------------------------------------------------------
# donation: activations donatable, weights never
# ---------------------------------------------------------------------------

@pytest.mark.filterwarnings("ignore:Some donated buffers")
def test_donated_buffers_are_safe():
    # (on CPU donation is a no-op — jax warns and keeps the buffers —
    # so this asserts the semantics survive wherever donation lands)
    nplan = _plan(get_net("mlp", batch=4))
    inputs = make_network_inputs(nplan, seed=0)
    fused = fused_runner(nplan, cache=False)
    expect = jax.device_get(fused(inputs, keep="boundary"))

    donated = fused({k: jnp.array(v) for k, v in inputs.items()},
                    keep="boundary", donate=True)
    for name, val in expect.items():
        assert _rel_err(donated[name], val) < TOL, name
    # weights are never donated: the same resident weight arrays serve
    # the next request (only activations were handed over)
    again = fused({k: (v if k.endswith(".W") else jnp.array(v))
                   for k, v in inputs.items()}, keep="boundary",
                  donate=True)
    for name, val in expect.items():
        assert _rel_err(again[name], val) < TOL, name


# ---------------------------------------------------------------------------
# invalid plans fail loudly, naming the layer
# ---------------------------------------------------------------------------

def test_invalid_plan_errors_name_layer():
    net = get_net("mobilenet", batch=1)       # dwconv has no kernel
    sched = solve(net, HW)
    nplan = lower_network(sched, net, HW)
    assert not nplan.executable
    with pytest.raises(ValueError, match="mobilenet.*dw"):
        fused_runner(nplan, cache=False)
    with pytest.raises(ValueError, match="mobilenet.*dw"):
        FusedNetwork(nplan)
    inputs = {}
    with pytest.raises(ValueError, match="mobilenet.*dw"):
        network_runner(nplan, inputs, backend="compiled")
    bad = next(p for _, p in sorted(nplan.plans.items()) if not p.valid)
    with pytest.raises(ValueError, match=bad.layer.name):
        compiled_plan_fn(bad)


# ---------------------------------------------------------------------------
# per-backend calibration storage
# ---------------------------------------------------------------------------

def test_per_backend_calibration_registry():
    from repro.core.cost_model import (Calibration, get_calibration,
                                       set_calibration)
    try:
        cal_i = Calibration(a_compute=1.0, backend="interpret")
        cal_c = Calibration(a_compute=2.0, backend="compiled")
        set_calibration(cal_i)
        set_calibration(cal_c)
        # the last-installed backend is active; both stay addressable
        assert get_calibration() is cal_c
        assert get_calibration("interpret") is cal_i
        assert get_calibration("compiled") is cal_c
        set_calibration(None, backend="compiled")
        assert get_calibration("compiled") is None
        assert get_calibration("interpret") is cal_i
    finally:
        set_calibration(None)
    assert get_calibration() is None
