"""Inter-layer batched-estimation parity with the scalar reference path.

The batched upper level (``core/estimate_batch.py`` + the array DP in
``solver/interlayer.py``) must be *bit-exact* equal to the scalar
``estimate_layer``-per-candidate path on validity masks, per-candidate
bounds, Pareto survivors, and DP chain costs — across all seven paper nets.
"""
import pytest

from repro.core.estimate import estimate_layer
from repro.core.solver import memo, solve
from repro.core.solver.interlayer import (
    _consumer_map, candidate_metas, dp_prioritize, dp_prioritize_scalar,
    enumerate_segments, enumerate_segments_scalar, estimate_candidates,
    estimate_segment_scalar)
from repro.hw.presets import eyeriss_multinode, tpu_like_edge
from repro.workloads.layers import conv, fc
from repro.workloads.nets import NETS, get_net, transformer

HW = eyeriss_multinode()

SEVEN = ["alexnet", "mobilenet", "vggnet", "googlenet", "resnet", "mlp",
         "lstm"]


def _assert_candidate_parity(net, hw):
    """Batched estimates + validity masks == scalar path, candidate by
    candidate (pre-Pareto, so invalid candidates are compared too)."""
    metas = candidate_metas(net, hw, range(len(net.layers)), 4)
    valid, energy, latency, dram = estimate_candidates(net, hw, metas)
    consumers = _consumer_map(net)
    n_valid = 0
    for c, (start, stop, alloc, gf) in enumerate(metas):
        names = {l.name for l in net.layers[start:stop]}
        ref = estimate_segment_scalar(net, hw, start, stop, alloc, gf,
                                      names, consumers)
        assert (ref is not None) == bool(valid[c]), (c, metas[c])
        if ref is None:
            continue
        n_valid += 1
        # bit-exact, not approx: the batched math preserves the scalar
        # accumulation order
        assert ref.est_energy == energy[c], (c, metas[c])
        assert ref.est_latency == latency[c], (c, metas[c])
        assert ref.est_dram == dram[c], (c, metas[c])
    assert n_valid > 0
    return len(metas), n_valid


@pytest.mark.parametrize("name", ["resnet", "googlenet", "lstm"])
def test_candidate_estimates_and_masks_match_scalar(name):
    net = get_net(name, batch=64)
    total, n_valid = _assert_candidate_parity(net, HW)
    if name == "resnet":
        assert total > n_valid          # invalid lanes were compared too


def test_candidate_parity_with_dram_ports_and_edge_hw():
    net = get_net("alexnet", batch=4)
    _assert_candidate_parity(net, eyeriss_multinode(dram_ports=4))
    _assert_candidate_parity(net, tpu_like_edge())


@pytest.mark.parametrize("name", SEVEN)
def test_enumerate_segments_matches_scalar(name):
    """Pareto survivors identical (same candidates, same order)."""
    net = get_net(name, batch=64)
    for start in (0, len(net.layers) // 2, len(net.layers) - 1):
        assert enumerate_segments(net, HW, start) == \
            enumerate_segments_scalar(net, HW, start)


@pytest.mark.parametrize("name", SEVEN)
@pytest.mark.parametrize("objective", ["energy", "edp"])
def test_dp_chain_costs_match_scalar(name, objective):
    net = get_net(name, batch=64)
    batched = dp_prioritize(net, HW, objective=objective)
    scalar = dp_prioritize_scalar(net, HW, objective=objective)
    assert [c.est_cost for c in batched] == [c.est_cost for c in scalar]
    # chain structure: same segment boundaries cost-wise (ties may pick a
    # different equal-cost alloc, so compare est fields, not allocs)
    for cb, cs in zip(batched, scalar):
        assert [(s.start, s.stop, s.est_energy) for s in cb.segments] == \
            [(s.start, s.stop, s.est_energy) for s in cs.segments]


def test_dram_ports_scales_dram_bound_latency():
    # a layer whose optimistic bound is DRAM-limited: more ports -> faster
    layer = fc("f", 64, 4096, 4096)
    e1 = estimate_layer(layer, eyeriss_multinode(), nodes_assigned=1)
    e4 = estimate_layer(layer, eyeriss_multinode(dram_ports=4),
                        nodes_assigned=1)
    assert e1.valid and e4.valid
    assert e4.latency_lb_cycles <= e1.latency_lb_cycles
    assert e4.energy_lb_pj == e1.energy_lb_pj        # ports change no energy
    # compute-bound side unaffected by port count
    c1 = estimate_layer(conv("c", 1, 8, 8, 7, 7, 3, 3), eyeriss_multinode(),
                        nodes_assigned=256)
    c4 = estimate_layer(conv("c", 1, 8, 8, 7, 7, 3, 3),
                        eyeriss_multinode(dram_ports=4), nodes_assigned=256)
    assert c1.valid and c4.valid


def test_transformer_builder_registered():
    assert "transformer" in NETS
    g = get_net("transformer", batch=8)
    assert len(g.layers) == 6 * 12                  # default 12 blocks
    g48 = transformer(batch=4, layers=48, d_model=256, d_ff=1024)
    assert len(g48.layers) == 6 * 48
    # residual edges: second add of each block consumes ff2 + first add
    assert g48.by_name["b1.add2"].src == ("b1.ff2", "b1.add1")
    assert g48.by_name["b1.qkv"].src == ("b0.add2",)


def test_transformer_solves_end_to_end():
    g = transformer(batch=8, layers=3, d_model=128, d_ff=256)
    res = solve(g, HW)
    assert res.valid
    assert set(res.layer_schemes) == {l.name for l in g.layers}


def test_parallel_chain_solving_matches_serial():
    net = get_net("alexnet", batch=64)
    memo.clear_all()
    serial = solve(net, HW, max_workers=1)
    memo.clear_all()
    parallel = solve(net, HW, max_workers=8)
    assert serial.valid and parallel.valid
    assert parallel.total_energy_pj == serial.total_energy_pj
    assert parallel.total_latency_cycles == serial.total_latency_cycles
    assert set(parallel.layer_schemes) == set(serial.layer_schemes)


def test_wide_allocs_never_hurt_chain_cost():
    """The widened 2-D alloc space is a strict superset: the DP's best
    chain cost can only improve over the column-strip-only space."""
    from repro.core.solver.interlayer import segment_pool
    net = get_net("mlp", batch=64)
    n = len(net.layers)
    wide = segment_pool(net, HW, range(n), 4, wide=True)
    narrow = segment_pool(net, HW, range(n), 4, wide=False)
    n_wide = sum(len(v) for v in wide.values())
    n_narrow = sum(len(v) for v in narrow.values())
    assert n_wide >= n_narrow
