"""Directive representation: footprints, parallelism, access counts."""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:     # degrade: property tests skip, rest run
    from _hypothesis_stub import given, settings, strategies as st

from repro.core.directives import (LayerScheme, LevelBlocking,
                                   canonical_orders, divisors,
                                   smallest_prime_factor)
from repro.workloads.layers import conv, fc


def simple_scheme(layer, t0=None, s0=None, t1=None, s1=None, t2=None):
    lv0 = LevelBlocking(t=t0 or {}, s=s0 or {})
    lv1 = LevelBlocking(t=t1 or {}, s=s1 or {})
    lv2 = LevelBlocking(t=t2 or {})
    return LayerScheme(layer, [lv0, lv1, lv2])


def test_divisors():
    assert divisors(12) == [1, 2, 3, 4, 6, 12]
    assert divisors(1) == [1]
    assert divisors(13) == [1, 13]


def test_smallest_prime_factor():
    assert smallest_prime_factor(12) == 2
    assert smallest_prime_factor(35) == 5
    assert smallest_prime_factor(13) == 13
    assert smallest_prime_factor(1) == 1


def test_canonical_orders_unique():
    orders = canonical_orders()
    assert len(orders) == 6
    assert len(set(orders)) == 6
    for o in orders:
        assert set(o) == {"N", "C", "K", "X", "Y"}


def test_fc_footprints():
    layer = fc("f", 8, 16, 32)
    sch = simple_scheme(layer, t0={"N": 2, "C": 4},
                        t1={"N": 4, "C": 4, "K": 8}, t2={"K": 4})
    assert sch.validate_factors()
    # level 0 tile: I = 2*4 = 8; W = 4*1... K at level0 = 1
    assert sch.tile_elems("I", 0) == 8
    assert sch.tile_elems("W", 0) == 4
    assert sch.tile_elems("O", 0) == 2
    # level 1 tile: cumfactors N=8, C=16, K=8
    assert sch.tile_elems("I", 1) == 8 * 16
    assert sch.tile_elems("W", 1) == 16 * 8
    assert sch.tile_elems("O", 1) == 8 * 8


def test_spatial_sharding_reduces_tile():
    layer = fc("f", 8, 16, 32)
    sch = simple_scheme(layer, s1={"K": 4}, t1={"N": 8, "C": 16, "K": 8})
    # W tile at level 1 excludes its own spatial factor
    assert sch.tile_elems("W", 1) == 16 * 8
    assert sch.parallelism(1) == 4
    # replication: I doesn't contain K => replicated across the 4 nodes
    assert sch.replication("I", 1) == 4
    assert sch.replication("W", 1) == 1


def test_fetch_counts_order_dependence():
    layer = fc("f", 4, 8, 16)
    # all blocking at DRAM level; order decides refetches into GBUF
    lvls = [LevelBlocking(), LevelBlocking(),
            LevelBlocking(t={"N": 4, "C": 8, "K": 16},
                          order=("K", "C", "N"))]
    sch = LayerScheme(layer, lvls)
    # I (N,C): innermost relevant loop is N (innermost) -> full product
    assert sch.fetches_into("I", 1) == 1 * (16 * 8 * 4)
    # W (C,K): innermost relevant is C; trailing irrelevant N reused
    assert sch.fetches_into("W", 1) == 1 * (16 * 8)
    # O (N,K) with reduction C outside => partial-sum rw
    rounds_rel = 16 * 8 * 4   # innermost relevant N
    assert sch.fetches_into("O", 1) == pytest.approx(2 * rounds_rel -
                                                     rounds_rel)


@settings(max_examples=200, deadline=None)
@given(n=st.sampled_from([4, 8, 16]), c=st.sampled_from([4, 12, 16]),
       k=st.sampled_from([8, 16]), data=st.data())
def test_property_factor_conservation(n, c, k, data):
    """Any valid split keeps allocated == total and tile products sane."""
    layer = fc("f", n, c, k)
    def split(total):
        d0 = data.draw(st.sampled_from(divisors(total)))
        d1 = data.draw(st.sampled_from(divisors(total // d0)))
        return d0, d1, total // d0 // d1
    tn, tc, tk = split(n), split(c), split(k)
    sch = simple_scheme(layer,
                        t0={"N": tn[0], "C": tc[0], "K": tk[0]},
                        t1={"N": tn[1], "C": tc[1], "K": tk[1]},
                        t2={"N": tn[2], "C": tc[2], "K": tk[2]})
    assert sch.validate_factors()
    # tensor tiles never exceed full tensor sizes
    for t in layer.tensors:
        for lvl in range(3):
            assert sch.tile_elems(t, lvl) <= layer.tensor_size(t) + 1e-9
    # fetches into a level are at least the data once
    for t in layer.tensors:
        assert sch.fetches_into(t, 1) >= sch.tile_elems(t, 1) - 1e-9


def test_to_directives_roundtrip_sizes():
    layer = conv("c", 4, 8, 16, 14, 14, 3, 3)
    sch = simple_scheme(layer, t0={"X": 7}, s0={"Y": 7},
                        t1={"C": 8, "X": 2, "Y": 2}, s1={"K": 4},
                        t2={"N": 4, "K": 4})
    assert sch.validate_factors()
    dirs = sch.to_directives(["REGF", "GBUF", "DRAM"])
    assert len(dirs) == 3
    text = "\n".join(str(d) for d in dirs)
    assert "stack(" in text and "update(" in text and "tensor{" in text


def test_top_level_granularity():
    layer = fc("f", 8, 16, 32)
    sch = simple_scheme(layer, t1={"N": 8, "C": 16, "K": 32})
    g = sch.top_level_granularity()
    assert g == {"K": 32, "N": 8}
