"""Optimizers + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:     # degrade: property tests skip, rest run
    from _hypothesis_stub import given, settings, strategies as st

from repro.optim.compression import (compress, decompress, ef_round,
                                     init_error, wire_bytes_saved)
from repro.optim.optimizers import (adafactor, adamw, clip_by_global_norm,
                                    global_norm, make_optimizer)


def quad_loss(params):
    return sum(jnp.sum(jnp.square(p - 3.0))
               for p in jax.tree_util.tree_leaves(params))


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_optimizer_reduces_loss(opt_name):
    opt = make_optimizer(opt_name, lr=0.1, weight_decay=0.0)
    params = {"w": jnp.zeros((8, 8)), "b": jnp.zeros((8,))}
    state = opt.init(params)
    losses = []
    for _ in range(60):
        loss, grads = jax.value_and_grad(quad_loss)(params)
        params, state = opt.update(grads, state, params)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.2


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((16,))}
    state = opt.init(params)
    assert state["f"]["w"]["vr"].shape == (64,)
    assert state["f"]["w"]["vc"].shape == (32,)
    assert state["f"]["b"]["v"].shape == (16,)
    # factored state is much smaller than the params
    n_state = sum(x.size for x in jax.tree_util.tree_leaves(state["f"]))
    n_param = sum(x.size for x in jax.tree_util.tree_leaves(params))
    assert n_state < n_param * 0.2


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    small = {"a": jnp.full((4,), 0.01)}
    out = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(small["a"]), rtol=1e-6)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-100, 100), min_size=1, max_size=32))
def test_compression_bounded_error(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, s = compress(x)
    back = decompress(q, s)
    assert np.max(np.abs(np.asarray(back) - np.asarray(x))) <= \
        float(s) * 0.5 + 1e-6


def test_error_feedback_converges():
    """EF accumulates what quantization drops: the *sum* of dequantized
    grads over steps tracks the sum of true grads."""
    g = {"w": jnp.full((16,), 0.003)}
    err = init_error(g)
    total = np.zeros((16,), np.float32)
    for _ in range(100):
        deq, err = ef_round(g, err)
        total += np.asarray(deq["w"], np.float32)
    np.testing.assert_allclose(total, 0.3 * np.ones(16), rtol=0.05)


def test_wire_bytes_saved():
    g = {"w": jnp.zeros((1000,))}
    bf16, int8 = wire_bytes_saved(g)
    assert bf16 == 2000 and int8 < bf16
