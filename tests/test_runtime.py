"""Fault tolerance, elastic rescale, straggler mitigation."""
import time

import pytest

from repro.runtime.fault import (ElasticPlanner, NodeFailure, RecoveryPolicy,
                                 RecoveryStats, StepHeartbeat,
                                 run_with_recovery)
from repro.runtime.straggler import BackupDispatcher, StragglerDetector


def test_recovery_restores_and_retries():
    done = []
    fails = {"n": 0}

    def step(i):
        if i == 3 and fails["n"] < 2:
            fails["n"] += 1
            raise NodeFailure("chip lost")
        done.append(i)

    restores = []
    def restore():
        restores.append(1)
        return 2                      # resume from checkpointed step 2

    stats = run_with_recovery(step, 0, 6, restore,
                              policy=RecoveryPolicy(backoff_seconds=0),
                              sleep=lambda s: None)
    assert stats.restarts == 2
    assert done[-1] == 5
    assert 3 in done


def test_recovery_gives_up_after_max_retries():
    def step(i):
        raise NodeFailure("persistent")
    with pytest.raises(NodeFailure):
        run_with_recovery(step, 0, 3, lambda: 0,
                          policy=RecoveryPolicy(max_retries=2,
                                                backoff_seconds=0),
                          sleep=lambda s: None)


def test_permanent_loss_triggers_reshard():
    calls = []
    state = {"failed": False}

    def step(i):
        if i == 1 and not state["failed"]:
            state["failed"] = True
            raise NodeFailure("host down", lost_devices=16, permanent=True)

    stats = run_with_recovery(step, 0, 3, lambda: 0,
                              policy=RecoveryPolicy(backoff_seconds=0),
                              on_permanent_loss=lambda n: calls.append(n),
                              sleep=lambda s: None)
    assert calls == [16]
    assert stats.reshards == 1


def test_elastic_planner_keeps_tp_groups():
    ep = ElasticPlanner(model_axis=16)
    data, model = ep.plan(512 - 16)       # one host of 16 chips lost
    assert model == 16
    assert data == 16                      # 31 groups -> pow2 floor 16
    data2, _ = ep.plan(256)
    assert data2 == 16
    with pytest.raises(NodeFailure):
        ep.plan(8)


def test_elastic_batch_rescale():
    ep = ElasticPlanner(model_axis=16)
    assert ep.batch_for(256, 8, 16) == 128   # per-replica batch preserved


def test_straggler_detector():
    d = StragglerDetector(factor=1.5, warmup=3)
    for _ in range(5):
        for h in ("a", "b", "c"):
            d.record(h, 1.0)
        d.record("slow", 3.0)
    assert d.stragglers() == ["slow"]


def test_heartbeat_deadline():
    t = {"now": 0.0}
    hb = StepHeartbeat(deadline_seconds=10, clock=lambda: t["now"])
    hb.arm()
    t["now"] = 5.0
    hb.check()                             # fine
    t["now"] = 11.0
    with pytest.raises(NodeFailure):
        hb.check()


def test_backup_dispatcher_prefers_fast_backup():
    bd = BackupDispatcher(deadline_seconds=0.05)
    def slow():
        time.sleep(1.0)
        return "slow"
    def fast():
        return "fast"
    assert bd.run(slow, fast) == "fast"
    bd.close()


def test_recovery_default_policy_is_fresh_per_call():
    import inspect
    # a dataclass default instance in the signature would be shared
    # (mutable default): the default must be None, constructed per call
    assert inspect.signature(run_with_recovery) \
        .parameters["policy"].default is None
    done = []
    stats = run_with_recovery(lambda i: done.append(i), 0, 2, lambda: 0,
                              sleep=lambda s: None)
    assert stats.restarts == 0 and done == [0, 1]


def test_backup_dispatcher_failover_when_primary_raises():
    with BackupDispatcher(deadline_seconds=0.5) as bd:
        def bad():
            raise ValueError("primary died")
        assert bd.run(bad, lambda: "backup") == "backup"
        assert bd.failovers == 1


def test_backup_dispatcher_ignores_raising_backup():
    with BackupDispatcher(deadline_seconds=0.01) as bd:
        def slow_ok():
            time.sleep(0.1)
            return "primary"
        def bad():
            raise ValueError("backup died")
        assert bd.run(slow_ok, bad) == "primary"
        assert bd.failovers == 0


def test_backup_dispatcher_both_raise_surfaces_primary_error():
    class PrimaryErr(Exception):
        pass
    with BackupDispatcher(deadline_seconds=0.01) as bd:
        def p():
            time.sleep(0.05)
            raise PrimaryErr("p")
        def b():
            raise ValueError("b")
        with pytest.raises(PrimaryErr):
            bd.run(p, b)


def test_backup_dispatcher_run_with_queued_backup():
    # one worker: the deadline-launched backup queues behind the still-
    # running primary; the primary's win is returned either way
    with BackupDispatcher(deadline_seconds=0.01, workers=1) as bd:
        def slow_ok():
            time.sleep(0.05)
            return "primary"
        assert bd.run(slow_ok, lambda: "backup") == "primary"


def test_backup_dispatcher_cancels_unstarted_loser():
    import threading
    # pin the single worker so the loser stays queued (cancellable):
    # cancellation must land before the winner's result is returned
    with BackupDispatcher(deadline_seconds=0.01, workers=1) as bd:
        blocker = threading.Event()
        bd.pool.submit(blocker.wait)
        winner = bd.pool.submit(lambda: "w")
        loser = bd.pool.submit(lambda: "l")
        threading.Timer(0.02, blocker.set).start()
        assert bd._finish(winner, loser) == "w"
        assert bd.cancelled_losers == 1
        assert loser.cancelled()


def test_circuit_breaker_state_machine():
    from repro.runtime.fault import CircuitBreaker
    t = {"now": 0.0}
    br = CircuitBreaker(threshold=2, cooldown_s=10.0,
                        clock=lambda: t["now"])
    assert br.state == "closed" and br.allow()
    br.record_failure()
    assert br.state == "closed"             # below threshold
    br.record_success()
    br.record_failure()
    assert br.state == "closed"             # success reset the streak
    br.record_failure()
    assert br.state == "open" and not br.allow()
    t["now"] = 11.0                         # past the cooldown
    assert br.state == "half-open"
    assert br.allow()                       # the single probe
    assert not br.allow()                   # no second probe
    br.record_failure()                     # probe failed: re-open
    assert br.state == "open" and not br.allow()
    t["now"] = 22.0
    assert br.allow()
    br.record_success()                     # probe succeeded: closed
    assert br.state == "closed" and br.allow()
    assert br.stats()["opens"] == 1         # re-open is not a new open
    assert br.stats()["consecutive_failures"] == 0


def test_straggler_forget_and_stats():
    d = StragglerDetector(factor=1.5, warmup=2)
    for _ in range(3):
        for h in ("a", "b"):
            d.record(h, 1.0)
        d.record("slow", 9.0)
    assert d.stragglers() == ["slow"]
    st = d.stats()
    assert st["stragglers"] == ["slow"]
    assert st["counts"]["slow"] == 3
    d.forget("slow")                       # drained/replaced node
    assert d.stragglers() == []
    assert "slow" not in d.stats()["hosts"]
    med = d.stats()["fleet_median"]
    assert med == pytest.approx(1.0)       # median no longer poisoned
    d.forget("never-seen")                 # idempotent / unknown ok


def test_elastic_planner_non_pow2_survivors():
    ep = ElasticPlanner(model_axis=16)
    data, model = ep.plan(17 * 16)         # 17 groups -> pow2 floor 16
    assert (data, model) == (16, 16)
    data, _ = ep.plan(3 * 16 + 7)          # ragged: 3 groups -> 2
    assert data == 2
    data, _ = ep.plan(16)                  # exactly one group survives
    assert data == 1


def test_elastic_planner_min_data_boundary():
    ep = ElasticPlanner(model_axis=16, min_data=2)
    assert ep.plan(32) == (2, 16)          # boundary: exactly min_data
    with pytest.raises(NodeFailure) as ei:
        ep.plan(31)                        # one chip short of 2 groups
    assert ei.value.permanent


def test_elastic_batch_round_trip_keeps_microbatch():
    ep = ElasticPlanner(model_axis=16)
    b16 = 256
    per_replica = b16 // 16
    b8 = ep.batch_for(b16, 8, 16)
    assert b8 // 8 == per_replica          # microbatch preserved down
    assert ep.batch_for(b8, 16, 8) == b16  # and exactly restored up


def test_elastic_plan_nodes():
    ep = ElasticPlanner(model_axis=1, min_data=2)
    assert ep.plan_nodes(3) == 3           # every survivor stays used
    assert ep.plan_nodes(2) == 2
    with pytest.raises(NodeFailure) as ei:
        ep.plan_nodes(1)
    assert ei.value.permanent


def test_recovery_permanent_loss_reshard_resume():
    # permanent loss -> on_permanent_loss re-plans the mesh -> restore
    # rewinds to the checkpoint -> the run RESUMES and completes on the
    # shrunk mesh (the full reshard path, with a simulated restore)
    ep = ElasticPlanner(model_axis=16)
    world = {"chips": 512, "data": 16, "ckpt": 0, "restores": 0}
    done = []

    def reshard(lost):
        world["chips"] -= lost
        world["data"], _ = ep.plan(world["chips"])

    def restore():
        world["restores"] += 1
        return world["ckpt"]

    def step(i):
        if i == 3 and world["chips"] == 512:
            raise NodeFailure("host down", lost_devices=384,
                              permanent=True)
        done.append((i, world["data"]))
        if i % 2 == 0:
            world["ckpt"] = i + 1          # checkpoint after even steps

    stats = run_with_recovery(step, 0, 6, restore,
                              policy=RecoveryPolicy(backoff_seconds=0),
                              on_permanent_loss=reshard,
                              sleep=lambda s: None)
    assert stats.reshards == 1 and stats.restarts == 1
    assert world["restores"] == 1
    assert world["data"] == 8              # 128 chips -> 8 TP groups
    # steps 3..5 ran on the shrunk mesh after replaying from ckpt 3
    assert [d for i, d in done if i >= 3] == [8, 8, 8]
    assert [i for i, _ in done] == [0, 1, 2, 3, 4, 5]
