#!/usr/bin/env python
"""Batched serving: prefill a batch of prompts, then decode with a shared
KV-cache pool (dense) or SSM state (mamba2).

  PYTHONPATH=src python examples/serve_batched.py --arch mamba2-1.3b
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    toks = serve(args.arch, requests=args.requests, prompt_len=32,
                 gen=args.gen, tiny=True)
    print("generated token matrix:", toks.shape)


if __name__ == "__main__":
    main()
