#!/usr/bin/env python
"""Quickstart: KAPLA schedules AlexNet on the 16x16-node Eyeriss-like
accelerator and prints the winning tensor-centric directives (paper
Listing-1 style), the energy/latency, and a comparison with random search.
The solve routes through the SCHEDULE SERVICE (LocalClient over a
content-addressed store), and a repeated request demonstrates the cached
path: a store hit instead of a re-solve.  Then the winning scheme for one
conv layer is LOWERED to a Pallas kernel plan and executed (interpret
mode on CPU), and finally the WHOLE batch-1 schedule is compiled to a
NetworkPlan and executed end-to-end — segment pipelining, on-chip
forwarding and all — printing predicted-vs-measured latency at both
tiers: the full solver -> store -> silicon-facing pipeline in one script.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

try:                     # installed package, or PYTHONPATH=src (see docs)
    import repro         # noqa: F401
except ImportError:      # fallback: resolve src/ relative to this file so
    sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                    "..", "src"))

import atexit
import tempfile

from repro.core.solver import random_search, solve
from repro.hw.presets import eyeriss_multinode
from repro.lower import (compare_network, lower_scheme, make_inputs,
                         make_network_inputs, measure_network, measure_plan,
                         network_runner, verify_plan)
from repro.service import LocalClient, ScheduleStore
from repro.workloads.nets import get_net


def main():
    hw = eyeriss_multinode()
    net = get_net("alexnet", batch=64)
    print(f"scheduling {net.name}: {len(net)} layers on {hw.name} "
          f"({hw.total_pes} PEs)")

    # solves route through the schedule service: a content-addressed store
    # keeps every winner, so only the first request pays the solver
    store_dir = tempfile.TemporaryDirectory(
        prefix="repro-quickstart-store-")
    atexit.register(store_dir.cleanup)
    client = LocalClient(ScheduleStore(store_dir.name))
    first = client.solve(net, hw)
    res = first.schedule
    print(f"\nKAPLA: energy {res.total_energy_pj / 1e9:.2f} mJ, "
          f"latency {res.total_latency_cycles / hw.freq_hz * 1e3:.2f} ms, "
          f"solved in {res.solve_seconds:.2f} s")
    print(f"inter-layer chains kept: k_S={len(res.chain.segments)} segments")
    st = res.prune_stats
    print(f"pruning: {st.total} inter-layer candidates -> "
          f"{st.after_pareto} after validity+Pareto "
          f"({100 * (1 - st.after_pareto / st.total):.1f}% pruned)")

    print("\n--- directives for conv2 (row-stationary, node-parallel) ---")
    for d in res.layer_schemes["conv2"].to_directives(
            ["REGF", "GBUF", "DRAM"]):
        print(d)

    rnd = random_search.solve(net, hw, samples=500)
    print(f"\nrandom search: {rnd.total_energy_pj / res.total_energy_pj:.2f}x"
          " KAPLA energy")

    # --- same request again: served from the store, not re-solved ----------
    second = client.solve(get_net("alexnet", batch=64), hw)
    st = client.stats()
    print(f"\nschedule service: first solve source={first.source} "
          f"({first.seconds * 1e3:.0f} ms), second source={second.source} "
          f"({second.seconds * 1e3:.1f} ms, "
          f"{first.seconds / second.seconds:.0f}x faster) | "
          f"store hits={st['hits']} misses={st['misses']}")
    assert second.schedule.total_energy_pj == res.total_energy_pj

    # --- lower the winning scheme for one layer and actually run it --------
    # (batch 1 keeps the interpret-mode execution snappy on CPU)
    edge_net = get_net("alexnet", batch=1)
    edge = solve(edge_net, hw)
    plan = lower_scheme(edge.layer_schemes["conv3"], hw)
    print(f"\n--- lowering conv3 (batch 1) to a Pallas plan ---")
    print(plan.describe())
    ok, err = verify_plan(plan)
    print(f"numerics vs kernels/ref.py oracle: "
          f"{'OK' if ok else 'MISMATCH'} (max rel err {err:.1e})")
    measured = measure_plan(plan, make_inputs(plan), iters=2)
    predicted = plan.predicted.latency_cycles / hw.freq_hz
    print(f"predicted latency {predicted * 1e3:.3f} ms "
          f"({plan.predicted.latency_cycles:.0f} cycles @ "
          f"{hw.freq_hz / 1e6:.0f} MHz) | measured (interpret mode, jitted) "
          f"{measured * 1e3:.3f} ms")
    print("(interpret mode calibrates the model's *ranking*, not absolute "
          "silicon time — see README 'Lowering & calibration')")

    # --- then lower and execute the WHOLE network (the network tier) -------
    nplan = edge.lower(edge_net, hw)
    print(f"\n--- network tier: executing all of alexnet (batch 1) ---")
    print(nplan.describe())
    # one compiled runner serves verification, warmup and timing
    net_inputs = make_network_inputs(nplan)
    run = network_runner(nplan, net_inputs)
    ver = compare_network(nplan, run(), net_inputs)
    print(f"whole-graph numerics vs reference pass: "
          f"{'OK' if ver.ok else 'MISMATCH'} (worst layer {ver.worst_layer}, "
          f"max rel err {ver.max_rel_err:.1e}); "
          f"{ver.n_forwarded} tensors forwarded on-chip")
    net_measured = measure_network(nplan, iters=1, warmup=0, runner=run)
    net_predicted = nplan.predicted_latency_cycles / hw.freq_hz
    print(f"network predicted {net_predicted * 1e3:.2f} ms | measured "
          f"(interpret) {net_measured * 1e3:.2f} ms — see BENCH_network.json "
          "for the multi-net Spearman record")
    # the compiled tier: whole segments fused into single executables
    # (the default measured path; interpret above is the oracle)
    fused_measured = measure_network(nplan, net_inputs, iters=1,
                                     backend="compiled")
    print(f"fused compiled tier: {fused_measured * 1e3:.2f} ms "
          f"({net_measured / fused_measured:.0f}x over interpret) — "
          "segments are single jitted executables, cached process-wide "
          "by plan signature (README 'Compiled execution')")


if __name__ == "__main__":
    main()
