#!/usr/bin/env python
"""Quickstart: KAPLA schedules AlexNet on the 16x16-node Eyeriss-like
accelerator and prints the winning tensor-centric directives (paper
Listing-1 style), the energy/latency, and a comparison with random search.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.solver import random_search, solve
from repro.hw.presets import eyeriss_multinode
from repro.workloads.nets import get_net


def main():
    hw = eyeriss_multinode()
    net = get_net("alexnet", batch=64)
    print(f"scheduling {net.name}: {len(net)} layers on {hw.name} "
          f"({hw.total_pes} PEs)")

    res = solve(net, hw)
    print(f"\nKAPLA: energy {res.total_energy_pj / 1e9:.2f} mJ, "
          f"latency {res.total_latency_cycles / hw.freq_hz * 1e3:.2f} ms, "
          f"solved in {res.solve_seconds:.2f} s")
    print(f"inter-layer chains kept: k_S={len(res.chain.segments)} segments")
    st = res.prune_stats
    print(f"pruning: {st.total} inter-layer candidates -> "
          f"{st.after_pareto} after validity+Pareto "
          f"({100 * (1 - st.after_pareto / st.total):.1f}% pruned)")

    print("\n--- directives for conv2 (row-stationary, node-parallel) ---")
    for d in res.layer_schemes["conv2"].to_directives(
            ["REGF", "GBUF", "DRAM"]):
        print(d)

    rnd = random_search.solve(net, hw, samples=500)
    print(f"\nrandom search: {rnd.total_energy_pj / res.total_energy_pj:.2f}x"
          " KAPLA energy")


if __name__ == "__main__":
    main()
