#!/usr/bin/env python
"""Show the KAPLA-style autoshard plan for an assigned architecture x shape
(without needing 512 devices): candidate log, chosen specs, HBM accounting.

  PYTHONPATH=src python examples/autoshard_plan.py --arch kimi-k2-1t-a32b
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import SHAPES, get_config
from repro.core.autoshard import plan_sharding
from repro.models.api import build_model
from repro.optim.optimizers import make_optimizer


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="kimi-k2-1t-a32b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16}
                    if args.multi_pod else {"data": 16, "model": 16})
    api = build_model(cfg)
    param_sds = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    opt_sds = jax.eval_shape(make_optimizer(cfg.optimizer).init, param_sds) \
        if shape.mode == "train" else {}
    plan = plan_sharding(cfg, shape, mesh, param_sds, opt_sds)

    print(f"plan for {args.arch} x {args.shape} on {mesh.shape}:")
    print("  solver candidate log (validity check + cost estimate):")
    for n in plan.notes:
        print(f"    {n}")
    print(f"  chosen: zero={plan.zero_opt} attn_sharded={plan.attn_sharded} "
          f"hbm/chip={plan.hbm_gb_per_chip:.1f} GiB")
    print("  example param specs:")
    shown = 0
    flat = jax.tree_util.tree_flatten_with_path(
        plan.param_specs, is_leaf=lambda x: hasattr(x, "index"))[0]
    for path, spec in flat[:60]:
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if any(t in name for t in ("wq", "wi/", "embed", "lm_head", "w_x",
                                   "moe")):
            print(f"    {name}: {spec}")
            shown += 1
            if shown > 8:
                break


if __name__ == "__main__":
    main()
