#!/usr/bin/env python
"""End-to-end training driver: trains a reduced Qwen2.5-family model for a
few hundred steps with checkpointing, failure injection and automatic
recovery — the full production code path at laptop scale.

  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 200]
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen2.5-3b")
    args = ap.parse_args()
    with tempfile.TemporaryDirectory() as ckpt_dir:
        losses, stats = train(
            args.arch, steps=args.steps, batch=8, seq=128, tiny=True,
            ckpt_dir=ckpt_dir, ckpt_every=50,
            fail_at=args.steps // 2,       # inject a node failure mid-run
            log_every=20)
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"recovered from {stats.restarts} injected failure(s)")


if __name__ == "__main__":
    main()
