"""Table V: KAPLA energy overhead vs exhaustive for GoogLeNet across
hardware configurations (node/PE/buffer sweeps)."""
from __future__ import annotations

import sys
sys.path.insert(0, "src")

from repro.core.solver import exhaustive, solve
from repro.hw.presets import eyeriss_multinode
from repro.workloads.nets import get_net

from .common import emit, timed

CONFIGS = [
    # (batch, nodes, pe, gbuf, regf)
    (64, 4, 8, 32 * 1024, 32),
    (64, 4, 8, 32 * 1024, 64),
    (64, 4, 8, 32 * 1024, 128),
    (8, 4, 16, 32 * 1024, 32),
    (1, 16, 8, 32 * 1024, 64),
]


def run(budget=400, net_name="alexnet"):
    # paper uses GoogLeNet; we sweep AlexNet so the exhaustive reference is
    # meaningful (not budget-starved) within the CPU budget — the claim
    # under test is robustness of the K-vs-S gap across hardware configs
    rows = []
    for batch, nodes, pe, gbuf, regf in CONFIGS:
        hw = eyeriss_multinode(nodes=nodes, pe=pe, regf_bytes=regf,
                               gbuf_bytes=gbuf)
        net = get_net(net_name, batch=batch, training=False)
        k, us_k = timed(solve, net, hw, max_seg_len=2)
        s, _ = timed(exhaustive.solve, net, hw, budget_per_layer=budget,
                     max_seg_len=2)
        if not s.valid:
            rows.append((f"tab5.b{batch}.n{nodes}.pe{pe}.regf{regf}", us_k,
                         "overhead=n/a(S found no valid scheme in budget)"))
            continue
        ov = k.total_energy_pj / s.total_energy_pj - 1.0
        rows.append((f"tab5.b{batch}.n{nodes}.pe{pe}.regf{regf}", us_k,
                     f"overhead={ov * 100:.1f}%"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
