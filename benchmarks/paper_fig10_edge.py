"""Fig 10: inference on the single-node TPU-like edge device, batch 1."""
from __future__ import annotations

import sys
sys.path.insert(0, "src")

from repro.core.solver import annealing, exhaustive, random_search, solve
from repro.hw.presets import tpu_like_edge
from repro.workloads.nets import get_net

from .common import emit, timed

NETS = ["alexnet", "mobilenet", "mlp", "lstm"]


def run(nets=None, budget=200):
    hw = tpu_like_edge()
    rows = []
    for name in nets or NETS:
        net = get_net(name, batch=1, training=False)
        s, _ = timed(exhaustive.solve, net, hw, budget_per_layer=budget)
        k, us_k = timed(solve, net, hw)
        r, _ = timed(random_search.solve, net, hw, samples=600, p=0.85)
        m, _ = timed(annealing.solve, net, hw, iters=10, batch=16)
        base = s.total_energy_pj
        rows.append((f"fig10.{name}.K", us_k,
                     f"norm_energy={k.total_energy_pj / base:.3f}"))
        rows.append((f"fig10.{name}.R", 0.0,
                     f"norm_energy={r.total_energy_pj / base:.3f}"))
        rows.append((f"fig10.{name}.M", 0.0,
                     f"norm_energy={m.total_energy_pj / base:.3f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
