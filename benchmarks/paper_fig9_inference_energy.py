"""Fig 9: dataflow energy for INFERENCE on the multi-node accelerator."""
from __future__ import annotations

import sys
sys.path.insert(0, "src")

from repro.core.solver import annealing, exhaustive, random_search, solve
from repro.hw.presets import eyeriss_multinode
from repro.workloads.nets import get_net

from .common import emit, timed

NETS = ["alexnet", "mobilenet", "vggnet", "mlp", "lstm"]


def run(nets=None, budget=100):
    hw = eyeriss_multinode()
    rows = []
    for name in nets or NETS:
        net = get_net(name, batch=64, training=False)
        s, us_s = timed(exhaustive.solve, net, hw, budget_per_layer=budget)
        k, us_k = timed(solve, net, hw)
        r, us_r = timed(random_search.solve, net, hw, samples=400)
        m, us_m = timed(annealing.solve, net, hw, iters=8, batch=12)
        base = s.total_energy_pj
        rows.append((f"fig9.{name}.K", us_k,
                     f"norm_energy={k.total_energy_pj / base:.3f}"))
        rows.append((f"fig9.{name}.R", us_r,
                     f"norm_energy={r.total_energy_pj / base:.3f}"))
        rows.append((f"fig9.{name}.M", us_m,
                     f"norm_energy={m.total_energy_pj / base:.3f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
