"""Fig 8: dataflow PERFORMANCE (latency) for training — same solvers,
normalized latency (lower is better)."""
from __future__ import annotations

import sys
sys.path.insert(0, "src")

from repro.core.solver import annealing, exhaustive, random_search, solve
from repro.hw.presets import eyeriss_multinode
from repro.workloads.nets import get_net

from .common import emit, timed

NETS = ["alexnet", "mlp", "lstm"]


def run(nets=None, budget=100):
    hw = eyeriss_multinode()
    rows = []
    for name in nets or NETS:
        net = get_net(name, batch=64, training=True)
        s, _ = timed(exhaustive.solve, net, hw, budget_per_layer=budget)
        k, us_k = timed(solve, net, hw, objective="perf")
        r, _ = timed(random_search.solve, net, hw, samples=400)
        base = s.total_latency_cycles
        rows.append((f"fig8.{name}.K", us_k,
                     f"norm_latency={k.total_latency_cycles / base:.3f}"))
        rows.append((f"fig8.{name}.R", 0.0,
                     f"norm_latency={r.total_latency_cycles / base:.3f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
