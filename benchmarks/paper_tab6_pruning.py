"""Table VI: effectiveness of inter-layer conservative + Pareto pruning.

Counts are sourced from the solver flight recorder
(``interlayer.funnel_report``), sweeping **every** segment start index
per net — the same memoized candidate batch a DP solve consumes — so the
bench table and an ``obs explain`` record agree by construction rather
than by reconciliation.
"""
from __future__ import annotations

import sys
sys.path.insert(0, "src")

from repro.core.solver.interlayer import funnel_report
from repro.hw.presets import eyeriss_multinode
from repro.workloads.nets import NETS, get_net

from .common import emit, timed


def run(nets=None):
    hw = eyeriss_multinode()
    rows = []
    for name in nets or list(NETS):
        net = get_net(name, batch=64, training=False)
        # all start indices (what a real solve enumerates), one batch
        funnel, us = timed(funnel_report, net, hw, None, 4)
        tot = funnel["totals"]
        pruned = 100.0 * (1 - tot["after_pareto"]
                          / max(1, tot["enumerated"]))
        by_rule = ";".join(
            f"{rule}={info['count']}" for rule, info in
            sorted(funnel["pruned_by_rule"].items()) if info["count"])
        rows.append((f"tab6.{name}", us,
                     f"total={tot['enumerated']};"
                     f"valid={tot['after_validity']};"
                     f"kept={tot['after_pareto']};"
                     f"pruned={pruned:.1f}%"
                     + (f";{by_rule}" if by_rule else "")))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
