"""Table VI: effectiveness of inter-layer conservative + Pareto pruning."""
from __future__ import annotations

import sys
sys.path.insert(0, "src")

from repro.core.solver import enumerate_segments
from repro.core.solver.interlayer import PruneStats
from repro.hw.presets import eyeriss_multinode
from repro.workloads.nets import NETS, get_net

from .common import emit, timed


def run(nets=None):
    hw = eyeriss_multinode()
    rows = []
    for name in nets or list(NETS):
        net = get_net(name, batch=64, training=False)
        stats = PruneStats()
        # representative segment start (paper reports one per net)
        _, us = timed(enumerate_segments, net, hw, 0, 4, stats)
        pruned = 100.0 * (1 - stats.after_pareto / max(1, stats.total))
        rows.append((f"tab6.{name}", us,
                     f"total={stats.total};kept={stats.after_pareto};"
                     f"pruned={pruned:.1f}%"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
